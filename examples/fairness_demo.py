"""Starvation-avoidance demo (paper Fig. 9) via the unified AgentService.

    PYTHONPATH=src python examples/fairness_demo.py

An "elephant" agent arrives first; "mice" keep arriving.  Under SRJF the
elephant's completion grows without bound as mice multiply; under Justitia
it plateaus: once the GPS virtual time passes the elephant's virtual finish
time, later mice queue BEHIND it regardless of their size.  The workload is
expressed once as backend-agnostic ``AgentSpec``s and served through
``AgentService.sim`` — swap ``.sim`` for ``.engine(model, params, ...)`` to
replay it on the real JAX backend.
"""

from repro.api import AgentService, AgentSpec
from repro.core import InferenceSpec, agent_cost

M = 1000.0


def workload(n_mice):
    es = [InferenceSpec(300, 400)] * 6
    specs = [AgentSpec(stages=[es], arrival=0.0, name="elephant")]
    for i in range(n_mice):
        s = [InferenceSpec(250, 150)]
        specs.append(
            AgentSpec(stages=[s], arrival=1.0 + i * 2.5, name="mouse")
        )
    return specs


def main():
    print(f"{'mice':>6s} {'SRJF elephant JCT':>18s} "
          f"{'Justitia elephant JCT':>22s}")
    for n in (30, 60, 120, 240, 480):
        row = []
        for name in ("srjf", "justitia"):
            service = AgentService.sim(name, total_kv=M, decode_rate=30.0)
            handles = service.submit_many(workload(n))
            service.drain()
            row.append(handles[0].jct)   # the elephant
        print(f"{n:6d} {row[0]:17.0f}s {row[1]:21.0f}s")
    print("\nSRJF grows unboundedly; Justitia is bounded "
          "(Theorem B.1: delay <= 2c_max + C_max/M).")


if __name__ == "__main__":
    main()
