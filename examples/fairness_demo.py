"""Starvation-avoidance demo (paper Fig. 9) on the paper-scale simulator.

    PYTHONPATH=src python examples/fairness_demo.py

An "elephant" agent arrives first; "mice" keep arriving.  Under SRJF the
elephant's completion grows without bound as mice multiply; under Justitia
it plateaus: once the GPS virtual time passes the elephant's virtual finish
time, later mice queue BEHIND it regardless of their size.
"""

import numpy as np

from repro.core import InferenceSpec, agent_cost, make_scheduler
from repro.sim import ClusterSim, SimAgent

M = 1000.0


def workload(n_mice):
    es = [InferenceSpec(300, 400)] * 6
    agents = [SimAgent(0, 0.0, [es], agent_cost(es), agent_cost(es))]
    for i in range(n_mice):
        s = [InferenceSpec(250, 150)]
        agents.append(SimAgent(1 + i, 1.0 + i * 2.5, [s],
                               agent_cost(s), agent_cost(s)))
    return agents


def main():
    print(f"{'mice':>6s} {'SRJF elephant JCT':>18s} "
          f"{'Justitia elephant JCT':>22s}")
    for n in (30, 60, 120, 240, 480):
        row = []
        for name in ("srjf", "justitia"):
            sim = ClusterSim(make_scheduler(name, M, service_rate=30.0), M)
            row.append(sim.run(workload(n)).jct[0])
        print(f"{n:6d} {row[0]:17.0f}s {row[1]:21.0f}s")
    print("\nSRJF grows unboundedly; Justitia is bounded "
          "(Theorem B.1: delay <= 2c_max + C_max/M).")


if __name__ == "__main__":
    main()
