"""End-to-end driver: serve a mixed agent workload with batched requests.

    PYTHONPATH=src python examples/serve_agents.py [--scheduler justitia]

The full production path in miniature: the 9-class agent workload sampler
generates task-parallel agents with synthetic prompts; the per-class
TF-IDF+MLP predictor (trained on 60 samples/class here) predicts each
agent's KV token-time at arrival; the Justitia scheduler computes one-shot
virtual finish times; the continuous-batching engine runs REAL model
prefill/decode steps with paged KV accounting, swap-on-pressure, and
non-preemptive admission.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import make_scheduler
from repro.engine import EngineAgent, ServeEngine
from repro.models import Model
from repro.predictor import AgentCostPredictor
from repro.workloads import AGENT_CLASSES, sample_agent

VOCAB = 512


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="justitia")
    ap.add_argument("--n-agents", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("h2o-danube-1.8b").reduced(vocab=VOCAB)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # train the per-class cost predictor on a small history
    print("training per-class MLP cost predictors...")
    samples = {}
    for cls in ("EV", "FV", "CC", "KBQAV"):
        hist = [sample_agent(rng, cls) for _ in range(60)]
        samples[cls] = ([a.prompt for a in hist],
                        [a.true_cost for a in hist])
    predictor = AgentCostPredictor(max_features=48)
    predictor.fit(samples, epochs=300)

    pool = 4096
    engine = ServeEngine(
        model, params,
        make_scheduler(args.scheduler, float(pool)),
        pool_tokens=pool, block_size=16, max_batch=4, cache_len=512,
    )

    # sample small agents, scale their token demands to engine scale
    print(f"submitting {args.n_agents} agents "
          f"({args.scheduler} scheduler)...")
    t0 = time.time()
    for aid in range(args.n_agents):
        cls = ("EV", "FV", "CC", "KBQAV")[aid % 4]
        a = sample_agent(rng, cls)
        stages = [
            [
                (rng.integers(0, VOCAB, size=max(8, s.prefill // 8)),
                 max(4, s.decode // 8))
                for s in stage
            ]
            for stage in a.stages
        ]
        pred_cost = predictor.predict(cls, a.prompt)
        engine.submit_agent(EngineAgent(
            agent_id=aid, arrival_iter=engine.now, stages=stages,
            predicted_cost=pred_cost / 64.0,  # match the 1/8 token scaling
        ))

    completions = engine.run_until_idle()
    wall = time.time() - t0
    engine.alloc.check_invariants()
    jcts = sorted(completions.values())
    print(f"served {args.n_agents} agents / "
          f"{engine.metrics['tokens']} tokens in {wall:.1f}s wall")
    print(f"completion iterations: mean={np.mean(jcts):.0f} "
          f"p90={np.percentile(jcts, 90):.0f}")
    print("engine metrics:", engine.metrics)


if __name__ == "__main__":
    main()
