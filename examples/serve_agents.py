"""End-to-end driver: one workload spec, two backends, one AgentService.

    PYTHONPATH=src python examples/serve_agents.py --backend engine
    PYTHONPATH=src python examples/serve_agents.py --backend sim

The full production path in miniature, now behind the unified serving API:
the 9-class agent workload sampler generates task-parallel agents with
synthetic prompts and bursty (Mooncake-like) arrival times; the per-class
TF-IDF+MLP predictor predicts each agent's KV token-time at arrival; the
scheduler (any name registered with ``@register_scheduler``) computes its
priority keys; and :class:`repro.api.AgentService` streams the agents into
the chosen backend *online* — agents are submitted with future arrival
times and enter the system mid-run, exactly like live traffic.

``--backend engine`` runs REAL model prefill/decode steps (paged KV
accounting, swap-on-pressure, non-preemptive admission); ``--backend sim``
runs the identical AgentSpec list on the discrete-event cluster.

``--replicas N`` serves the workload on an N-way
:class:`repro.api.ReplicatedBackend` fleet: the router (``--router``, any
name registered with ``@repro.api.register_router`` — ``round_robin``,
``least_loaded``, or ``memory_cost_aware``, which places by the
predictor's cost estimate) shards agents across N child backends, the
children advance in lockstep, and their per-replica GPS clocks are
reconciled into one global virtual time whose lag is reported in the
backend metrics.  Every lifecycle event then carries the serving replica.
"""

import argparse
import time

import numpy as np

from repro.api import (
    AgentHooks,
    router_names,
    service_for_backend,
    specs_from_classes,
)
from repro.api.workload import DEFAULT_CLASSES
from repro.core import scheduler_names
from repro.predictor import AgentCostPredictor
from repro.workloads import sample_agent


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="engine",
                    choices=("engine", "sim"))
    ap.add_argument("--scheduler", default="justitia",
                    choices=scheduler_names())
    ap.add_argument("--n-agents", type=int, default=8)
    ap.add_argument("--window-s", type=float, default=30.0)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--router", default="memory_cost_aware",
                    choices=router_names())
    args = ap.parse_args()

    rng = np.random.default_rng(0)

    # train the per-class cost predictor on a small history
    print("training per-class MLP cost predictors...")
    samples = {}
    for cls in DEFAULT_CLASSES:
        hist = [sample_agent(rng, cls) for _ in range(60)]
        samples[cls] = ([a.prompt for a in hist],
                        [a.true_cost for a in hist])
    predictor = AgentCostPredictor(max_features=48)
    predictor.fit(samples, epochs=300)

    specs = specs_from_classes(
        rng, args.n_agents, args.window_s, predictor=predictor
    )
    service = service_for_backend(
        args.backend, args.scheduler, arch="h2o-danube-1.8b",
        pool_tokens=4096,
        replicas=args.replicas, router=args.router,
    )

    fleet = (f" x{args.replicas} replicas via {args.router}"
             if args.replicas > 1 else "")
    print(f"streaming {args.n_agents} agents into the {args.backend} "
          f"backend{fleet} ({args.scheduler} scheduler, online arrivals "
          f"over {args.window_s:.0f}s)...")
    t0 = time.time()
    hooks = AgentHooks(
        on_complete=lambda ev: print(
            f"  t={ev.time:7.1f}s agent {ev.agent_id} done "
            f"(jct {ev.jct:.1f}s"
            + (f", replica {ev.replica}" if ev.replica is not None else "")
            + ")"
        )
    )
    for spec in specs:
        service.submit(spec, hooks=hooks)
    result = service.drain()
    wall = time.time() - t0

    print(f"served {args.n_agents} agents on backend={result.backend} "
          f"in {wall:.1f}s wall")
    print("jct:", result.stats.row())
    print("events:", result.event_counts)
    print("backend metrics:", result.metrics)
    for r, stats in result.per_replica.items():
        print(f"replica {r}: {stats.row()}")


if __name__ == "__main__":
    main()
