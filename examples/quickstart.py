"""Quickstart: serve a tiny model with the Justitia scheduler.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced granite-family model, submits two competing agents (an
elephant and a mouse), and shows selective pampering in action: the mouse
(earlier GPS virtual finish) completes long before the elephant even though
it arrived second.
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core import InferenceSpec, agent_cost, make_scheduler
from repro.engine import EngineAgent, ServeEngine
from repro.models import Model

VOCAB = 256


def make_agent(rng, aid, n_inferences, prompt_len, decode_len):
    stage = [
        (rng.integers(0, VOCAB, size=prompt_len), decode_len)
        for _ in range(n_inferences)
    ]
    specs = [InferenceSpec(prompt_len, decode_len)] * n_inferences
    return EngineAgent(
        agent_id=aid, arrival_iter=0, stages=[stage],
        predicted_cost=agent_cost(specs),
    )


def main():
    cfg = get_config("granite-3-2b").reduced(vocab=VOCAB)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    scheduler = make_scheduler("justitia", total_kv=512.0)
    engine = ServeEngine(
        model, params, scheduler,
        pool_tokens=512, block_size=16, max_batch=2, cache_len=256,
    )

    engine.submit_agent(make_agent(rng, 0, n_inferences=6,
                                   prompt_len=100, decode_len=100))
    engine.submit_agent(make_agent(rng, 1, n_inferences=1,
                                   prompt_len=16, decode_len=8))

    completions = engine.run_until_idle()
    print("agent completion iterations:", completions)
    print("engine metrics:", engine.metrics)
    assert completions[1] < completions[0], "mouse should finish first"
    print("OK: the mouse was pampered past the elephant "
          "(earlier GPS virtual finish time)")


if __name__ == "__main__":
    main()
