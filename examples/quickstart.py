"""Quickstart for the unified serving API (``repro.api.AgentService``).

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced granite-family model, wraps it in an ``AgentService`` over
the real continuous-batching engine backend, and submits two competing
agents (an elephant and a mouse) as backend-agnostic ``AgentSpec``s.  The
service resolves the scheduler by registry name, streams lifecycle events
(admissions, per-token generation, completions) to the agent handles, and
shows selective pampering in action: the mouse (earlier GPS virtual finish)
completes long before the elephant even though it was submitted second.
Swap ``AgentService.engine(...)`` for ``AgentService.sim(...)`` to run the
same two specs on the discrete-event cluster.
"""

import jax

from repro.api import AgentService, AgentSpec
from repro.configs import get_config
from repro.core import InferenceSpec
from repro.models import Model

VOCAB = 256


def make_spec(n_inferences, prompt_len, decode_len, name):
    return AgentSpec(
        stages=[[InferenceSpec(prompt_len, decode_len)] * n_inferences],
        name=name,
    )


def main():
    cfg = get_config("granite-3-2b").reduced(vocab=VOCAB)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    service = AgentService.engine(
        model, params, "justitia",
        pool_tokens=512, block_size=16, max_batch=2, cache_len=256,
    )
    elephant = service.submit(
        make_spec(6, prompt_len=100, decode_len=100, name="elephant")
    )
    mouse = service.submit(
        make_spec(1, prompt_len=16, decode_len=8, name="mouse")
    )

    result = service.drain()
    print("agent completion iterations:", result.finish)
    print("mouse generated tokens:", mouse.tokens)
    print("engine metrics:", result.metrics)
    assert mouse.finish < elephant.finish, "mouse should finish first"
    print("OK: the mouse was pampered past the elephant "
          "(earlier GPS virtual finish time)")


if __name__ == "__main__":
    main()
