"""Train a ~small LM for a few hundred steps on the synthetic pipeline.

    PYTHONPATH=src python examples/train_small.py [--steps 200] [--arch granite-3-2b]

Uses the same Model/train_step/AdamW/data/checkpoint substrate as the
multi-pod dry-run, at a CPU-friendly scale.  Loss should fall well below
the uniform baseline ln(vocab).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.training import (
    AdamWConfig,
    DataConfig,
    data_iterator,
    init_adamw,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt.msgpack")
    args = ap.parse_args()

    vocab = 512
    cfg = get_config(args.arch).reduced(
        vocab=vocab, n_layers=2, d_model=256, d_ff=512, n_heads=4,
        n_kv_heads=2, head_dim=64,
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=3e-3, warmup_steps=20,
                           total_steps=args.steps, weight_decay=0.01)
    ))
    data = data_iterator(DataConfig(vocab=vocab, seq_len=128,
                                    global_batch=8, order=1,
                                    temperature=0.25))
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(metrics['loss']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    uniform = float(np.log(vocab))
    final = float(metrics["loss"])
    print(f"final loss {final:.3f} vs uniform {uniform:.3f}")
    save_checkpoint(args.ckpt, {"params": params}, step=args.steps)
    restored, st = restore_checkpoint(args.ckpt, {"params": params})
    print(f"checkpoint round-trip OK at step {st} -> {args.ckpt}")


if __name__ == "__main__":
    main()
