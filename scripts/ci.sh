#!/usr/bin/env bash
# CI entry: tier-1 test suite + a short CPU smoke of the serving launcher
# on BOTH backends of the unified AgentService API.
#
#   scripts/ci.sh            # full tier-1 + smokes
#   scripts/ci.sh --smoke    # smokes only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# ~30s CPU smoke first: the same workload spec through both backends.
# (Runs before tier-1 so a pre-existing test failure — the container has
# known Pallas-on-CPU gaps in tests/test_kernels.py — cannot mask a broken
# serving path.)
echo "== smoke: repro.launch.serve --backend sim =="
python -m repro.launch.serve --backend sim --n-agents 4 --window-s 10

echo "== smoke: repro.launch.serve --backend engine =="
python -m repro.launch.serve --backend engine --n-agents 3 --window-s 10 \
    --pool-tokens 2048 --max-batch 2

if [[ "${1:-}" != "--smoke" ]]; then
    echo "== tier-1: pytest =="
    python -m pytest -x -q
fi

echo "CI OK"
