#!/usr/bin/env bash
# CI entry, four stages over the unified AgentService API:
#
#   1. smokes   — the serving launcher on BOTH backends, single and
#                 multi-replica (ReplicatedBackend + router), ~40s CPU;
#   2. tier-1   — the cross-backend event-conformance suite first (its
#                 own named gate: the lifecycle-grammar contract every
#                 backend must satisfy), then the default pytest tier
#                 (slow-marked kernel/model-zoo/training sweeps are
#                 deselected via addopts; the full tier re-runs the
#                 conformance file — cheap, and -x keeps one red gate
#                 from hiding behind another);
#   3. perf     — `benchmarks/perf.py --quick` (sim core),
#                 `benchmarks/perf_engine.py --quick` (engine hot path),
#                 and `benchmarks/perf_cache.py --quick` (prefix-cache
#                 fairness-vs-hit-rate): each first PROVES the optimized
#                 core behaviour-identical to its retained pre-rewrite
#                 oracle on seeded workloads (the cache bench proves the
#                 cache-OFF engine bit-identical, then gates saved>0,
#                 allocator invariants, and the locality_fair-vs-justitia
#                 hit/delay claim in-band), plus
#                 `benchmarks/perf_slo.py --quick` (fused-off oracle +
#                 SLO latency), `benchmarks/perf_faults.py --quick`
#                 (fault-off oracle, deterministic crash failover,
#                 under-budget stall inertness, watermark swap-cut), and
#                 `benchmarks/perf_suspend.py --quick` (suspend-off
#                 oracle, think-time KV retention hold/spill/drop,
#                 graceful hold->spill escalation), and
#                 `benchmarks/perf_fleet.py --quick` (concurrent-vs-
#                 sequential bit-identity gate, device-overlap speedup,
#                 streaming constant-memory scale): each records its
#                 BENCH_*_quick.json; `benchmarks/trend.py` renders
#                 every BENCH artifact into TREND.md (all uploaded in CI);
#                 tier-1 additionally re-runs the concurrency suites
#                 under PYTHONDEVMODE=1 + faulthandler (thread-safety);
#   4. slow     — `pytest -m slow`: the full kernel/model/training sweeps.
#                 Run as its own stage so a Pallas-on-CPU container gap
#                 cannot mask a broken scheduler/serving path.
#
#   scripts/ci.sh            # smokes + tier-1 + perf (the gating stages)
#   scripts/ci.sh --smoke    # smokes only
#   scripts/ci.sh --slow     # all four stages.  NB: on CPU-only
#                            # containers the slow tier carries the known
#                            # Pallas kernel failures, so this exits red
#                            # there by design — it needs an accelerator.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# Smokes first: a pre-existing test failure must not mask a broken
# serving path.
echo "== smoke: repro.launch.serve --backend sim =="
python -m repro.launch.serve --backend sim --n-agents 4 --window-s 10

echo "== smoke: repro.launch.serve --backend sim --replicas 3 =="
python -m repro.launch.serve --backend sim --n-agents 6 --window-s 10 \
    --replicas 3 --router memory_cost_aware

echo "== smoke: repro.launch.serve --backend engine =="
python -m repro.launch.serve --backend engine --n-agents 3 --window-s 10 \
    --pool-tokens 2048 --max-batch 2

echo "== smoke: repro.launch.serve --backend engine --replicas 2 =="
python -m repro.launch.serve --backend engine --n-agents 4 --window-s 10 \
    --pool-tokens 1024 --max-batch 2 --replicas 2 --router round_robin

if [[ "${1:-}" == "--smoke" ]]; then
    echo "CI OK (smokes)"
    exit 0
fi

echo "== tier-1 gate: cross-backend event conformance =="
python -m pytest -x -q tests/test_event_conformance.py

echo "== tier-1: pytest (slow tier deselected) =="
python -m pytest -x -q

# Re-run the concurrency-sensitive suites in dev mode: PYTHONDEVMODE
# surfaces unjoined threads / unclosed resources and faulthandler dumps
# every thread on a hang — the concurrent fleet drive (fleet_workers)
# must stay clean under both.
echo "== tier-1 thread-safety: concurrency suites under PYTHONDEVMODE =="
PYTHONDEVMODE=1 PYTHONFAULTHANDLER=1 python -m pytest -x -q \
    tests/test_fleet_concurrent.py tests/test_faults.py \
    tests/test_suspend.py

echo "== perf: benchmarks/perf.py --quick (oracle + 1k sim-core bench) =="
# separate output paths: the committed BENCH_sim.json / BENCH_engine.json
# are the FULL-tier records (acceptance numbers) and must not be
# overwritten by the quick stage
python -m benchmarks.perf --quick --out BENCH_sim_quick.json

echo "== perf: benchmarks/perf_engine.py --quick (engine oracle + hot-path bench) =="
python -m benchmarks.perf_engine --quick --out BENCH_engine_quick.json

echo "== perf: benchmarks/perf_cache.py --quick (cache-off oracle + prefix-cache bench) =="
python -m benchmarks.perf_cache --quick --out BENCH_cache_quick.json

echo "== perf: benchmarks/perf_slo.py --quick (fused-off oracle + SLO latency bench) =="
python -m benchmarks.perf_slo --quick --out BENCH_slo_quick.json

echo "== perf: benchmarks/perf_faults.py --quick (fault-off oracle + failover/watermark bench) =="
python -m benchmarks.perf_faults --quick --out BENCH_faults_quick.json

echo "== perf: benchmarks/perf_suspend.py --quick (suspend-off oracle + think-time retention bench) =="
python -m benchmarks.perf_suspend --quick --out BENCH_suspend_quick.json

echo "== perf: benchmarks/perf_fleet.py --quick (concurrent-fleet identity + overlap/streaming bench) =="
python -m benchmarks.perf_fleet --quick --out BENCH_fleet_quick.json

echo "== perf: benchmarks/trend.py -> TREND.md =="
python -m benchmarks.trend --out TREND.md > /dev/null

if [[ "${1:-}" == "--slow" ]]; then
    echo "== slow tier: pytest -m slow =="
    python -m pytest -q -m slow
fi

echo "CI OK"
