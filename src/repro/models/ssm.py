"""State-space and recurrent sequence mixers: Mamba2 (SSD) and xLSTM blocks.

TPU adaptation notes (DESIGN.md §3): these are implemented with
``jax.lax.scan`` over the sequence (training/prefill) and an O(1) functional
state update (decode).  The mLSTM additionally has the *parallel* quadratic
form used for training — mathematically equivalent to its recurrence and
MXU-friendly (it is a decay-masked attention), matching how the xLSTM paper
trains on accelerators.

State layouts (per layer):
  mamba2:  h: (B, H, P, N)   conv: (B, W-1, d_conv_channels)
  mlstm:   C: (B, H, hd, hd)  n: (B, H, hd)  m: (B, H)
  slstm:   c,n,h: (B, H, hd)  m: (B, H)
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.shardlib import shard

# ------------------------------------------------------------------- mamba2


def mamba2_dims(d_model: int, d_state: int):
    d_inner = 2 * d_model
    p = 64                       # head dim (Mamba2 default)
    h = d_inner // p             # ssm heads
    return d_inner, p, h, d_state


def init_mamba2(key, d_model: int, d_state: int, conv_width: int, dtype):
    d_inner, p, h, n = mamba2_dims(d_model, d_state)
    ks = jax.random.split(key, 6)
    scale = d_model ** -0.5
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": (jax.random.normal(ks[0], (d_model, 2 * d_inner + 2 * n + h))
                 * scale).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_width, d_inner + 2 * n))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner + 2 * n,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "w_out": (jax.random.normal(ks[2], (d_inner, d_model))
                  * d_inner ** -0.5).astype(dtype),
        "norm_w": jnp.ones((d_inner,), jnp.float32),
    }


def _mamba2_project(p, x, conv_state=None):
    """Shared projection+conv for train/prefill/decode.

    x: (B, S, D).  Returns z, xs, bv, cv, dt and the new conv state.
    """
    d_model = x.shape[-1]
    d_inner = 2 * d_model
    h = p["a_log"].shape[0]
    n = (p["w_in"].shape[1] - 2 * d_inner - h) // 2

    zxbc = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z = zxbc[..., :d_inner]
    xbc = zxbc[..., d_inner : d_inner + d_inner + 2 * n]
    dt = zxbc[..., -h:]

    w = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], w - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state
    xbc_pad = jnp.concatenate([pad, xbc], axis=1)
    new_conv_state = xbc_pad[:, -(w - 1):, :]
    # causal depthwise conv via stacked shifts (w is small, 4)
    conv = sum(
        xbc_pad[:, i : i + xbc.shape[1], :] * p["conv_w"][i]
        for i in range(w)
    ) + p["conv_b"]
    conv = jax.nn.silu(conv)
    xs = conv[..., :d_inner]
    bv = conv[..., d_inner : d_inner + n]
    cv = conv[..., d_inner + n :]
    return z, xs, bv, cv, dt, new_conv_state


def mamba2_forward(p, x, state=None, conv_state=None):
    """Full-sequence form. x: (B,S,D) -> (y, (ssm_state, conv_state))."""
    b, s, d_model = x.shape
    h = p["a_log"].shape[0]
    pdim = (2 * d_model) // h

    z, xs, bv, cv, dt, new_conv = _mamba2_project(p, x, conv_state)
    xs = xs.reshape(b, s, h, pdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,)
    decay = jnp.exp(a * dt)   # (B,S,H)

    n = bv.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, pdim, n), jnp.float32)

    def step(carry, inp):
        hst = carry
        x_t, b_t, c_t, dt_t, dec_t = inp
        # outer product update: h = dec*h + dt * x ⊗ B
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
        hst = hst * dec_t[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bn->bhp", hst, c_t)
        return hst, y_t

    xs_t = jnp.moveaxis(xs.astype(jnp.float32), 1, 0)        # (S,B,H,P)
    bv_t = jnp.moveaxis(bv.astype(jnp.float32), 1, 0)        # (S,B,N)
    cv_t = jnp.moveaxis(cv.astype(jnp.float32), 1, 0)
    dt_t = jnp.moveaxis(dt, 1, 0)                            # (S,B,H)
    dec_t = jnp.moveaxis(decay, 1, 0)
    state, ys = jax.lax.scan(step, state, (xs_t, bv_t, cv_t, dt_t, dec_t))
    y = jnp.moveaxis(ys, 0, 1)                               # (B,S,H,P)
    y = y + xs.astype(jnp.float32) * p["d_skip"][..., None]
    y = y.reshape(b, s, 2 * d_model).astype(x.dtype)
    # gated RMSNorm (Mamba2 style)
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y32 = y32 * jax.lax.rsqrt(
        jnp.mean(y32 * y32, axis=-1, keepdims=True) + 1e-5
    ) * p["norm_w"]
    out = jnp.einsum("bse,ed->bsd", y32.astype(x.dtype), p["w_out"])
    return shard(out, "batch", "seq", "embed"), (state, new_conv)


def mamba2_forward_chunked(p, x, state=None, conv_state=None,
                           chunk: int = 512):
    """Chunkwise SSD form (Mamba2 paper §6): O(L*chunk) memory, quadratic
    only within a chunk, exact same math as the per-step recurrence.

    Per head (scalar decay a, per-step dt): with lam_t = exp(a*dt_t),
    cum_t = sum_{j<=t} log lam_j (<= 0, so every exp below is stable):

      y_t   = Lam_t (C_t . H_0) + sum_{j<=t} e^{cum_t-cum_j} (C_t.B_j) u_j
      H_out = Lam_L H_0 + sum_j e^{cum_L-cum_j} u_j (x) B_j

    The per-step scan form (``mamba2_forward``) is kept as the oracle and
    decode path; backward through THIS form only stores per-chunk boundary
    states (the BPTT residuals of the step form — one (B,H,P,N) state per
    token — cannot fit HBM at 4k).
    """
    b, s, d_model = x.shape
    h = p["a_log"].shape[0]
    pdim = (2 * d_model) // h

    z, xs, bv, cv, dt, new_conv = _mamba2_project(p, x, conv_state)
    xs = xs.reshape(b, s, h, pdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["a_log"])
    log_lam = a * dt                                              # (B,S,H) <=0

    n = bv.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, pdim, n), jnp.float32)

    c = s // max(1, s // min(chunk, s))
    while s % c:
        c += 1
    nc = s // c

    u = (xs.astype(jnp.float32) * dt[..., None])                  # (B,S,H,P)
    ug = jnp.moveaxis(u.reshape(b, nc, c, h, pdim), 1, 0)
    bg = jnp.moveaxis(bv.astype(jnp.float32).reshape(b, nc, c, n), 1, 0)
    cg = jnp.moveaxis(cv.astype(jnp.float32).reshape(b, nc, c, n), 1, 0)
    lg = jnp.moveaxis(log_lam.reshape(b, nc, c, h), 1, 0)

    @jax.checkpoint
    def one_chunk(hst, inp):
        u_c, b_c, c_c, l_c = inp
        cum = jnp.cumsum(l_c, axis=1)                             # (B,c,H)
        lam = jnp.exp(cum)
        # intra-chunk decay-weighted "attention": (B,H,c,c).  The exponent
        # is positive (-> inf) in the masked upper triangle; clamp it with
        # a where BEFORE exp or the backward pass turns 0*inf into NaN.
        expo = cum[:, :, None, :] - cum[:, None, :, :]            # t,j
        causal = jnp.tril(jnp.ones((c, c), bool))[None, :, :, None]
        ratio = jnp.exp(jnp.where(causal, expo, 0.0))
        cb = jnp.einsum("btn,bjn->btj", c_c, b_c)                 # (B,c,c)
        g = jnp.where(causal, cb[..., None] * ratio, 0.0)
        y_intra = jnp.einsum("btjh,bjhp->bthp", g, u_c)
        y_inter = lam[..., None] * jnp.einsum("btn,bhpn->bthp", c_c, hst)
        # chunk-final state
        wj = jnp.exp(cum[:, -1:, :] - cum)                        # (B,c,H)
        upd = jnp.einsum("bjhp,bjn,bjh->bhpn", u_c, b_c, wj)
        hst = hst * jnp.exp(cum[:, -1])[..., None, None] + upd
        return hst, y_intra + y_inter

    state, ys = jax.lax.scan(one_chunk, state, (ug, bg, cg, lg))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, pdim)
    y = y + xs.astype(jnp.float32) * p["d_skip"][..., None]
    y = y.reshape(b, s, 2 * d_model).astype(x.dtype)
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y32 = y32 * jax.lax.rsqrt(
        jnp.mean(y32 * y32, axis=-1, keepdims=True) + 1e-5
    ) * p["norm_w"]
    out = jnp.einsum("bse,ed->bsd", y32.astype(x.dtype), p["w_out"])
    return shard(out, "batch", "seq", "embed"), (state, new_conv)


def mamba2_decode(p, x1, state, conv_state):
    """One-token decode. x1: (B,1,D)."""
    return mamba2_forward(p, x1, state=state, conv_state=conv_state)


def mamba2_init_state(p, batch: int, d_model: int):
    h = p["a_log"].shape[0]
    pdim = (2 * d_model) // h
    n = (p["w_in"].shape[1] - 4 * d_model - h) // 2
    w = p["conv_w"].shape[0]
    return (
        jnp.zeros((batch, h, pdim, n), jnp.float32),
        jnp.zeros((batch, w - 1, 2 * d_model + 2 * n), p["conv_w"].dtype),
    )


# -------------------------------------------------------------------- mlstm


def init_mlstm(key, d_model: int, n_heads: int, head_dim: int, dtype):
    ks = jax.random.split(key, 6)
    scale = d_model ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads, head_dim)) * scale
               ).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_heads, head_dim)) * scale
               ).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_heads, head_dim)) * scale
               ).astype(dtype),
        "w_if": (jax.random.normal(ks[3], (d_model, n_heads, 2)) * scale
                 ).astype(jnp.float32),
        "b_if": jnp.array([[0.0, 3.0]] * n_heads, jnp.float32),  # forget open
        "wo": (jax.random.normal(ks[4], (n_heads, head_dim, d_model))
               * (n_heads * head_dim) ** -0.5).astype(dtype),
        "norm_w": jnp.ones((n_heads, head_dim), jnp.float32),
    }


def _mlstm_gates(p, x):
    g = jnp.einsum("bsd,dhg->bshg", x.astype(jnp.float32), p["w_if"]) + p["b_if"]
    i_raw = g[..., 0]                                  # (B,S,H)
    log_f = -jax.nn.softplus(-g[..., 1])               # log sigmoid
    return i_raw, log_f


def mlstm_parallel(p, x):
    """Parallel (training/prefill) form: decay-masked attention.

    h_i = sum_{j<=i} exp(D_ij - m_i) (q_i.k_j/sqrt(d)) v_j / n_i
    D_ij = cumsum(log_f)_i - cumsum(log_f)_j + i_raw_j
    """
    b, s, d_model = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "heads", "head_dim")
    v = shard(v, "batch", "seq", "heads", "head_dim")
    hd = q.shape[-1]
    i_raw, log_f = _mlstm_gates(p, x)
    fcum = jnp.cumsum(log_f, axis=1)                   # (B,S,H)
    dmat = fcum[:, :, None, :] - fcum[:, None, :, :] + i_raw[:, None, :, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)  # (B,S,S,H)
    m = jnp.max(dmat, axis=2, keepdims=True)           # (B,S,1,H)
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bshk,bthk->bsth", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd) * dexp
    norm = jnp.maximum(
        jnp.abs(scores.sum(axis=2)), jnp.exp(-m[:, :, 0, :])
    )                                                   # (B,S,H)
    hvec = jnp.einsum("bsth,bthk->bshk", scores.astype(x.dtype), v)
    hvec = hvec / norm[..., None].astype(x.dtype)
    hvec = rms_head_norm(hvec, p["norm_w"])
    out = jnp.einsum("bshk,hkd->bsd", hvec, p["wo"])
    return shard(out, "batch", "seq", "embed")


def rms_head_norm(h, w):
    h32 = h.astype(jnp.float32)
    y = h32 * jax.lax.rsqrt(jnp.mean(h32 * h32, axis=-1, keepdims=True) + 1e-5)
    return (y * w).astype(h.dtype)


def mlstm_forward(p, x, state=None):
    """Recurrent full-sequence form: lax.scan of the stabilized step.

    Linear in S with O(H * hd^2) state — the form used for long sequences
    (training at 4k and prefill at 32k+); ``mlstm_parallel`` is its
    quadratic-memory equivalent kept for short sequences and as the oracle
    in the equivalence property test.
    Returns (y (B,S,D), final_state).
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    hd = q.shape[-1]
    i_raw, log_f = _mlstm_gates(p, x)
    if state is None:
        state = mlstm_init_state(p, b)

    def step(carry, inp):
        c, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inp
        m_new = jnp.maximum(f_t + m, i_t)
        alpha = jnp.exp(f_t + m - m_new)
        beta = jnp.exp(i_t - m_new)
        kf = k_t.astype(jnp.float32) / math.sqrt(hd)
        c = c * alpha[..., None, None] + beta[..., None, None] * jnp.einsum(
            "bhk,bhv->bhkv", kf, v_t.astype(jnp.float32)
        )
        n = n * alpha[..., None] + beta[..., None] * kf
        num = jnp.einsum("bhk,bhkv->bhv", q_t.astype(jnp.float32), c)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", q_t.astype(jnp.float32), n)),
            jnp.exp(-m_new),
        )
        return (c, n, m_new), (num / den[..., None])

    mv = lambda a: jnp.moveaxis(a, 1, 0)
    state, hs = jax.lax.scan(
        step, state, (mv(q), mv(k), mv(v), mv(i_raw), mv(log_f))
    )
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    h = rms_head_norm(h, p["norm_w"])
    out = jnp.einsum("bshk,hkd->bsd", h, p["wo"])
    return shard(out, "batch", "seq", "embed"), state


def mlstm_forward_chunked(p, x, state=None, chunk: int = 256):
    """Chunkwise-parallel mLSTM (the xLSTM training form): quadratic only
    within a chunk, recurrent state handed across chunks; exactly equal to
    the per-step recurrence (``mlstm_forward``) but BPTT-feasible — the
    step form would store a (B,H,hd,hd) matrix state per TOKEN in backward.

    Stabilized like the paper's App. formulas: with F_t = cumsum(log f),
    D_tj = F_t - F_j + i_j (j<=t), m_t = max(F_t + m0, max_j D_tj):

      num_t = e^{F_t+m0-m_t} (q_t.C0) + sum_j e^{D_tj-m_t} (q_t.k_j/√d) v_j
      den_t = max(|e^{F_t+m0-m_t} (q_t.n0) + sum_j e^{D_tj-m_t} (q_t.k_j/√d)|,
                  e^{-m_t})
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    hd = q.shape[-1]
    i_raw, log_f = _mlstm_gates(p, x)
    if state is None:
        state = mlstm_init_state(p, b)

    c = s // max(1, s // min(chunk, s))
    while s % c:
        c += 1
    nc = s // c
    mv = lambda a: jnp.moveaxis(
        a.reshape(b, nc, c, *a.shape[2:]), 1, 0
    )
    # only k carries the 1/sqrt(d) scale (matching the recurrent form,
    # where C accumulates k/sqrt(d) (x) v and q contracts unscaled)
    qg, kg, vg = mv(q.astype(jnp.float32)), \
        mv(k.astype(jnp.float32) / math.sqrt(hd)), mv(v.astype(jnp.float32))
    ig, fg = mv(i_raw), mv(log_f)

    @jax.checkpoint
    def one_chunk(carry, inp):
        c0, n0, m0 = carry
        q_c, k_c, v_c, i_c, f_c = inp       # (B,c,H,hd) / (B,c,H)
        fcum = jnp.cumsum(f_c, axis=1)      # F_t
        d = fcum[:, :, None, :] - fcum[:, None, :, :] + i_c[:, None, :, :]
        causal = jnp.tril(jnp.ones((c, c), bool))
        d = jnp.where(causal[None, :, :, None], d, -jnp.inf)  # (B,t,j,H)
        m_intra = jnp.max(d, axis=2)                          # (B,t,H)
        m_inter = fcum + m0[:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)
        w = jnp.exp(d - m_t[:, :, None, :])                   # (B,t,j,H)
        inter = jnp.exp(m_inter - m_t)                        # (B,t,H)

        qk = jnp.einsum("bthk,bjhk->btjh", q_c, k_c)
        num = jnp.einsum("btjh,btjh,bjhk->bthk", qk, w, v_c) + inter[
            ..., None
        ] * jnp.einsum("bthk,bhkv->bthv", q_c, c0)
        den_sum = jnp.einsum("btjh,btjh->bth", qk, w) + inter * jnp.einsum(
            "bthk,bhk->bth", q_c, n0
        )
        den = jnp.maximum(jnp.abs(den_sum), jnp.exp(-m_t))
        h_c = num / den[..., None]

        # chunk-final state (t = L)
        m_new = m_t[:, -1]
        wj = jnp.exp(fcum[:, -1:, :] - fcum + i_c - m_new[:, None, :])
        c_new = jnp.exp(m_inter[:, -1] - m_new)[..., None, None] * c0 + \
            jnp.einsum("bjh,bjhk,bjhv->bhkv", wj, k_c, v_c)
        n_new = jnp.exp(m_inter[:, -1] - m_new)[..., None] * n0 + \
            jnp.einsum("bjh,bjhk->bhk", wj, k_c)
        return (c_new, n_new, m_new), h_c

    state, hs = jax.lax.scan(one_chunk, state, (qg, kg, vg, ig, fg))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, *hs.shape[3:]).astype(x.dtype)
    h = rms_head_norm(h, p["norm_w"])
    out = jnp.einsum("bshk,hkd->bsd", h, p["wo"])
    return shard(out, "batch", "seq", "embed"), state


def mlstm_init_state(p, batch: int):
    n_heads, hd = p["norm_w"].shape
    return (
        jnp.zeros((batch, n_heads, hd, hd), jnp.float32),  # C
        jnp.zeros((batch, n_heads, hd), jnp.float32),      # n
        jnp.full((batch, n_heads), -1e30, jnp.float32),    # m (running max)
    )


def mlstm_decode(p, x1, state):
    """One-token recurrent step.  x1: (B,1,D)."""
    c, n, m = state
    b = x1.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x1, p["wq"])[:, 0]
    k = jnp.einsum("bsd,dhk->bshk", x1, p["wk"])[:, 0]
    v = jnp.einsum("bsd,dhk->bshk", x1, p["wv"])[:, 0]
    hd = q.shape[-1]
    i_raw, log_f = _mlstm_gates(p, x1)
    i_raw, log_f = i_raw[:, 0], log_f[:, 0]            # (B,H)
    m_new = jnp.maximum(log_f + m, i_raw)
    alpha = jnp.exp(log_f + m - m_new)
    beta = jnp.exp(i_raw - m_new)
    kf = k.astype(jnp.float32) / math.sqrt(hd)
    c = c * alpha[..., None, None] + beta[..., None, None] * jnp.einsum(
        "bhk,bhv->bhkv", kf, v.astype(jnp.float32)
    )
    n = n * alpha[..., None] + beta[..., None] * kf
    num = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), c)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n)),
        jnp.exp(-m_new),
    )
    hvec = (num / den[..., None]).astype(x1.dtype)
    hvec = rms_head_norm(hvec, p["norm_w"])
    out = jnp.einsum("bhk,hkd->bd", hvec, p["wo"])[:, None, :]
    return out, (c, n, m_new)


# -------------------------------------------------------------------- slstm


def init_slstm(key, d_model: int, n_heads: int, head_dim: int, dtype):
    ks = jax.random.split(key, 4)
    scale = d_model ** -0.5
    return {
        # fused z,i,f,o input projections: (D, H, hd, 4)
        "w_in": (jax.random.normal(ks[0], (d_model, n_heads, head_dim, 4))
                 * scale).astype(dtype),
        # recurrent per-head projections (block-diagonal R): (H, hd, hd, 4)
        "r": (jax.random.normal(ks[1], (n_heads, head_dim, head_dim, 4))
              * head_dim ** -0.5).astype(jnp.float32),
        "b": jnp.zeros((n_heads, head_dim, 4), jnp.float32),
        "wo": (jax.random.normal(ks[2], (n_heads, head_dim, d_model))
               * (n_heads * head_dim) ** -0.5).astype(dtype),
        "norm_w": jnp.ones((n_heads, head_dim), jnp.float32),
    }


def slstm_init_state(p, batch: int):
    n_heads, hd = p["norm_w"].shape
    z = jnp.zeros((batch, n_heads, hd), jnp.float32)
    return (z, z, z, jnp.full((batch, n_heads, hd), -1e30, jnp.float32))


def _slstm_step(p, carry, u_t):
    """u_t: (B,H,hd,4) pre-activations from the input projection."""
    c, n, h_prev, m = carry
    rec = jnp.einsum("bhk,hkjg->bhjg", h_prev, p["r"])
    pre = u_t + rec + p["b"]
    z = jnp.tanh(pre[..., 0])
    i_raw = pre[..., 1]
    log_f = -jax.nn.softplus(-pre[..., 2])             # sigmoid forget
    o = jax.nn.sigmoid(pre[..., 3])
    m_new = jnp.maximum(log_f + m, i_raw)
    alpha = jnp.exp(log_f + m - m_new)
    beta = jnp.exp(i_raw - m_new)
    c = alpha * c + beta * z
    n = alpha * n + beta
    h = o * c / jnp.maximum(n, 1e-6)
    return (c, n, h, m_new), h


def slstm_forward(p, x, state=None):
    """x: (B,S,D) -> (y, state); lax.scan over the sequence."""
    b, s, _ = x.shape
    u = jnp.einsum("bsd,dhkg->bshkg", x.astype(jnp.float32),
                   p["w_in"].astype(jnp.float32))
    if state is None:
        state = slstm_init_state(p, b)
    u_t = jnp.moveaxis(u, 1, 0)                        # (S,B,H,hd,4)
    state, hs = jax.lax.scan(
        lambda cr, ut: _slstm_step(p, cr, ut), state, u_t
    )
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)         # (B,S,H,hd)
    h = rms_head_norm(h, p["norm_w"])
    out = jnp.einsum("bshk,hkd->bsd", h, p["wo"])
    return shard(out, "batch", "seq", "embed"), state


def slstm_decode(p, x1, state):
    y, state = slstm_forward(p, x1, state)
    return y, state
