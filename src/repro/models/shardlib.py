"""Logical-axis sharding annotations (MaxText-style, minimal).

Model code annotates activations with *logical* axis names; the launcher
installs a mesh and a logical->mesh rule table.  With no mesh installed
(unit tests, CPU smoke runs) every annotation is a no-op, so the same model
code serves single-host tests and the 512-chip dry-run.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: dict[str, object]):
    """rules: logical axis name -> mesh axis (str), tuple of axes, or None."""
    _current().append((mesh, dict(rules)))
    try:
        yield
    finally:
        _current().pop()


def active_rules() -> Optional[tuple[Mesh, dict]]:
    stack = _current()
    return stack[-1] if stack else None


def logical_to_spec(names: Sequence[Optional[str]], rules: dict) -> P:
    axes = []
    for n in names:
        if n is None:
            axes.append(None)
        else:
            axes.append(rules.get(n))
    return P(*axes)


def shard(x, *names: Optional[str]):
    """Annotate ``x`` whose dims carry the given logical names."""
    ctx = active_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim != len(names):
        raise ValueError(
            f"shard(): rank {x.ndim} array got {len(names)} logical names"
        )
    spec = logical_to_spec(names, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_spec(names: Sequence[Optional[str]]) -> P:
    """PartitionSpec for a parameter (used to build in_shardings trees)."""
    ctx = active_rules()
    if ctx is None:
        return P()
    _, rules = ctx
    return logical_to_spec(names, rules)
