"""Shared neural building blocks: RMSNorm, RoPE, GQA attention (full /
sliding-window / cached-decode), gated MLP, and capacity-based MoE.

All functions are pure; parameters are plain dict pytrees.  Activations are
annotated with logical sharding axes (see shardlib) so the same code runs
unsharded on CPU and pjit-sharded on the production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.shardlib import active_rules, shard

# --------------------------------------------------------------------- norm


def rms_norm(x, w, eps: float = 1e-5):
    """RMSNorm with the variance reduction in f32.

    Deliberately structured so the only f32 consumer of ``x`` is inside the
    (fused) square-mean reduction: an elementwise f32 copy of x would make
    XLA store the layer-scan residual stack in f32 — 2x the activation
    memory of the whole backward pass (measured: +21 GiB/device at the
    llama3.2-3b train_4k shape).  The scale is applied in the compute dtype.
    """
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    scale = (jax.lax.rsqrt(var + eps) * w).astype(x.dtype)  # (..., D)
    return x * scale


# --------------------------------------------------------------------- rope


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float):
    """x: (..., S, n, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention


def _qk_scale(head_dim: int) -> float:
    return head_dim ** -0.5


def gqa_attention(
    q,  # (B, S, nh, hd)
    k,  # (B, T, nkv, hd)
    v,  # (B, T, nkv, hd)
    *,
    causal_offset: Optional[int] = 0,
    window: int = 0,
    q_positions=None,   # (B, S) absolute positions of queries; default arange
    kv_valid=None,      # (B, T) bool mask of valid cache slots (decode)
    kv_positions=None,  # (B, T) absolute positions of cache slots (ring SWA)
):
    """Grouped-query attention with optional causal/sliding-window masking.

    Training/prefill: T == S, causal mask, window applied if nonzero.
    Decode: S == 1, ``kv_valid``/``kv_positions`` describe the cache.
    """
    b, s, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    qpk = nh // nkv
    qg = q.reshape(b, s, nkv, qpk, hd)

    logits = jnp.einsum(
        "bsngh,btnh->bngst", qg, k, preferred_element_type=jnp.float32
    ) * _qk_scale(hd)  # (B, nkv, qpk, S, T)

    if q_positions is None:
        q_pos = jnp.arange(s)[None, :] + (causal_offset or 0)
        q_pos = jnp.broadcast_to(q_pos, (b, s))
    else:
        q_pos = q_positions
    if kv_positions is None:
        k_pos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
    else:
        k_pos = kv_positions

    mask = k_pos[:, None, :] <= q_pos[:, :, None]  # (B, S, T) causal
    if window:
        mask &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)

    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v)
    return out.reshape(b, s, nh, hd)


def chunked_gqa_attention(
    q, k, v,
    *,
    window: int = 0,
    q_positions=None,
    kv_positions=None,
    kv_valid=None,
    chunk_q: int = 512,
    chunk_k: int = 1024,
):
    """Flash-style chunked attention in pure JAX (lax.scan online softmax).

    Same semantics as ``gqa_attention`` but with O(S*chunk) memory instead
    of O(S*T): mandatory for the 4k-train / 32k-prefill shapes, where the
    full (B, H, S, T) logits tensor would not fit HBM.  On TPU the Pallas
    ``flash_prefill`` kernel replaces this; this is the shardable jnp form
    the dry-run lowers (XLA keeps the scan as a while loop, so HLO size and
    live memory stay bounded).
    """
    b, s, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    qpk = nh // nkv
    # context parallelism (§Perf O4): when the launcher maps the logical
    # "q_chunks" axis to a mesh axis, q chunks are computed as a vmapped
    # batch (shardable) instead of a sequential scan, and nq is forced to
    # a multiple of that axis degree.  This is the attention sharding for
    # archs whose head count does not divide the model axis (llama3.2 24H,
    # llava 56H, starcoder2 36H, whisper 6H): logits stay device-local,
    # only the (B,S,nh,hd) output is re-gathered once per layer.
    cp_degree = 0
    ctx = active_rules()
    if ctx is not None:
        mesh, rules = ctx
        ax = rules.get("q_chunks")
        if ax is not None:
            cp_degree = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                cp_degree *= mesh.shape[a]
    # snap chunk sizes to divisors of s/t (e.g. whisper's 1500-frame
    # encoder output): s // (s // c) is the smallest divisor-chunk >= c
    cq = s // max(1, s // min(chunk_q, s))
    ck = t // max(1, t // min(chunk_k, t))
    while s % cq:
        cq += 1
    while t % ck:
        ck += 1
    nq, nk = s // cq, t // ck
    if cp_degree > 1:
        # force nq to a multiple of the context-parallel degree
        nq2 = ((max(nq, cp_degree) + cp_degree - 1) // cp_degree) * cp_degree
        while nq2 <= s and s % nq2:
            nq2 += cp_degree
        if nq2 <= s:
            nq = nq2
            cq = s // nq
        else:
            cp_degree = 0  # cannot split this length: fall back to scan

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    if kv_valid is None:
        kv_valid = jnp.ones((b, t), bool)

    qg = q.reshape(b, nq, cq, nkv, qpk, hd)
    kg = k.reshape(b, nk, ck, nkv, hd)
    vg = v.reshape(b, nk, ck, nkv, hd)
    # pin the scanned K/V layout HERE, outside the chunk loops: without this
    # SPMD re-gathers each (q,k) chunk pair inside the innermost loop when
    # the cache output layout differs from the attention layout (measured
    # 640 GiB of all-gather at dbrx prefill_32k — §Perf iteration 2)
    kg = shard(kg, "batch", None, None, "kv_heads", "head_dim")
    vg = shard(vg, "batch", None, None, "kv_heads", "head_dim")
    qp = q_positions.reshape(b, nq, cq)
    kp = kv_positions.reshape(b, nk, ck)
    kva = kv_valid.reshape(b, nk, ck)
    scale = _qk_scale(hd)

    def one_q_chunk(carry, qs):
        q_c, qp_c = qs          # (B,cq,nkv,qpk,hd), (B,cq)

        @jax.checkpoint
        def one_k_chunk(acc, ks):
            m, l, o = acc
            k_c, v_c, kp_c, kva_c = ks
            s_ = jnp.einsum(
                "bqngh,bknh->bngqk", q_c, k_c,
                preferred_element_type=jnp.float32,
            ) * scale                               # (B,nkv,qpk,cq,ck)
            msk = (kp_c[:, None, :] <= qp_c[:, :, None]) & kva_c[:, None, :]
            if window:
                msk &= kp_c[:, None, :] > (qp_c[:, :, None] - window)
            s_ = jnp.where(msk[:, None, None], s_, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
            safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - safe), 0.0)
            p = jnp.where(jnp.isfinite(s_), jnp.exp(s_ - safe[..., None]), 0.0)
            l = alpha * l + jnp.sum(p, axis=-1)
            o = alpha[..., None] * o + jnp.einsum(
                "bngqk,bknh->bngqh", p.astype(v_c.dtype), v_c
            ).astype(jnp.float32)
            return (m_new, l, o), None

        init = (
            jnp.full((b, nkv, qpk, cq), -jnp.inf, jnp.float32),
            jnp.zeros((b, nkv, qpk, cq), jnp.float32),
            jnp.zeros((b, nkv, qpk, cq, hd), jnp.float32),
        )
        mv = lambda a: jnp.moveaxis(a, 1, 0)
        (m, l, o), _ = jax.lax.scan(
            one_k_chunk, init, (mv(kg), mv(vg), mv(kp), mv(kva))
        )
        out = o / jnp.maximum(l, 1e-30)[..., None]
        # (B,nkv,qpk,cq,hd) -> (B,cq,nh,hd)
        out = jnp.moveaxis(out, 3, 1).reshape(b, cq, nh, hd)
        return carry, out.astype(q.dtype)

    if cp_degree > 1:
        # context-parallel path: q chunks as a vmapped (shardable) batch
        qg = shard(qg, "batch", "q_chunks", None, None, None, None)
        qp_s = shard(qp, "batch", "q_chunks", None)

        def per_chunk(q_c, qp_c):
            _, out = one_q_chunk(None, (q_c, qp_c))
            return out

        outs = jax.vmap(per_chunk, in_axes=(1, 1), out_axes=1)(qg, qp_s)
        outs = shard(outs, "batch", "q_chunks", None, None, None)
        return outs.reshape(b, s, nh, hd)

    mvq = lambda a: jnp.moveaxis(a, 1, 0)
    _, outs = jax.lax.scan(one_q_chunk, None, (mvq(qg), mvq(qp)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, nh, hd)


def attention_any(
    q, k, v, *, window=0, q_positions=None, kv_positions=None,
    kv_valid=None, full_threshold: int = 2048,
):
    """Dispatch: full-matrix attention for small S*T, chunked otherwise."""
    s, t = q.shape[1], k.shape[1]
    if s * t <= full_threshold * full_threshold or s == 1:
        return gqa_attention(
            q, k, v, window=window, q_positions=q_positions,
            kv_positions=kv_positions, kv_valid=kv_valid,
        )
    return chunked_gqa_attention(
        q, k, v, window=window, q_positions=q_positions,
        kv_positions=kv_positions, kv_valid=kv_valid,
    )


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int


def init_attention(key, d_model: int, dims: AttnDims, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d_model ** -0.5
    nh, nkv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    return {
        "wq": (jax.random.normal(k1, (d_model, nh, hd)) * scale).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, nkv, hd)) * scale).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, nkv, hd)) * scale).astype(dtype),
        "wo": (jax.random.normal(k4, (nh, hd, d_model)) * scale).astype(dtype),
    }


def attention_qkv(p, x, positions, theta: float, use_rope: bool):
    """Project and (optionally) rotate. x: (B,S,D) -> q,k,v."""
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    ctxr = active_rules()
    if ctxr is not None and ctxr[1].get("head_dim_proj") is not None:
        # context-parallel mode (§Perf O4/iter.5): pin the PROJECTION
        # outputs head_dim-sharded first — otherwise SPMD replicates the
        # whole qkv matmul on every model shard (2.8x per-device FLOPs) —
        # then the plain annotations below insert one explicit activation
        # all-gather per layer at the attention boundary.
        q = shard(q, "batch", "seq", "heads", "head_dim_proj")
        k = shard(k, "batch", "seq", "kv_heads", "head_dim_proj")
        v = shard(v, "batch", "seq", "kv_heads", "head_dim_proj")
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    if use_rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attention_out(p, o):
    y = jnp.einsum("bsnh,nhd->bsd", o, p["wo"])
    return shard(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------- mlp


def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": (jax.random.normal(k1, (d_model, d_ff)) * d_model**-0.5).astype(dtype),
        "w3": (jax.random.normal(k2, (d_model, d_ff)) * d_model**-0.5).astype(dtype),
        "w2": (jax.random.normal(k3, (d_ff, d_model)) * d_ff**-0.5).astype(dtype),
    }


def gated_mlp(p, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"])) * jnp.einsum(
        "bsd,df->bsf", x, p["w3"]
    )
    h = shard(h, "batch", "seq", "ffn")
    y = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    return shard(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------- moe


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype):
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": (jax.random.normal(k0, (d_model, n_experts)) * d_model**-0.5
                   ).astype(jnp.float32),
        "w1": (jax.random.normal(k1, (n_experts, d_model, d_ff))
               * d_model**-0.5).astype(dtype),
        "w3": (jax.random.normal(k2, (n_experts, d_model, d_ff))
               * d_model**-0.5).astype(dtype),
        "w2": (jax.random.normal(k3, (n_experts, d_ff, d_model))
               * d_ff**-0.5).astype(dtype),
    }


def moe_mlp(p, x, *, top_k: int, capacity_factor: float = 1.25,
            group_size: int = 1024):
    """GShard-style capacity-based top-k MoE, GROUPED for long sequences.

    Tokens are processed in groups of <= ``group_size`` along the sequence
    (the GSPMD MoE trick): the dispatch one-hot is (B, G, g, E, C) with
    C = ceil(g * top_k / E * capacity_factor), so memory scales with the
    group, not the full sequence.  The dispatch/combine einsums lower to
    all-to-alls when experts are sharded over the 'model' mesh axis.
    Overflowing tokens fall through the residual (standard capacity drop).
    Returns (output, aux) where aux carries the load-balancing loss term.
    """
    b, s, d = x.shape
    e = p["router"].shape[-1]
    g = s // max(1, s // min(group_size, s))   # divisor-snapped group size
    while s % g:
        g += 1
    ng = s // g
    cap = max(1, int(g * top_k / e * capacity_factor))

    xg = x.reshape(b, ng, g, d)
    gate_logits = jnp.einsum(
        "bngd,de->bnge", xg.astype(jnp.float32), p["router"]
    )
    probs = jax.nn.softmax(gate_logits, axis=-1)        # (B,G,g,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)   # (B,G,g,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (B,G,g,K,E)
    flat = onehot.reshape(b, ng, g * top_k, e)
    pos_in_expert = jnp.cumsum(flat, axis=2) * flat - 1.0
    pos_in_expert = pos_in_expert.reshape(b, ng, g, top_k, e)
    fits = (pos_in_expert >= 0) & (pos_in_expert < cap)

    pos_clip = jnp.clip(pos_in_expert, 0, cap - 1).astype(jnp.int32)
    disp = (
        jax.nn.one_hot(pos_clip, cap, dtype=x.dtype)
        * (onehot * fits)[..., None].astype(x.dtype)
    ).sum(axis=3)                                        # (B,G,g,E,C)
    comb = (
        jax.nn.one_hot(pos_clip, cap, dtype=jnp.float32)
        * (onehot * fits * gate_vals[..., None]).astype(jnp.float32)[..., None]
    ).sum(axis=3).astype(x.dtype)

    xe = jnp.einsum("bngd,bngec->bnecd", xg, disp)       # (B,G,E,C,D)
    # expert-parallel archs shard E ("experts"->model, "ffn"->None);
    # few-expert archs shard F instead ("experts"->None, "ffn"->model) —
    # the rules guarantee the two never both map to "model".  Without the
    # "ffn" hint SPMD all-gathers the full F-sharded expert weights every
    # layer (measured 56 GiB/step at mixtral long_500k — §Perf iter. 3).
    xe = shard(xe, "batch", None, "experts", None, None)
    h = jax.nn.silu(jnp.einsum("bnecd,edf->bnecf", xe, p["w1"])) * jnp.einsum(
        "bnecd,edf->bnecf", xe, p["w3"]
    )
    h = shard(h, "batch", None, "experts", None, "ffn")
    ye = jnp.einsum("bnecf,efd->bnecd", h, p["w2"])
    y = jnp.einsum("bnecd,bngec->bngd", ye, comb)
    y = y.reshape(b, s, d)
    y = shard(y, "batch", "seq", "embed")

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(onehot.sum(3), axis=(0, 1, 2))   # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1, 2))            # (E,)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y, aux
