"""Unified model zoo: one functional Model class covering all assigned
architecture families (dense GQA / SWA, MoE, VLM decoder, audio enc-dec,
xLSTM, Mamba2+shared-attention hybrid).

Design choices for multi-pod dry-run sanity:
  * layers are STACKED and iterated with jax.lax.scan — the HLO contains one
    layer body regardless of depth, keeping 512-device SPMD compiles fast;
  * caches carry an explicit per-slot position tensor ``kv_pos`` (B, T);
    full caches and SWA ring buffers share one attention masking rule
    (valid = kv_pos >= 0, causal = kv_pos <= q_pos, window optional);
  * every architecture exposes the same three entry points:
      forward(params, batch)           -> logits            (training)
      prefill(params, batch, cache_len)-> (logits, cache)   (serving)
      decode(params, cache, tokens, pos)-> (logits, cache)  (serving)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.config import Block, ModelConfig
from repro.models.layers import (
    AttnDims,
    apply_rope,
    attention_any,
    attention_out,
    attention_qkv,
    gated_mlp,
    gqa_attention,
    init_attention,
    init_mlp,
    init_moe,
    moe_mlp,
    rms_norm,
)
from repro.models.shardlib import shard


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def update_cache(cache_kv, new_kv, pos):
    """cache_kv: (B,T,n,h); new_kv: (B,S,n,h); pos: (B,) write offsets."""

    def upd(c, n, p):
        return jax.lax.dynamic_update_slice(c, n, (p, 0, 0))

    return jax.vmap(upd)(cache_kv, new_kv, pos)


def update_pos(kv_pos, pos, s):
    """kv_pos: (B,T) slot-position tensor; write arange(pos, pos+s)."""

    def upd(kp, p):
        new = p + jnp.arange(s, dtype=kp.dtype)
        return jax.lax.dynamic_update_slice(kp, new, (p,))

    return jax.vmap(upd)(kv_pos, pos)


def update_pos_masked(kv_pos, pos, s, lens):
    """``update_pos`` with per-row valid lengths: positions at or beyond a
    row's true length are written as -1 (invalid slot), so padded chunk
    tails never become attendable cache entries."""

    def upd(kp, p, ln):
        new = p + jnp.arange(s, dtype=kp.dtype)
        new = jnp.where(new < ln, new, jnp.array(-1, kp.dtype))
        return jax.lax.dynamic_update_slice(kp, new, (p,))

    return jax.vmap(upd)(kv_pos, pos, lens)


def ring_update_cache(cache_kv, new_kv, pos):
    """SWA ring buffer: write one token at slot pos % T.  new_kv: (B,1,n,h)."""
    t = cache_kv.shape[1]
    slot = pos % t

    def upd(c, n, sl):
        return jax.lax.dynamic_update_slice(c, n, (sl, 0, 0))

    return jax.vmap(upd)(cache_kv, new_kv, slot)


def ring_update_pos(kv_pos, pos):
    t = kv_pos.shape[1]
    slot = pos % t

    def upd(kp, sl, p):
        return jax.lax.dynamic_update_slice(kp, p[None].astype(kp.dtype), (sl,))

    return jax.vmap(upd)(kv_pos, slot, pos)


# ===========================================================================
# dense / moe / vlm decoder blocks
# ===========================================================================


def init_dense_block(key, cfg: ModelConfig, dtype):
    ka, km = jax.random.split(key)
    dims = AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": init_attention(ka, cfg.d_model, dims, dtype),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.is_moe:
        p["moe"] = init_moe(km, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype)
    else:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, dtype)
    return p


def dense_block_train(p, x, positions, cfg: ModelConfig, attn_mask_lens=None):
    """Full-sequence causal block (training / prefill compute).

    Returns (x, (k, v, moe_aux)) so prefill can collect the cache.
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = attention_qkv(p["attn"], h, positions, cfg.rope_theta, cfg.use_rope)
    kv_valid = None
    if attn_mask_lens is not None:
        t = x.shape[1]
        kv_valid = jnp.arange(t)[None, :] < attn_mask_lens[:, None]
    att = attention_any(
        q, k, v,
        window=cfg.sliding_window,
        q_positions=positions,
        kv_positions=positions,
        kv_valid=kv_valid,
    )
    x = x + attention_out(p["attn"], att)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if cfg.is_moe:
        y, aux = moe_mlp(p["moe"], h2, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor)
    else:
        y = gated_mlp(p["mlp"], h2)
    return x + y, (k, v, aux)


def dense_block_chunk(p, x, pos, positions, lens, k_cache, v_cache, kv_pos,
                      cfg: ModelConfig):
    """S-token chunk step against a (non-ring) KV cache: the chunked-prefill
    generalization of ``dense_block_decode``.

    ``pos``: (B,) write offsets of the chunk; ``positions``: (B,S) absolute
    query positions (``pos + arange(S)``); ``lens``: (B,) true prompt
    lengths.  Chunk K/V is written into the cache first, then queries
    attend over the whole cache — the causal rule ``kv_pos <= q_pos`` masks
    future tokens *within* the chunk and ``kv_pos >= 0`` masks unwritten
    slots and padded tails, so the result matches full-sequence prefill.
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k_new, v_new = attention_qkv(
        p["attn"], h, positions, cfg.rope_theta, cfg.use_rope
    )
    k_cache = update_cache(k_cache, k_new, pos)
    v_cache = update_cache(v_cache, v_new, pos)
    kv_pos = update_pos_masked(kv_pos, pos, x.shape[1], lens)
    att = attention_any(
        q, k_cache, v_cache,
        window=cfg.sliding_window,
        q_positions=positions,
        kv_positions=kv_pos,
        kv_valid=kv_pos >= 0,
    )
    x = x + attention_out(p["attn"], att)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe_mlp(p["moe"], h2, top_k=cfg.top_k,
                       capacity_factor=cfg.capacity_factor)
    else:
        y = gated_mlp(p["mlp"], h2)
    return x + y, k_cache, v_cache, kv_pos


def dense_block_decode(p, x, pos, k_cache, v_cache, kv_pos, cfg: ModelConfig,
                       ring: bool):
    """One-token decode step against a (possibly ring) KV cache."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k_new, v_new = attention_qkv(
        p["attn"], h, pos[:, None], cfg.rope_theta, cfg.use_rope
    )
    if ring:
        k_cache = ring_update_cache(k_cache, k_new, pos)
        v_cache = ring_update_cache(v_cache, v_new, pos)
        kv_pos = ring_update_pos(kv_pos, pos)
    else:
        k_cache = update_cache(k_cache, k_new, pos)
        v_cache = update_cache(v_cache, v_new, pos)
        kv_pos = update_pos(kv_pos, pos, 1)
    att = gqa_attention(
        q, k_cache, v_cache,
        window=cfg.sliding_window,
        q_positions=pos[:, None],
        kv_positions=kv_pos,
        kv_valid=kv_pos >= 0,
    )
    x = x + attention_out(p["attn"], att)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe_mlp(p["moe"], h2, top_k=cfg.top_k,
                       capacity_factor=cfg.capacity_factor)
    else:
        y = gated_mlp(p["mlp"], h2)
    return x + y, k_cache, v_cache, kv_pos


# ===========================================================================
# the Model
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------ init

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = _dtype(cfg)
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {
            "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                      * 0.02).astype(dtype),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(keys[1], (cfg.d_model, cfg.vocab))
                * cfg.d_model ** -0.5
            ).astype(dtype)
        if not cfg.use_rope:
            params["pos_emb"] = (
                jax.random.normal(keys[2], (cfg.max_position, cfg.d_model))
                * 0.02
            ).astype(dtype)

        if cfg.kind in ("dense", "moe", "vlm"):
            lkeys = jax.random.split(keys[3], cfg.n_layers)
            params["blocks"] = jax.vmap(
                lambda k: init_dense_block(k, cfg, dtype)
            )(lkeys)
        elif cfg.kind == "encdec":
            ekeys = jax.random.split(keys[3], cfg.n_enc_layers)
            dkeys = jax.random.split(keys[4], cfg.n_layers)
            params["enc_blocks"] = jax.vmap(
                lambda k: init_dense_block(k, cfg, dtype)
            )(ekeys)
            params["dec_blocks"] = jax.vmap(
                lambda k: self._init_decoder_block(k, dtype)
            )(dkeys)
            params["enc_pos"] = (
                jax.random.normal(keys[5], (cfg.n_audio_frames, cfg.d_model))
                * 0.02
            ).astype(dtype)
            params["enc_final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        elif cfg.kind == "ssm":
            n_pairs = cfg.n_layers // cfg.slstm_every
            pkeys = jax.random.split(keys[3], n_pairs)
            params["xlstm_pairs"] = jax.vmap(
                lambda k: self._init_xlstm_pair(k, dtype)
            )(pkeys)
        elif cfg.kind == "hybrid":
            n_super = cfg.n_layers // cfg.attn_every
            mkeys = jax.random.split(keys[3], n_super)
            params["super_blocks"] = jax.vmap(
                lambda k: self._init_mamba_group(k, dtype)
            )(mkeys)
            # zamba2's single SHARED attention+MLP block
            params["shared_attn"] = init_dense_block(keys[4], cfg, dtype)
        else:
            raise ValueError(f"unknown kind {cfg.kind}")
        return params

    def _init_decoder_block(self, key, dtype):
        cfg = self.cfg
        ka, kc, km = jax.random.split(key, 3)
        dims = AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
        return {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attention(ka, cfg.d_model, dims, dtype),
            "ln_x": jnp.ones((cfg.d_model,), jnp.float32),
            "xattn": init_attention(kc, cfg.d_model, dims, dtype),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dtype),
        }

    def _init_xlstm_pair(self, key, dtype):
        cfg = self.cfg
        km, ks = jax.random.split(key)
        return {
            "ln_m": jnp.ones((cfg.d_model,), jnp.float32),
            "mlstm": ssm.init_mlstm(km, cfg.d_model, cfg.n_heads,
                                    cfg.head_dim, dtype),
            "ln_s": jnp.ones((cfg.d_model,), jnp.float32),
            "slstm": ssm.init_slstm(ks, cfg.d_model, cfg.n_heads,
                                    cfg.head_dim, dtype),
        }

    def _init_mamba_group(self, key, dtype):
        cfg = self.cfg
        gkeys = jax.random.split(key, cfg.attn_every)
        return {
            "ln": jnp.ones((cfg.attn_every, cfg.d_model), jnp.float32),
            "mamba": jax.vmap(
                lambda k: ssm.init_mamba2(k, cfg.d_model, cfg.ssm_state,
                                          cfg.conv_width, dtype)
            )(gkeys),
        }

    # ------------------------------------------------------------ embed

    def _embed(self, params, tokens, positions):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if not cfg.use_rope:
            x = x + jnp.take(params["pos_emb"], positions, axis=0)
        return shard(x, "batch", "seq", "embed")

    def head_matrix(self, params):
        return (
            params["embed"].T if self.cfg.tie_embeddings
            else params["lm_head"]
        )

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, self.head_matrix(params))
        return shard(logits, "batch", "seq", "vocab")

    # ------------------------------------------------------------ train

    def hidden(self, params, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Training forward up to the FINAL NORM (no vocab projection).

        Returns (normed hidden states over the token positions, moe aux).
        The training loss projects to the vocab in chunks
        (training.chunked_lm_loss) — materializing full (B,S,V) logits does
        not fit HBM for the 4k/32k shapes."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s_tok = tokens.shape
        aux = jnp.float32(0.0)

        if cfg.kind == "encdec":
            enc = batch["embeds"].astype(_dtype(cfg))
            enc = enc + params["enc_pos"][None, : enc.shape[1]]
            enc = self._run_encoder(params, enc)
            positions = jnp.broadcast_to(jnp.arange(s_tok)[None], (b, s_tok))
            x = self._embed(params, tokens, positions)
            x, aux = self._run_decoder_train(params, x, positions, enc)
        elif cfg.kind == "vlm" and "embeds" in batch:
            img = batch["embeds"].astype(_dtype(cfg))
            n_img = img.shape[1]
            positions = jnp.broadcast_to(
                jnp.arange(n_img + s_tok)[None], (b, n_img + s_tok)
            )
            x_tok = jnp.take(params["embed"], tokens, axis=0)
            x = jnp.concatenate([img, x_tok], axis=1)
            x = shard(x, "batch", "seq", "embed")
            x, aux = self._run_stack_train(params, x, positions)
            x = x[:, n_img:]
        else:
            positions = jnp.broadcast_to(jnp.arange(s_tok)[None], (b, s_tok))
            x = self._embed(params, tokens, positions)
            x, aux = self._run_stack_train(params, x, positions)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux

    def forward(self, params, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Training forward returning full logits (small configs only)."""
        x, aux = self.hidden(params, batch)
        logits = jnp.einsum("bsd,dv->bsv", x, self.head_matrix(params))
        return shard(logits, "batch", "seq", "vocab"), aux

    def _run_stack_train(self, params, x, positions, remat: bool = True):
        cfg = self.cfg
        if cfg.kind in ("dense", "moe", "vlm"):
            def body(carry, lp):
                h, aux = carry
                h, (_, _, a) = dense_block_train(lp, h, positions, cfg)
                return (h, aux + a), None

            if remat:
                body = jax.checkpoint(body)
            (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                       params["blocks"])
            return x, aux
        if cfg.kind == "ssm":
            def body(carry, lp):
                h = carry
                hm = rms_norm(h, lp["ln_m"], cfg.norm_eps)
                y, _ = ssm.mlstm_forward_chunked(lp["mlstm"], hm)
                h = h + y
                hs = rms_norm(h, lp["ln_s"], cfg.norm_eps)
                y2, _ = ssm.slstm_forward(lp["slstm"], hs)
                return h + y2, None

            if remat:
                body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, params["xlstm_pairs"])
            return x, jnp.float32(0.0)
        if cfg.kind == "hybrid":
            shared = params["shared_attn"]

            def body(carry, lp):
                h = carry

                @jax.checkpoint
                def mamba_one(hc, mp_ln):
                    mp, ln = mp_ln
                    hin = rms_norm(hc, ln, cfg.norm_eps)
                    y, _ = ssm.mamba2_forward_chunked(mp, hin)
                    return hc + y, None

                h, _ = jax.lax.scan(mamba_one, h, (lp["mamba"], lp["ln"]))
                h, _ = dense_block_train(shared, h, positions, cfg)[0], None
                return h, None

            if remat:
                body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, params["super_blocks"])
            return x, jnp.float32(0.0)
        raise ValueError(cfg.kind)

    def _run_encoder(self, params, enc):
        cfg = self.cfg
        b, f, _ = enc.shape
        positions = jnp.broadcast_to(jnp.arange(f)[None], (b, f))

        def body(h, lp):
            # bidirectional: no causal mask -> use kv_valid trick with a
            # huge q_pos so every key passes the causal comparison
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = attention_qkv(lp["attn"], hn, positions,
                                    cfg.rope_theta, False)
            att = gqa_attention(
                q, k, v,
                q_positions=jnp.full((b, f), f + 1, jnp.int32),
                kv_positions=positions,
            )
            h = h + attention_out(lp["attn"], att)
            h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
            return h + gated_mlp(lp["mlp"], h2), None

        enc, _ = jax.lax.scan(body, enc, params["enc_blocks"])
        return rms_norm(enc, params["enc_final_norm"], cfg.norm_eps)

    def _run_decoder_train(self, params, x, positions, enc):
        cfg = self.cfg
        b, f = enc.shape[0], enc.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(f)[None], (b, f))

        def body(h, lp):
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = attention_qkv(lp["attn"], hn, positions,
                                    cfg.rope_theta, cfg.use_rope)
            att = attention_any(q, k, v, q_positions=positions,
                                kv_positions=positions)
            h = h + attention_out(lp["attn"], att)
            hx = rms_norm(h, lp["ln_x"], cfg.norm_eps)
            qx, kx, vx = (
                jnp.einsum("bsd,dnh->bsnh", hx, lp["xattn"]["wq"]),
                jnp.einsum("bsd,dnh->bsnh", enc, lp["xattn"]["wk"]),
                jnp.einsum("bsd,dnh->bsnh", enc, lp["xattn"]["wv"]),
            )
            xat = attention_any(
                qx, kx, vx,
                q_positions=jnp.full_like(positions, f + 1),
                kv_positions=enc_pos,
            )
            h = h + attention_out(lp["xattn"], xat)
            h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
            return h + gated_mlp(lp["mlp"], h2), None

        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        return x, jnp.float32(0.0)

    # ------------------------------------------------------------ serve

    def init_cache(self, params, batch: int, cache_len: int) -> dict:
        """Allocate an empty decode cache (kv_pos = -1 -> invalid)."""
        cfg = self.cfg
        dtype = _dtype(cfg)
        t = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        kv = lambda n: jnp.zeros((n, batch, t, cfg.n_kv_heads, cfg.head_dim),
                                 dtype)
        pos = lambda n: jnp.full((n, batch, t), -1, jnp.int32)
        if cfg.kind in ("dense", "moe", "vlm"):
            return {"k": kv(cfg.n_layers), "v": kv(cfg.n_layers),
                    "kv_pos": pos(cfg.n_layers)}
        if cfg.kind == "encdec":
            nl = cfg.n_layers
            f = cfg.n_audio_frames
            cross = jnp.zeros((nl, batch, f, cfg.n_kv_heads, cfg.head_dim),
                              dtype)
            return {"k": kv(nl), "v": kv(nl), "kv_pos": pos(nl),
                    "cross_k": cross, "cross_v": cross,
                    "enc_len": jnp.zeros((batch,), jnp.int32)}
        if cfg.kind == "ssm":
            n_pairs = cfg.n_layers // cfg.slstm_every
            nh, hd = cfg.n_heads, cfg.head_dim
            z = lambda *s: jnp.zeros((n_pairs, batch, *s), jnp.float32)
            return {
                "mlstm_c": z(nh, hd, hd), "mlstm_n": z(nh, hd),
                "mlstm_m": jnp.full((n_pairs, batch, nh), -1e30, jnp.float32),
                "slstm_c": z(nh, hd), "slstm_n": z(nh, hd),
                "slstm_h": z(nh, hd),
                "slstm_m": jnp.full((n_pairs, batch, nh, hd), -1e30,
                                    jnp.float32),
            }
        if cfg.kind == "hybrid":
            n_super = cfg.n_layers // cfg.attn_every
            d_inner, pdim, h, n = ssm.mamba2_dims(cfg.d_model, cfg.ssm_state)
            w = cfg.conv_width
            return {
                "mamba_h": jnp.zeros(
                    (n_super, cfg.attn_every, batch, h, pdim, n), jnp.float32
                ),
                "mamba_conv": jnp.zeros(
                    (n_super, cfg.attn_every, batch, w - 1, d_inner + 2 * n),
                    _dtype(cfg),
                ),
                "k": kv(n_super), "v": kv(n_super), "kv_pos": pos(n_super),
            }
        raise ValueError(cfg.kind)

    def prefill(self, params, batch: dict, cache_len: int):
        """Process the full prompt; returns (last-position logits, cache).

        batch: {"tokens": (B,S), optional "embeds", optional "lens": (B,)}.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        lens = batch.get("lens", jnp.full((b,), s, jnp.int32))
        cache = self.init_cache(params, b, cache_len)

        if cfg.kind in ("dense", "moe", "vlm"):
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            x = self._embed(params, tokens, positions)
            if cfg.kind == "vlm" and "embeds" in batch:
                img = batch["embeds"].astype(_dtype(cfg))
                x = jnp.concatenate([img, jnp.take(params["embed"], tokens,
                                                   axis=0)], axis=1)
                x = shard(x, "batch", "seq", "embed")
                s = x.shape[1]
                positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
                lens = lens + img.shape[1]  # prompt = image tokens + text

            def body(carry, lp):
                h = carry
                h, (k, v, _) = dense_block_train(lp, h, positions, cfg,
                                                 attn_mask_lens=lens)
                return h, (k, v)

            x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
            cache = self._fill_kv(cache, ks, vs, lens, s)
            logits = self._logits(params, _gather_last(x, lens))
            return logits, cache

        if cfg.kind == "encdec":
            enc = batch["embeds"].astype(_dtype(cfg))
            enc = enc + params["enc_pos"][None, : enc.shape[1]]
            enc = self._run_encoder(params, enc)
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            x = self._embed(params, tokens, positions)
            f = enc.shape[1]
            enc_pos = jnp.broadcast_to(jnp.arange(f)[None], (b, f))

            def body(carry, lp):
                h = carry
                hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
                q, k, v = attention_qkv(lp["attn"], hn, positions,
                                        cfg.rope_theta, cfg.use_rope)
                att = attention_any(q, k, v, q_positions=positions,
                                    kv_positions=positions)
                h = h + attention_out(lp["attn"], att)
                hx = rms_norm(h, lp["ln_x"], cfg.norm_eps)
                kx = jnp.einsum("bsd,dnh->bsnh", enc, lp["xattn"]["wk"])
                vx = jnp.einsum("bsd,dnh->bsnh", enc, lp["xattn"]["wv"])
                qx = jnp.einsum("bsd,dnh->bsnh", hx, lp["xattn"]["wq"])
                xat = attention_any(
                    qx, kx, vx,
                    q_positions=jnp.full_like(positions, f + 1),
                    kv_positions=enc_pos,
                )
                h = h + attention_out(lp["xattn"], xat)
                h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
                return h + gated_mlp(lp["mlp"], h2), (k, v, kx, vx)

            x, (ks, vs, kxs, vxs) = jax.lax.scan(body, x,
                                                 params["dec_blocks"])
            cache = self._fill_kv(cache, ks, vs, lens, s)
            cache["cross_k"], cache["cross_v"] = kxs, vxs
            logits = self._logits(params, _gather_last(x, lens))
            return logits, cache

        if cfg.kind == "ssm":
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            x = self._embed(params, tokens, positions)

            def body(carry, lp):
                h = carry
                hm = rms_norm(h, lp["ln_m"], cfg.norm_eps)
                y, m_state = ssm.mlstm_forward_chunked(lp["mlstm"], hm)
                h = h + y
                hs = rms_norm(h, lp["ln_s"], cfg.norm_eps)
                y2, sl_state = ssm.slstm_forward(lp["slstm"], hs)
                return h + y2, (m_state, sl_state)

            x, (m_states, sl_states) = jax.lax.scan(body, x,
                                                    params["xlstm_pairs"])
            cache["mlstm_c"], cache["mlstm_n"], cache["mlstm_m"] = m_states
            cache["slstm_c"], cache["slstm_n"] = sl_states[0], sl_states[1]
            cache["slstm_h"], cache["slstm_m"] = sl_states[2], sl_states[3]
            logits = self._logits(params, _gather_last(x, lens))
            return logits, cache

        if cfg.kind == "hybrid":
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            x = self._embed(params, tokens, positions)
            shared = params["shared_attn"]

            def body(carry, lp):
                h = carry

                def mamba_one(hc, mp_ln):
                    mp, ln = mp_ln
                    hin = rms_norm(hc, ln, cfg.norm_eps)
                    y, st = ssm.mamba2_forward_chunked(mp, hin)
                    return hc + y, st

                h, m_states = jax.lax.scan(mamba_one, h,
                                           (lp["mamba"], lp["ln"]))
                h, (k, v, _) = dense_block_train(shared, h, positions, cfg,
                                                 attn_mask_lens=lens)
                return h, (m_states, k, v)

            x, (m_states, ks, vs) = jax.lax.scan(body, x,
                                                 params["super_blocks"])
            cache = self._fill_kv(cache, ks, vs, lens, s)
            cache["mamba_h"], cache["mamba_conv"] = m_states
            logits = self._logits(params, _gather_last(x, lens))
            return logits, cache

        raise ValueError(cfg.kind)

    def prefill_chunked(self, params, batch: dict, cache_len: int,
                        chunk: int):
        """Chunked prefill: process the prompt ``chunk`` tokens at a time.

        Same signature contract as :meth:`prefill` (returns last-position
        logits and a decode cache) but bounds per-step activation memory to
        ``B x chunk`` instead of ``B x S`` — the serving engine's
        ``prefill_chunk`` knob maps directly onto this, so long prompts are
        *actually* processed in chunk-sized slices rather than merely
        accounted as multiple iterations.

        Falls back to the one-shot :meth:`prefill` when chunking cannot
        help or would change the result: prompts that fit in one chunk,
        non-attention-cache families (recurrent state would need chunk
        carry), MoE (GShard capacity routing is sequence-length dependent,
        so per-chunk capacities drop different tokens than one-shot),
        VLM image batches, and ring (sliding-window) caches smaller than
        the prompt.
        """
        cfg = self.cfg
        s = batch["tokens"].shape[1]
        ring = bool(cfg.sliding_window) and min(
            cache_len, cfg.sliding_window
        ) < cache_len
        if (
            s <= chunk
            or cfg.kind not in ("dense", "vlm")
            or "embeds" in batch
            or ring
        ):
            return self.prefill(params, batch, cache_len=cache_len)

        tokens = batch["tokens"]
        b = tokens.shape[0]
        lens = batch.get("lens", jnp.full((b,), s, jnp.int32))
        cache = self.init_cache(params, b, cache_len)
        k_cache, v_cache, kv_pos = cache["k"], cache["v"], cache["kv_pos"]
        hidden = []
        for c0 in range(0, s, chunk):
            toks_c = tokens[:, c0:c0 + chunk]
            sc = toks_c.shape[1]
            positions = jnp.broadcast_to(
                jnp.arange(c0, c0 + sc)[None], (b, sc)
            )
            pos0 = jnp.full((b,), c0, jnp.int32)
            x = self._embed(params, toks_c, positions)

            def body(carry, xs, positions=positions, pos0=pos0):
                h = carry
                lp, kc, vc, kp = xs
                h, kc, vc, kp = dense_block_chunk(
                    lp, h, pos0, positions, lens, kc, vc, kp, cfg
                )
                return h, (kc, vc, kp)

            x, (k_cache, v_cache, kv_pos) = jax.lax.scan(
                body, x, (params["blocks"], k_cache, v_cache, kv_pos)
            )
            hidden.append(x)
        x = jnp.concatenate(hidden, axis=1)
        cache = dict(cache, k=k_cache, v=v_cache, kv_pos=kv_pos)
        logits = self._logits(params, _gather_last(x, lens))
        return logits, cache

    def prefill_slice(self, params, cache: dict, tokens, slot, start, total):
        """One bounded prefill slice of a SINGLE batch slot against a live
        decode cache — the serving engine's fused prefill-in-window unit.

        ``tokens``: (S,) int32 chunk of the prompt (zero-padded past the
        prompt's end); ``slot``/``start``/``total``: traced int32 scalars —
        the cache row being prefilled, the slice's absolute write offset,
        and the full prompt length.  Follows ``dense_block_chunk``'s rule
        per layer: write the slice's K/V first (positions at or beyond
        ``total`` masked to -1; writes use explicit scatter-with-drop, so
        an out-of-range ``slot``/index never clamp-corrupts a neighbour
        the way ``dynamic_update_slice`` would), then attend the queries
        over the slot's whole cache with ``kv_pos <= q_pos`` masking the
        chunk-internal future and ``kv_pos >= 0`` the unwritten rows.

        Returns ``(logits (V,), cache)`` where the logits are taken at the
        prompt's final position clipped into this slice — i.e. the
        first-token distribution when this slice completes the prompt, and
        garbage otherwise.  Supports the full-cache attention families
        (dense / moe / vlm token prompts); callers gate ring (sliding
        window smaller than the cache) layouts out, as chunked writes
        cannot reproduce a ring wrap.
        """
        cfg = self.cfg
        if cfg.kind not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"prefill_slice: unsupported model kind {cfg.kind!r}"
            )
        s = tokens.shape[0]
        idx = start + jnp.arange(s, dtype=jnp.int32)
        positions = idx[None, :]
        x = self._embed(params, tokens[None, :], positions)

        def body(h, xs):
            lp, kc, vc, kp = xs
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            q, k_new, v_new = attention_qkv(
                lp["attn"], hn, positions, cfg.rope_theta, cfg.use_rope
            )
            kc = kc.at[slot, idx].set(k_new[0].astype(kc.dtype), mode="drop")
            vc = vc.at[slot, idx].set(v_new[0].astype(vc.dtype), mode="drop")
            kp = kp.at[slot, idx].set(
                jnp.where(idx < total, idx, -1).astype(kp.dtype), mode="drop"
            )
            kp_row = kp[slot][None]
            att = attention_any(
                q, kc[slot][None], vc[slot][None],
                window=cfg.sliding_window,
                q_positions=positions,
                kv_positions=kp_row,
                kv_valid=kp_row >= 0,
            )
            h = h + attention_out(lp["attn"], att)
            h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                y, _ = moe_mlp(lp["moe"], h2, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor)
            else:
                y = gated_mlp(lp["mlp"], h2)
            return h + y, (kc, vc, kp)

        x, (ks, vs, kps) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"],
                      cache["kv_pos"])
        )
        cache = dict(cache, k=ks, v=vs, kv_pos=kps)
        last = jnp.clip(total - 1 - start, 0, s - 1)
        logits = self._logits(params, x[:, last][:, None, :])
        return logits[0, 0], cache

    def _fill_kv(self, cache, ks, vs, lens, s):
        """Copy prefill K/V (L,B,S,n,h) into the cache's first S slots."""
        cfg = self.cfg
        t = cache["k"].shape[2]
        if cfg.sliding_window and t < s:
            # ring buffer smaller than the prompt: keep the last t tokens
            ks, vs = ks[:, :, -t:], vs[:, :, -t:]
            kvp = jnp.arange(s - t, s, dtype=jnp.int32)
            kvp = jnp.broadcast_to(kvp[None, None], ks.shape[:3])
        else:
            pad = t - ks.shape[2]
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            kvp = jnp.pad(
                jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, None],
                                 (ks.shape[0], ks.shape[1], s)),
                ((0, 0), (0, 0), (0, pad)), constant_values=-1,
            )
        # mask out slots beyond each row's true prompt length
        valid = kvp < lens[None, :, None]
        kvp = jnp.where(valid, kvp, -1)
        cache["k"], cache["v"], cache["kv_pos"] = ks, vs, kvp
        return cache

    def decode(self, params, cache: dict, tokens, pos):
        """One decode step.  tokens: (B,1) int32; pos: (B,) positions of the
        new token.  Returns (logits (B,1,V), updated cache)."""
        cfg = self.cfg
        b = tokens.shape[0]
        x = self._embed(params, tokens, pos[:, None])
        ring = bool(cfg.sliding_window) and (
            "k" in cache and cache["k"].shape[2] == cfg.sliding_window
        )

        if cfg.kind in ("dense", "moe", "vlm"):
            def body(carry, xs):
                h = carry
                lp, kc, vc, kp = xs
                h, kc, vc, kp = dense_block_decode(lp, h, pos, kc, vc, kp,
                                                   cfg, ring)
                return h, (kc, vc, kp)

            x, (ks, vs, kps) = jax.lax.scan(
                body, x, (params["blocks"], cache["k"], cache["v"],
                          cache["kv_pos"])
            )
            cache = dict(cache, k=ks, v=vs, kv_pos=kps)
            return self._logits(params, x), cache

        if cfg.kind == "encdec":
            f = cache["cross_k"].shape[2]
            enc_pos = jnp.broadcast_to(jnp.arange(f)[None], (b, f))

            def body(carry, xs):
                h = carry
                lp, kc, vc, kp, ckx, cvx = xs
                h2, kc, vc, kp = dense_block_decode_selfonly(
                    lp, h, pos, kc, vc, kp, cfg
                )
                hx = rms_norm(h2, lp["ln_x"], cfg.norm_eps)
                qx = jnp.einsum("bsd,dnh->bsnh", hx, lp["xattn"]["wq"])
                xat = gqa_attention(
                    qx, ckx, cvx,
                    q_positions=jnp.full((b, 1), f + 1, jnp.int32),
                    kv_positions=enc_pos,
                )
                h2 = h2 + attention_out(lp["xattn"], xat)
                hm = rms_norm(h2, lp["ln2"], cfg.norm_eps)
                h2 = h2 + gated_mlp(lp["mlp"], hm)
                return h2, (kc, vc, kp)

            x, (ks, vs, kps) = jax.lax.scan(
                body, x,
                (params["dec_blocks"], cache["k"], cache["v"],
                 cache["kv_pos"], cache["cross_k"], cache["cross_v"]),
            )
            cache = dict(cache, k=ks, v=vs, kv_pos=kps)
            return self._logits(params, x), cache

        if cfg.kind == "ssm":
            def body(carry, xs):
                h = carry
                lp, mc, mn, mm, sc, sn, sh, sm = xs
                hm = rms_norm(h, lp["ln_m"], cfg.norm_eps)
                y, (mc, mn, mm) = ssm.mlstm_decode(lp["mlstm"], hm,
                                                   (mc, mn, mm))
                h = h + y
                hs = rms_norm(h, lp["ln_s"], cfg.norm_eps)
                y2, (sc, sn, sh, sm) = ssm.slstm_decode(lp["slstm"], hs,
                                                        (sc, sn, sh, sm))
                return h + y2, (mc, mn, mm, sc, sn, sh, sm)

            x, states = jax.lax.scan(
                body, x,
                (params["xlstm_pairs"], cache["mlstm_c"], cache["mlstm_n"],
                 cache["mlstm_m"], cache["slstm_c"], cache["slstm_n"],
                 cache["slstm_h"], cache["slstm_m"]),
            )
            cache = dict(
                cache,
                mlstm_c=states[0], mlstm_n=states[1], mlstm_m=states[2],
                slstm_c=states[3], slstm_n=states[4], slstm_h=states[5],
                slstm_m=states[6],
            )
            return self._logits(params, x), cache

        if cfg.kind == "hybrid":
            shared = params["shared_attn"]

            def body(carry, xs):
                h = carry
                lp, mh, mconv, kc, vc, kp = xs

                def mamba_one(hc, packed):
                    mp, ln, st, cv = packed
                    hin = rms_norm(hc, ln, cfg.norm_eps)
                    y, (st, cv) = ssm.mamba2_decode(mp, hin, st, cv)
                    return hc + y, (st, cv)

                h, (mh, mconv) = jax.lax.scan(
                    mamba_one, h, (lp["mamba"], lp["ln"], mh, mconv)
                )
                h, kc, vc, kp = dense_block_decode(shared, h, pos, kc, vc,
                                                   kp, cfg, ring)
                return h, (mh, mconv, kc, vc, kp)

            x, (mh, mconv, ks, vs, kps) = jax.lax.scan(
                body, x,
                (params["super_blocks"], cache["mamba_h"],
                 cache["mamba_conv"], cache["k"], cache["v"],
                 cache["kv_pos"]),
            )
            cache = dict(cache, mamba_h=mh, mamba_conv=mconv, k=ks, v=vs,
                         kv_pos=kps)
            return self._logits(params, x), cache

        raise ValueError(cfg.kind)


def _gather_last(x, lens):
    """x: (B,S,D); lens: (B,) true lengths -> (B,1,D) at position lens-1."""
    b = x.shape[0]
    idx = jnp.clip(lens - 1, 0, x.shape[1] - 1)
    return x[jnp.arange(b), idx][:, None, :]


def dense_block_decode_selfonly(p, x, pos, k_cache, v_cache, kv_pos,
                                cfg: ModelConfig):
    """Self-attention part of a decoder block (cross-attn handled outside)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k_new, v_new = attention_qkv(
        p["attn"], h, pos[:, None], cfg.rope_theta, cfg.use_rope
    )
    k_cache = update_cache(k_cache, k_new, pos)
    v_cache = update_cache(v_cache, v_new, pos)
    kv_pos = update_pos(kv_pos, pos, 1)
    att = gqa_attention(
        q, k_cache, v_cache,
        q_positions=pos[:, None],
        kv_positions=kv_pos,
        kv_valid=kv_pos >= 0,
    )
    return x + attention_out(p["attn"], att), k_cache, v_cache, kv_pos


