"""Model zoo: configs, layers, SSM blocks, and the unified Model."""

from repro.models.config import INPUT_SHAPES, Block, InputShape, ModelConfig
from repro.models.transformer import Model

__all__ = ["INPUT_SHAPES", "Block", "InputShape", "ModelConfig", "Model"]
