"""Model configuration for the assigned architecture pool.

One ``ModelConfig`` describes any architecture the framework can build:
dense GQA transformers, SWA variants, MoE, encoder-decoder (audio), VLM
decoders, xLSTM stacks, and Mamba2+attention hybrids.  Every assigned
architecture in ``repro/configs/`` instantiates this dataclass with the
exact numbers from its source paper / model card.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Block(enum.Enum):
    """Sequence-mixing block kinds a layer stack can be built from."""

    ATTN = "attn"          # (GQA) attention, optionally sliding-window
    MLSTM = "mlstm"        # xLSTM matrix-memory block
    SLSTM = "slstm"        # xLSTM scalar-memory block
    MAMBA2 = "mamba2"      # Mamba2 SSD block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 500_000.0
    use_rope: bool = True          # False -> learned absolute positions
    sliding_window: int = 0        # 0 -> full attention
    max_position: int = 1_048_576  # for learned positions / rope cache

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0             # Mamba2 state size per head
    conv_width: int = 4            # Mamba2 short conv
    attn_every: int = 0            # hybrid: one shared attn block every k
    # xLSTM: ratio of mLSTM blocks per sLSTM block (7:1 in the paper's
    # xLSTM[7:1]; we alternate per `slstm_every`)
    slstm_every: int = 2

    # encoder-decoder (audio)
    n_enc_layers: int = 0
    n_audio_frames: int = 1500     # whisper 30 s @ 50 Hz after conv stub

    # VLM
    n_image_tokens: int = 0        # anyres patch embeddings (stub frontend)

    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # distribution policy (resolved per-arch; see DESIGN.md §5)
    # "heads"    -> shard attention over the head axis
    # "head_dim" -> shard attention over the per-head feature axis
    attn_shard: str = "auto"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    # ---------------------------------------------------------------- props

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def attn_shard_mode(self, model_par: int) -> str:
        """Resolve 'auto' against a model-parallel degree."""
        if self.attn_shard != "auto":
            return self.attn_shard
        return "heads" if self.n_heads % model_par == 0 else "head_dim"

    def n_params(self) -> int:
        """Approximate parameter count (reporting/roofline only)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * hd * nh + 2 * d * hd * nkv + hd * nh * d
        if self.is_moe:
            ffn = self.n_experts * 3 * d * f
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        total = self.n_layers * per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + ffn + 2 * d)
        return int(total)

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if not self.is_moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_ffn = self.n_layers * 3 * d * f
        total = self.n_params() - self.n_layers * self.n_experts * 3 * d * f
        return int(total + self.top_k * dense_ffn)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (CPU-runnable)."""
        small = dict(
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=32,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            n_audio_frames=16 if self.n_enc_layers else 1500,
            n_image_tokens=8 if self.n_image_tokens else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window
            else 0,
            max_position=4096,
            dtype="float32",
            name=self.name + "-smoke",
        )
        # keep kv heads consistent with heads
        if small["n_heads"] % small["n_kv_heads"]:
            small["n_kv_heads"] = 1
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
