"""Production mesh builders.

Target: TPU v5e pods — 16x16 = 256 chips per pod ("data" x "model"),
2 pods = 512 chips with a leading "pod" axis (pure data parallelism across
pods; ICI within a pod, DCN across).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    if jax.device_count() == n:
        return jax.make_mesh(shape, axes)
    if jax.device_count() < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {jax.device_count()} "
            "(the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import)"
        )
    # more devices than the mesh needs (single-pod mesh under the 512-device
    # dry-run flag): take the first n
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for CPU sharding tests (device count must match)."""
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)
