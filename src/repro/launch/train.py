"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        [--reduced] [--steps 100] [--mesh debug]

``--reduced`` (default on CPU) trains the smoke-scale variant on the local
device; on a real TPU slice drop it to train the full config on the
production mesh with the same sharding policy the dry-run validated.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.sharding import make_rules, opt_pspecs, param_pspecs
from repro.models import Model
from repro.models.shardlib import use_sharding
from repro.training import (
    AdamWConfig,
    DataConfig,
    data_iterator,
    init_adamw,
    make_train_step,
    save_checkpoint,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ALL_ARCHS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--mesh", choices=["none", "debug", "prod", "multipod"],
                    default="none")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab=min(cfg.vocab, 512))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    step = make_train_step(model, opt_cfg)
    data = data_iterator(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch, order=1))

    if args.mesh == "none":
        step = jax.jit(step)
        ctx = None
    else:
        mesh = {"debug": lambda: make_debug_mesh(),
                "prod": lambda: make_production_mesh(),
                "multipod": lambda: make_production_mesh(multi_pod=True),
                }[args.mesh]()
        rules = make_rules(cfg, mesh)
        pspecs = param_pspecs(
            jax.eval_shape(lambda: params), cfg, mesh
        )
        step = jax.jit(step)
        ctx = (mesh, rules)

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        if ctx:
            with ctx[0], use_sharding(*ctx):
                params, opt, metrics = step(params, opt, batch)
        else:
            params, opt, metrics = step(params, opt, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(metrics['loss']):.3f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params}, step=args.steps)
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
