"""Serving launcher: the unified ``AgentService`` API over either backend.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        [--backend engine|sim] [--scheduler justitia] [--n-agents 6] \
        [--replicas 3] [--router memory_cost_aware]

One workload spec (the paper's agent-class sampler + bursty arrivals) is
driven through :class:`repro.api.AgentService`; ``--backend engine`` serves
it on the real JAX continuous-batching engine (actual prefill/decode on
device, paged KV accounting, swap-on-pressure), ``--backend sim`` on the
calibrated discrete-event cluster — same ``AgentSpec`` list, same scheduler
policy objects, one flag apart.  Scheduler names resolve through the plugin
registry (``repro.core.registry``), so ``--scheduler`` accepts any
registered policy.  Agents arrive *online* at their sampled arrival times,
not upfront.

``--replicas N`` serves the same workload on an N-way
:class:`repro.api.ReplicatedBackend` fleet (per-replica pools, lockstep
clocks, reconciled global virtual time); ``--router`` picks the placement
policy from the router registry (``repro.api.router_names()``).

CPU runs the reduced model variant end-to-end; the full configs are
validated against the production mesh by the dry-run (repro.launch.dryrun),
which this launcher shares all sharding policy with.  Installed as the
``repro-serve`` console entrypoint (see pyproject.toml).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import router_names, service_for_backend, specs_from_classes
from repro.configs import ALL_ARCHS
from repro.core import scheduler_names


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ALL_ARCHS)
    ap.add_argument("--backend", default="engine", choices=("engine", "sim"))
    ap.add_argument("--scheduler", default="justitia",
                    choices=scheduler_names())
    ap.add_argument("--n-agents", type=int, default=6)
    ap.add_argument("--pool-tokens", type=int, default=4096)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--window-s", type=float, default=20.0,
                    help="arrival window (workload seconds)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve on an N-way replicated fleet")
    ap.add_argument("--router", default="round_robin",
                    choices=router_names(),
                    help="fleet placement policy (with --replicas > 1)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    specs = specs_from_classes(rng, args.n_agents, args.window_s)
    service = service_for_backend(
        args.backend, args.scheduler,
        arch=args.arch, pool_tokens=args.pool_tokens,
        max_batch=args.max_batch,
        replicas=args.replicas, router=args.router,
    )

    t0 = time.time()
    service.submit_many(specs)
    result = service.drain()
    print(f"backend={result.backend} scheduler={args.scheduler} "
          f"agents={args.n_agents} wall={time.time() - t0:.1f}s")
    print("jct:", result.stats.row())
    print("completions:",
          {k: round(v, 1) for k, v in sorted(result.finish.items())})
    print("events:", result.event_counts)
    print("metrics:", result.metrics)
    if result.per_replica:
        for r, stats in result.per_replica.items():
            print(f"replica {r}: {stats.row()}")


if __name__ == "__main__":
    main()
