"""Serving launcher: the unified ``AgentService`` API over either backend.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        [--backend engine|sim] [--scheduler justitia] [--n-agents 6] \
        [--replicas 3] [--router memory_cost_aware]

One workload spec (the paper's agent-class sampler + bursty arrivals) is
driven through :class:`repro.api.AgentService`; ``--backend engine`` serves
it on the real JAX continuous-batching engine (actual prefill/decode on
device, paged KV accounting, swap-on-pressure), ``--backend sim`` on the
calibrated discrete-event cluster — same ``AgentSpec`` list, same scheduler
policy objects, one flag apart.  Scheduler names resolve through the plugin
registry (``repro.core.registry``), so ``--scheduler`` accepts any
registered policy.  Agents arrive *online* at their sampled arrival times,
not upfront.

``--replicas N`` serves the same workload on an N-way
:class:`repro.api.ReplicatedBackend` fleet (per-replica pools, lockstep
clocks, reconciled global virtual time); ``--router`` picks the placement
policy from the router registry (``repro.api.router_names()``).

CPU runs the reduced model variant end-to-end; the full configs are
validated against the production mesh by the dry-run (repro.launch.dryrun),
which this launcher shares all sharding policy with.  Installed as the
``repro-serve`` console entrypoint (see pyproject.toml).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import router_names, service_for_backend, specs_from_classes
from repro.configs import ALL_ARCHS
from repro.core import scheduler_names


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ALL_ARCHS)
    ap.add_argument("--backend", default="engine", choices=("engine", "sim"))
    ap.add_argument("--scheduler", default="justitia",
                    choices=scheduler_names())
    ap.add_argument("--n-agents", type=int, default=6)
    ap.add_argument("--pool-tokens", type=int, default=4096)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--window-s", type=float, default=20.0,
                    help="arrival window (workload seconds)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve on an N-way replicated fleet")
    ap.add_argument("--router", default="round_robin",
                    choices=router_names(),
                    help="fleet placement policy (with --replicas > 1)")
    ap.add_argument("--watchdog-timeout", type=float, default=None,
                    metavar="S",
                    help="(with --replicas > 1) suspect a busy replica "
                         "lagging the fleet clock by S seconds")
    ap.add_argument("--watchdog-retries", type=int, default=None,
                    help="suspect probes before declaring a replica dead "
                         "(fleet default: 3)")
    ap.add_argument("--watchdog-backoff", type=float, default=None,
                    help="multiplier between successive suspect probes "
                         "(fleet default: 2.0)")
    ap.add_argument("--admission-watermark", type=float, nargs=2,
                    default=None, metavar=("LOW", "HIGH"),
                    help="watermark admission control: defer admissions "
                         "below LOW free-pool fraction, resume above HIGH")
    ap.add_argument("--suspend-retention", default=None,
                    choices=("hold", "spill", "drop"),
                    help="KV retention for agents suspended through "
                         "tool-call think time (closed-loop workloads)")
    ap.add_argument("--fleet-workers", type=int, default=None, metavar="N",
                    help="(with --replicas > 1) advance the fleet's "
                         "children concurrently on an N-thread pool — "
                         "bit-identical to the sequential lockstep loop")
    ap.add_argument("--steal-threshold", type=float, default=None,
                    metavar="X",
                    help="(with --replicas > 1) migrate queued, "
                         "never-admitted agents off a replica whose "
                         "capacity-normalized backlog exceeds X times the "
                         "fleet mean (X > 1; the X-to-mean gap is the "
                         "hysteresis band)")
    ap.add_argument("--steal-interval", type=float, default=None,
                    metavar="S",
                    help="workload seconds between stealing passes "
                         "(fleet default: 1.0)")
    args = ap.parse_args()
    if args.watchdog_timeout is not None and args.replicas <= 1:
        ap.error("--watchdog-timeout requires --replicas > 1")
    if args.fleet_workers is not None and args.replicas <= 1:
        ap.error("--fleet-workers requires --replicas > 1")
    if args.steal_threshold is not None and args.replicas <= 1:
        ap.error("--steal-threshold requires --replicas > 1")
    if args.steal_interval is not None and args.steal_threshold is None:
        ap.error("--steal-interval requires --steal-threshold")

    rng = np.random.default_rng(0)
    specs = specs_from_classes(rng, args.n_agents, args.window_s)
    service = service_for_backend(
        args.backend, args.scheduler,
        arch=args.arch, pool_tokens=args.pool_tokens,
        max_batch=args.max_batch,
        replicas=args.replicas, router=args.router,
        watchdog_timeout=args.watchdog_timeout,
        watchdog_retries=args.watchdog_retries,
        watchdog_backoff=args.watchdog_backoff,
        admission_watermark=(
            tuple(args.admission_watermark)
            if args.admission_watermark is not None else None
        ),
        suspend_retention=args.suspend_retention,
        fleet_workers=args.fleet_workers,
        steal_threshold=args.steal_threshold,
        steal_interval=args.steal_interval,
    )

    t0 = time.time()
    service.submit_many(specs)
    result = service.drain()
    print(f"backend={result.backend} scheduler={args.scheduler} "
          f"agents={args.n_agents} wall={time.time() - t0:.1f}s")
    print("jct:", result.stats.row())
    print("completions:",
          {k: round(v, 1) for k, v in sorted(result.finish.items())})
    print("events:", result.event_counts)
    print("metrics:", result.metrics)
    if result.per_replica:
        for r, stats in result.per_replica.items():
            print(f"replica {r}: {stats.row()}")


if __name__ == "__main__":
    main()
