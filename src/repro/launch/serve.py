"""Serving launcher: continuous-batching engine + Justitia scheduling.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        [--scheduler justitia] [--n-agents 6]

CPU runs the reduced variant end-to-end (real prefill/decode); the full
configs are validated against the production mesh by the dry-run
(repro.launch.dryrun), which this launcher shares all sharding policy with.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.core import make_scheduler
from repro.engine import EngineAgent, ServeEngine
from repro.models import Model
from repro.workloads import sample_agent


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ALL_ARCHS)
    ap.add_argument("--scheduler", default="justitia")
    ap.add_argument("--n-agents", type=int, default=6)
    ap.add_argument("--pool-tokens", type=int, default=4096)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    vocab = 512
    cfg = get_config(args.arch).reduced(vocab=vocab)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    engine = ServeEngine(
        model, params,
        make_scheduler(args.scheduler, float(args.pool_tokens)),
        pool_tokens=args.pool_tokens, max_batch=args.max_batch,
        cache_len=512,
    )
    classes = ("EV", "FV", "CC", "KBQAV")
    t0 = time.time()
    for aid in range(args.n_agents):
        a = sample_agent(rng, classes[aid % len(classes)])
        stages = [
            [(rng.integers(0, vocab, size=max(8, s.prefill // 8)),
              max(4, s.decode // 8)) for s in stage]
            for stage in a.stages
        ]
        engine.submit_agent(EngineAgent(
            agent_id=aid, arrival_iter=engine.now, stages=stages,
            predicted_cost=a.true_cost / 64.0,
        ))
    done = engine.run_until_idle()
    engine.alloc.check_invariants()
    print(f"arch={cfg.name} scheduler={args.scheduler} "
          f"agents={args.n_agents} wall={time.time() - t0:.1f}s")
    print("completion iterations:", dict(sorted(done.items())))
    print("metrics:", engine.metrics)


if __name__ == "__main__":
    main()
