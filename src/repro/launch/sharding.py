"""Sharding policy: logical-axis rules + parameter/batch/cache PartitionSpecs.

Baseline scheme (DESIGN.md §5):
  * activations: batch -> ("pod","data"); ffn/vocab/experts/head_dim ->
    "model"; heads -> None.  head_dim sharding is the universal baseline —
    every assigned arch has head_dim % 16 == 0 while several have
    n_heads % 16 != 0 (llama3.2 24H, llava 56H, starcoder2 36H, whisper 6H).
    Head-sharding for divisible archs is a §Perf hillclimb alternative.
  * params: 2-D sharded — d_model axis ("p_embed") over "data" (FSDP;
    gathered per layer inside the scan) x output axis over "model" (tensor
    parallel).  Optimizer states inherit the parameter specs (ZeRO).
  * pods replicate params; gradients all-reduce over "pod" (+"data").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import InputShape, ModelConfig
from repro.training.optimizer import AdamState


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_rules(cfg: ModelConfig, mesh: Mesh, *, shard_batch: bool = True,
               attn_mode: str = "head_dim") -> dict:
    """Logical activation-axis -> mesh-axis rules (see models/shardlib).

    Divisibility-aware: an axis whose size does not divide the "model"
    degree is left unsharded (e.g. whisper/granite vocabs 51865/49155,
    mixtral's 8 experts on a 16-way model axis).
    """
    mp = mesh.shape["model"]
    b_axes = batch_axes(mesh) if shard_batch else None
    heads = "model" if attn_mode == "heads" and cfg.n_heads % mp == 0 else None
    hd = "model" if attn_mode == "head_dim" and cfg.head_dim % mp == 0 else None
    kvh = (
        "model"
        if attn_mode == "heads" and cfg.n_kv_heads % mp == 0
        else None
    )
    return {
        "batch": b_axes,
        "seq": None,
        "heads": heads,
        "kv_heads": kvh,
        "head_dim": hd,
        # context parallelism (O4): vmapped q-chunk axis on "model" for
        # archs whose heads do not divide the model degree
        "q_chunks": "model" if attn_mode == "context" else None,
        # O4 iteration 5 (REFUTED, kept disabled): pinning projection
        # outputs sharded + explicit activation gathers gave compute
        # 1.79->0.97s but collective 2.35->3.76s at llama train_4k — WORSE
        # step time than SPMD's replicated-projection choice.  The
        # partitioner's weight-gather tradeoff wins at 16-way model
        # parallelism; see EXPERIMENTS §Perf iteration 5.
        "head_dim_proj": None,
        "embed": None,
        # expert-parallel archs put experts on "model"; the ffn dim then
        # stays local (both on "model" would be a spec conflict).  MoE
        # archs whose expert count does NOT divide the axis (mixtral 8e)
        # fall back to tensor-parallel ffn sharding instead.
        "ffn": (
            None
            if (cfg.n_experts and cfg.n_experts % mp == 0)
            else ("model" if (cfg.d_ff == 0 or cfg.d_ff % mp == 0) else None)
        ),
        "vocab": "model" if cfg.vocab % mp == 0 else None,
        "experts": "model" if cfg.n_experts and cfg.n_experts % mp == 0
        else None,
    }


# --------------------------------------------------------------- parameters


def _param_base_spec(path_keys: list[str], shape: tuple, cfg: ModelConfig,
                     attn_mode: str, mesh: Mesh, fsdp: bool = True) -> P:
    """Spec for the TRAILING dims of a leaf; leading stack dims -> None.

    Every chosen axis is validated against the actual dim size: a mesh
    axis whose degree does not divide the dim is dropped (replicated).
    ``fsdp=False`` (serving): weights replicate over "data" — latency paths
    must not all-gather weights every step.
    """
    ndim = len(shape)
    name = path_keys[-1]
    ctx = set(path_keys)
    model_par = mesh.shape["model"]
    E = "data" if fsdp else None  # d_model axis of params
    heads = "model" if attn_mode == "heads" else None
    # context mode (O4): attention WEIGHTS stay head_dim-sharded (memory,
    # and the projections compute sharded); only the q/k/v ACTIVATIONS are
    # gathered at the attention boundary — attention itself is q-chunk
    # parallel.  Replicating the projection weights instead was measured to
    # 2.8x the per-device FLOPs (§Perf iteration 4).
    hd = "model" if attn_mode in ("head_dim", "context") else None

    if "attn" in ctx or "xattn" in ctx or "shared_attn" in ctx:
        if name in ("wq", "wk", "wv"):
            base = (E, heads, hd)                 # (D, n, h)
        elif name == "wo":
            base = (heads, hd, E)                 # (n, h, D)
        elif name in ("w1", "w3"):
            base = (E, "model")                   # (D, F)
        elif name == "w2":
            base = ("model", E)                   # (F, D)
        else:
            base = ()
    elif "moe" in ctx:
        # experts shard over "model" when the count divides it (dbrx 16e);
        # otherwise fall back to tensor-parallel F sharding (mixtral 8e)
        ep = cfg.n_experts % model_par == 0
        if name == "router":
            base = (E, None)                      # (D, E#)
        elif name in ("w1", "w3"):
            base = ("model", E, None) if ep else (None, E, "model")
        elif name == "w2":
            base = ("model", None, E) if ep else (None, "model", E)
        else:
            base = ()
    elif "mlp" in ctx:
        if name in ("w1", "w3"):
            base = (E, "model")
        elif name == "w2":
            base = ("model", E)
        else:
            base = ()
    elif "mamba" in ctx:
        if name == "w_in":
            base = (E, "model")                   # (D, 2di+2n+h)
        elif name == "w_out":
            base = ("model", E)                   # (di, D)
        else:
            base = ()                             # conv/gates: tiny
    elif "mlstm" in ctx:
        if name in ("wq", "wk", "wv"):
            base = (E, None, "model")             # (D, H, hd)
        elif name == "wo":
            base = (None, "model", E)             # (H, hd, D)
        else:
            base = ()
    elif "slstm" in ctx:
        if name == "w_in":
            base = (E, None, "model", None)       # (D, H, hd, 4)
        elif name == "r":
            base = (None, "model", None, None)    # (H, hd, hd, 4)
        elif name == "wo":
            base = (None, "model", E)
        else:
            base = ()
    elif name == "embed":
        base = ("model", E)                       # (V, D)
    elif name == "lm_head":
        base = (E, "model")                       # (D, V)
    else:
        base = ()                                 # norms, pos tables, gates

    if len(base) > ndim:
        base = base[-ndim:] if ndim else ()
    pad = (None,) * (ndim - len(base))
    full = list(pad + tuple(base))
    # divisibility safety net: drop any axis that does not divide the dim
    for i, ax in enumerate(full):
        if ax is None:
            continue
        degree = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            degree *= mesh.shape[a]
        if shape[i] % degree:
            full[i] = None
    return P(*full)


def param_pspecs(params_struct, cfg: ModelConfig, mesh: Mesh, *,
                 attn_mode: str = "head_dim", fsdp: bool = True):
    """PartitionSpec pytree matching the params pytree."""

    def one(path, leaf):
        keys = [
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        ]
        return _param_base_spec(keys, tuple(leaf.shape), cfg, attn_mode,
                                mesh, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(one, params_struct)


def opt_pspecs(param_specs) -> AdamState:
    return AdamState(step=P(), m=param_specs, v=param_specs)


# ------------------------------------------------------------ batch / cache


def train_batch_pspecs(cfg: ModelConfig, mesh: Mesh) -> dict:
    b = P(batch_axes(mesh))
    specs = {"tokens": P(batch_axes(mesh), None)}
    if cfg.kind in ("encdec", "vlm"):
        specs["embeds"] = P(batch_axes(mesh), None, None)
    return specs


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_struct,
                 *, shard_batch: bool, shard_seq: bool,
                 seq_axis: str = "batch") -> dict:
    """Specs for the decode cache pytree (shapes from Model.init_cache).

    ``shard_seq`` with ``seq_axis="model"`` gives flash-decoding-style
    sequence-parallel attention: the KV sequence dim lives on the model
    axis, attention partials combine with tiny stat psums instead of
    all-reducing full logits (§Perf optimization O3).
    """
    b = batch_axes(mesh) if shard_batch else None
    if shard_seq:
        t = batch_axes(mesh) if seq_axis == "batch" else "model"
    else:
        t = None
    # hd and T cannot both live on "model"
    hd = None if t == "model" else "model"

    def one(path, leaf):
        name = str(path[-1].key)
        if name in ("k", "v"):           # (L,B,T,nkv,hd)
            return P(None, b, t, None, hd)
        if name in ("cross_k", "cross_v"):
            return P(None, b, None, None, hd)
        if name == "kv_pos":             # (L,B,T)
            return P(None, b, t)
        if name == "enc_len":
            return P(b)
        if name == "mlstm_c":            # (Pair,B,H,hd,hd)
            return P(None, b, None, "model", None)
        if name in ("mlstm_n",):         # (Pair,B,H,hd)
            return P(None, b, None, "model")
        if name == "mlstm_m":            # (Pair,B,H)
            return P(None, b, None)
        if name in ("slstm_c", "slstm_n", "slstm_h", "slstm_m"):
            return P(None, b, None, "model")
        if name == "mamba_h":            # (NS,AE,B,H,P,N)
            return P(None, None, b, "model", None, None)
        if name == "mamba_conv":         # (NS,AE,B,W-1,C)
            return P(None, None, b, None, "model")
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, cache_struct)
