"""Dry-run construction: ShapeDtypeStruct inputs + jit shardings for every
(architecture x input-shape) pair on a given mesh.

``build_dryrun(arch, shape, mesh)`` returns everything needed to
``jax.jit(fn, in_shardings=..., out_shardings=...).lower(*args)`` with NO
device allocation: parameter/optimizer/cache structures come from
``jax.eval_shape``; batches are ShapeDtypeStructs (weak-type-correct and
shardable).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, supports_shape
from repro.launch.sharding import (
    batch_axes,
    cache_pspecs,
    make_rules,
    opt_pspecs,
    param_pspecs,
    train_batch_pspecs,
)
from repro.models import Model
from repro.models.shardlib import use_sharding
from repro.training import AdamWConfig, init_adamw, make_train_step


class DryrunPlan(NamedTuple):
    fn: Callable
    args: tuple                    # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    rules: dict
    cfg: Any
    mode: str


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _act_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def input_specs(arch: str, shape_name: str, *, batch_override: int = 0,
                cfg=None, seq_override: int = 0) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this pair."""
    shape = INPUT_SHAPES[shape_name]
    cfg = cfg or get_config(arch, shape=shape_name)
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    out: dict[str, Any] = {}
    if shape.mode in ("train", "prefill"):
        out["tokens"] = _sds((b, s), jnp.int32)
        if cfg.kind == "encdec":
            out["embeds"] = _sds((b, cfg.n_audio_frames, cfg.d_model),
                                 _act_dtype(cfg))
        if cfg.kind == "vlm":
            out["embeds"] = _sds((b, cfg.n_image_tokens, cfg.d_model),
                                 _act_dtype(cfg))
        if shape.mode == "prefill":
            out["lens"] = _sds((b,), jnp.int32)
    else:  # decode: one new token against a seq_len cache
        out["tokens"] = _sds((b, 1), jnp.int32)
        out["pos"] = _sds((b,), jnp.int32)
    return out


def build_dryrun(arch: str, shape_name: str, mesh: Mesh,
                 *, batch_override: int = 0,
                 attn_mode: str = "head_dim", cfg_override=None,
                 seq_override: int = 0, optimized: bool = False) -> DryrunPlan:
    """``optimized=True`` applies the §Perf sharding scheme:
      O1 train/prefill: head-sharded attention when n_heads % 16 == 0
         (kills the per-chunk logits all-reduce of head_dim sharding);
      O2 serving (prefill/decode): no FSDP — weights replicate over "data"
         (no per-step weight all-gathers on the latency path);
      O3 decode: KV cache sequence dim sharded over "model"
         (flash-decoding partials; tiny stat psums instead of logits).
    """
    if not supports_shape(arch, shape_name):
        raise ValueError(f"{arch} skips {shape_name} (DESIGN.md §4)")
    shape = INPUT_SHAPES[shape_name]
    if seq_override:
        shape = dataclasses.replace(shape, seq_len=seq_override)
    cfg = cfg_override or get_config(arch, shape=shape_name)
    model = Model(cfg)
    b = batch_override or shape.global_batch
    s = shape.seq_len

    mode = shape.mode
    fsdp = True
    if optimized:
        if mode in ("train", "prefill"):
            if cfg.n_heads % mesh.shape["model"] == 0:
                attn_mode = "heads"                      # O1
            elif cfg.kind in ("dense", "moe", "vlm", "encdec"):
                attn_mode = "context"                    # O4 (vmapped q chunks)
        if mode in ("prefill", "decode"):
            fsdp = False                                 # O2

    shard_batch = b > 1
    rules = make_rules(cfg, mesh, shard_batch=shard_batch,
                       attn_mode=attn_mode)
    b_ax = batch_axes(mesh) if shard_batch else None

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_pspecs(params_struct, cfg, mesh, attn_mode=attn_mode,
                          fsdp=fsdp)
    batch_struct = input_specs(arch, shape_name, batch_override=b, cfg=cfg,
                               seq_override=shape.seq_len)

    if shape.mode == "train":
        opt_struct = jax.eval_shape(init_adamw, params_struct)
        ospecs = opt_pspecs(pspecs)
        bspecs = {
            k: P(*((b_ax,) + (None,) * (v.ndim - 1)))
            for k, v in batch_struct.items()
        }
        step = make_train_step(model, AdamWConfig())
        metric_specs = {"grad_norm": P(), "lr": P(), "loss": P()}
        return DryrunPlan(
            fn=step,
            args=(params_struct, opt_struct, batch_struct),
            in_shardings=(
                _named(mesh, pspecs), _named(mesh, ospecs),
                _named(mesh, bspecs),
            ),
            out_shardings=(
                _named(mesh, pspecs), _named(mesh, ospecs),
                _named(mesh, metric_specs),
            ),
            rules=rules, cfg=cfg, mode="train",
        )

    if shape.mode == "prefill":
        cache_len = s + (cfg.n_image_tokens if cfg.kind == "vlm" else 0)
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(None, b, cache_len)
        )
        cspecs = cache_pspecs(cfg, mesh, cache_struct,
                              shard_batch=shard_batch, shard_seq=False)
        bspecs = {
            k: P(*((b_ax,) + (None,) * (v.ndim - 1)))
            for k, v in batch_struct.items()
        }

        def prefill_fn(params, batch):
            return model.prefill(params, batch, cache_len=cache_len)

        return DryrunPlan(
            fn=prefill_fn,
            args=(params_struct, batch_struct),
            in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
            out_shardings=(
                _named(mesh, P(b_ax, None, rules["vocab"])),
                _named(mesh, cspecs),
            ),
            rules=rules, cfg=cfg, mode="prefill",
        )

    # decode: one token against a seq_len-deep cache
    cache_len = s
    cache_struct = jax.eval_shape(
        lambda: model.init_cache(None, b, cache_len)
    )
    # long-context decode with batch=1: shard the cache SEQUENCE dim over
    # the batch axes; optimized BATCHED decode shards it over "model" (O3).
    # O3 is NOT applied at batch=1: measured a 400x regression on
    # mixtral x long_500k (ring-buffer scatter across a model-sharded seq
    # dim lowers to per-step collective-permutes) — see §Perf iteration 3.
    shard_seq = (not shard_batch) or optimized
    cspecs = cache_pspecs(
        cfg, mesh, cache_struct,
        shard_batch=shard_batch, shard_seq=shard_seq,
        seq_axis="model" if (optimized and shard_batch) else "batch",
    )

    def decode_fn(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    return DryrunPlan(
        fn=decode_fn,
        args=(params_struct, cache_struct, batch_struct["tokens"],
              batch_struct["pos"]),
        in_shardings=(
            _named(mesh, pspecs), _named(mesh, cspecs),
            NamedSharding(mesh, P(b_ax, None)),
            NamedSharding(mesh, P(b_ax)),
        ),
        out_shardings=(
            _named(mesh, P(b_ax, None, rules["vocab"])),
            _named(mesh, cspecs),
        ),
        rules=rules, cfg=cfg, mode="decode",
    )


def lower_plan(plan: DryrunPlan, mesh: Mesh):
    """jit + lower under the mesh/rules contexts (no execution)."""
    jitted = jax.jit(
        plan.fn,
        in_shardings=plan.in_shardings,
        out_shardings=plan.out_shardings,
    )
    with mesh, use_sharding(mesh, plan.rules):
        return jitted.lower(*plan.args)
