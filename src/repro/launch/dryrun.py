import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
pair on the production meshes WITHOUT allocating real arrays.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json

Per pair it records: compile success, per-device memory analysis
(argument/output/temp/peak bytes), cost analysis (FLOPs, bytes accessed),
and the collective-bytes breakdown parsed from the optimized HLO — the
three §Roofline terms are derived from these (benchmarks/roofline.py).

The XLA_FLAGS line above MUST run before any jax import (jax locks the
device count at first init); keep it the first statement of this module.
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES, supports_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_dryrun, lower_plan

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    """Total bytes of all array shapes in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the optimized HLO.

    Counts each op once per HLO occurrence.  Ops inside while-loop bodies
    (layer scans) appear once in the text but execute n_layers times; the
    caller scales by trip count via the 'in_loop' flag heuristically — we
    report raw per-occurrence bytes plus occurrence counts here and let
    the roofline layer apply scan trip counts from the model config.
    """
    stats = {op: {"count": 0, "bytes": 0} for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w\.\-]+ = (.+?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", ls)
        if not m:
            continue
        result_type, op = m.groups()
        stats[op]["count"] += 1
        stats[op]["bytes"] += _shape_bytes(result_type)
    stats["total_bytes"] = sum(
        v["bytes"] for k, v in stats.items() if isinstance(v, dict)
    )
    stats["total_count"] = sum(
        v["count"] for k, v in stats.items() if isinstance(v, dict)
    )
    return stats


def run_pair(arch: str, shape: str, *, multi_pod: bool,
             verbose: bool = True, hlo_dir: str = "dryrun_hlo",
             optimized: bool = False) -> dict:
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "optimized": optimized}
    if not supports_shape(arch, shape):
        rec["status"] = "skipped"
        rec["reason"] = "long_500k not applicable (DESIGN.md §4)"
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        plan = build_dryrun(arch, shape, mesh, optimized=optimized)
        lowered = lower_plan(plan, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec.update(
            status="ok",
            mode=plan.mode,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0
                ),
            },
            cost={
                "flops": float(cost.get("flops", -1)),
                "bytes_accessed": float(cost.get("bytes accessed", -1)),
            },
            n_params=plan.cfg.n_params(),
            n_active_params=plan.cfg.n_active_params(),
        )
        hlo = compiled.as_text()
        rec["collectives"] = collective_stats(hlo)
        rec["hlo_bytes"] = len(hlo)
        if hlo_dir:
            import zstandard as zstd
            os.makedirs(hlo_dir, exist_ok=True)
            suffix = "_opt" if optimized else ""
            fname = (f"{arch}_{shape}_{rec['mesh']}{suffix}.hlo.zst"
                     .replace("/", "-"))
            with open(os.path.join(hlo_dir, fname), "wb") as f:
                f.write(zstd.ZstdCompressor(level=6).compress(hlo.encode()))
            rec["hlo_file"] = os.path.join(hlo_dir, fname)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    if verbose:
        mark = {"ok": "PASS", "fail": "FAIL", "skipped": "SKIP"}[rec["status"]]
        extra = ""
        if rec["status"] == "ok":
            gb = rec["memory"]["temp_bytes"] / 2**30
            extra = (f" mem_temp={gb:.2f}GiB flops={rec['cost']['flops']:.2e}"
                     f" coll={rec['collectives']['total_bytes']/2**30:.2f}GiB")
        if rec["status"] == "fail":
            extra = " " + rec["error"][:160]
        print(f"[{mark}] {arch} x {shape} ({rec['mesh']}) "
              f"{rec['wall_s']}s{extra}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x all shapes")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf sharding scheme (O1/O2/O3)")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_pair(arch, shape, multi_pod=mp,
                               optimized=args.optimized)
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_fail = sum(r["status"] == "fail" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"of {len(records)}")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
