"""Loop-aware HLO accounting for the roofline analysis.

``compiled.cost_analysis()`` counts each while-loop BODY once, but the
layer scan executes n_layers times (and the chunked-attention scans nest
inside it) — so raw cost_analysis under-reports FLOPs/bytes/collectives by
1-2 orders of magnitude for scanned models.  This module parses the
post-optimization HLO text into its computation graph, derives each while
loop's trip count from its condition, and aggregates:

  * flops            — 2 * prod(result dims) * contracted-size for dot ops
                       (+ convolutions counted via output*window);
  * bytes            — operand + result bytes at FUSION boundaries
                       (fusion-internal intermediates are virtual);
  * collective bytes — result bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute;

each scaled by the product of enclosing loop trip counts (recursively —
nested scans multiply).  All quantities are PER-DEVICE: the HLO is the
SPMD-partitioned per-device module.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OP_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)="
    r"(\{[^}]*\}|%?[\w\.\-]+)"
)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_list(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {c: 0 for c in COLLECTIVES}
    )
    coll_bytes_by: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES}
    )

    def add(self, other: "OpStats", scale: float = 1.0,
            include_bytes: bool = True) -> None:
        self.flops += other.flops * scale
        if include_bytes:
            self.bytes += other.bytes * scale
        self.coll_bytes += other.coll_bytes * scale
        for c in COLLECTIVES:
            self.coll_counts[c] += other.coll_counts[c] * scale
            self.coll_bytes_by[c] += other.coll_bytes_by[c] * scale


@dataclasses.dataclass
class Computation:
    name: str
    lines: list[str]
    local: OpStats = dataclasses.field(default_factory=OpStats)
    # (callee_name, kind) pairs; kind in {fusion, call, while, cond, reduce}
    calls: list[tuple[str, str, str]] = dataclasses.field(
        default_factory=list
    )  # (callee, kind, opname)


def _dot_flops(rest: str, symtab: dict) -> float:
    """rest: everything after '= ' for a dot op line.

    Depending on the XLA version, operand shapes are printed inline
    (``dot(f32[128,128]{1,0} %lhs, ...)``) or not (``dot(%lhs, ...)``);
    prefer the inline lhs shape and fall back to resolving the operand
    name through ``symtab`` (op name -> result type string).
    """
    shapes = _shape_list(rest.split(" dot(")[0])
    if not shapes:
        return 0.0
    result = shapes[0]
    lhs_dims: list[int] = []
    inner = re.search(r"dot\((.*)\)", rest)
    if inner:
        m_inline = re.match(r"\s*([a-z][a-z0-9]*)\[([0-9,]*)\]",
                            inner.group(1))
        if m_inline and m_inline.group(1) in _DTYPE_BYTES:
            lhs_dims = [int(d) for d in m_inline.group(2).split(",") if d]
    if not lhs_dims:
        marg = re.search(r"dot\((%[\w\.\-]+)", rest)
        if marg:
            lhs_type = symtab.get(marg.group(1).lstrip("%"), "")
            lhs_shapes = _shape_list(lhs_type)
            if lhs_shapes:
                lhs_dims = lhs_shapes[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
    contract = 1
    if m and m.group(1) and lhs_dims:
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    n_out = 1
    for d in result[1]:
        n_out *= d
    return 2.0 * n_out * contract


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(1), lines=[])
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
    return comps


def analyze_computation(comp: Computation) -> None:
    symtab = {}
    for line in comp.lines:
        m = _OP_RE.match(line)
        if m:
            symtab[m.group(1)] = m.group(2).split("(")[0]
    for line in comp.lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        opname, rest = m.groups()
        # op kind is the first word after the result type; find known verbs
        kind_m = re.search(
            r"\)?\s*(dot|convolution|fusion|while|conditional|call|"
            r"all-gather-start|all-gather|all-reduce-start|all-reduce|"
            r"reduce-scatter|all-to-all|collective-permute-start|"
            r"collective-permute|custom-call|reduce-window|reduce|sort|"
            r"scatter|gather|map|select-and-scatter)\(", rest
        )
        kind = kind_m.group(1) if kind_m else None

        if kind == "dot":
            comp.local.flops += _dot_flops(rest, symtab)
            comp.local.bytes += _bytes_of(rest.split(", lhs_")[0])
            # operand reads (resolved through the symbol table)
            for om in re.finditer(r"dot\(([^)]*)\)", rest):
                for nm in re.findall(r"%([\w\.\-]+)", om.group(1)):
                    comp.local.bytes += _bytes_of(symtab.get(nm, ""))
            continue
        if kind == "convolution":
            # rough: 2 * out elems * (window size * in features); window
            # parsing is brittle — count as 2*out*contract guess via shapes
            shapes = _shape_list(rest)
            if shapes:
                n_out = 1
                for d in shapes[0][1]:
                    n_out *= d
                comp.local.flops += 2.0 * n_out
            continue
        if kind in ("while", "conditional"):
            for attr in _CALL_ATTR_RE.finditer(rest):
                blob = attr.group(1)
                names = re.findall(r"%?([\w\.\-]+)", blob)
                attr_kind = attr.group(0).split("=")[0]
                for nm in names:
                    comp.calls.append((nm, attr_kind, opname))
            continue
        if kind == "fusion" or kind == "call":
            m2 = re.search(r"calls=%?([\w\.\-]+)", rest)
            if m2:
                comp.calls.append((m2.group(1), "calls", opname))
            # fusion boundary bytes: result + operands are materialized
            comp.local.bytes += _bytes_of(rest.split(" calls=")[0])
            continue
        started = None
        for c in COLLECTIVES:
            if kind and kind.startswith(c):
                started = c
                break
        if started:
            nbytes = _bytes_of(rest.split("(")[0])
            comp.local.coll_bytes += nbytes
            comp.local.coll_counts[started] += 1
            comp.local.coll_bytes_by[started] += nbytes
            comp.local.bytes += nbytes
            continue
        if kind in ("reduce", "reduce-window", "sort", "map", "scatter",
                    "gather", "select-and-scatter", "custom-call"):
            m2 = re.search(r"to_apply=%?([\w\.\-]+)", rest)
            if m2:
                comp.calls.append((m2.group(1), "to_apply", opname))
            comp.local.bytes += _bytes_of(rest.split("(")[0])
            continue
        # plain unfused compute ops contribute their result bytes.  Pure
        # layout/aliasing ops are EXCLUDED: the CPU scheduler materializes
        # copies of whole loop-carried caches per iteration that a TPU
        # compile aliases in place — counting them would swamp the real
        # HBM traffic (measured 100x inflation on decode shapes).
        skip = ("copy(", "convert(", "bitcast(", "transpose(", "reshape(",
                "parameter(", "get-tuple-element(", "tuple(", "constant(",
                "broadcast(", "iota(", "copy-start(", "copy-done(",
                "after-all(", "partition-id(")
        if kind is None and ("=" in line) and "[" in rest:
            body = rest.split("{", 1)[0]
            if not any(k in body for k in skip):
                comp.local.bytes += _bytes_of(rest.split("(")[0])


def _trip_count(cond: Computation) -> int:
    """Max s32 constant in the loop condition ~ the trip count."""
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def total_stats(text: str) -> OpStats:
    comps = parse_hlo(text)
    for c in comps.values():
        analyze_computation(c)

    # resolve while conditions -> trip counts
    memo: dict[str, OpStats] = {}

    def resolve(name: str, seen: frozenset) -> OpStats:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out = OpStats()
        if comp is None or name in seen:
            return out
        seen = seen | {name}
        out.add(comp.local)
        # group this computation's while ops: body+condition pairs share op
        whiles: dict[str, dict[str, str]] = {}
        for callee, kind, opname in comp.calls:
            if kind in ("body", "condition"):
                whiles.setdefault(opname, {})[kind] = callee
            elif kind in ("calls", "to_apply", "branch_computations"):
                # fusion-internal tensors are virtual: take flops and
                # collectives from inside, but NOT bytes (the caller already
                # counted the fusion boundary)
                out.add(resolve(callee, seen), include_bytes=False)
        for opname, pair in whiles.items():
            body = pair.get("body")
            cond = pair.get("condition")
            trip = _trip_count(comps[cond]) if cond in comps else 1
            if body:
                out.add(resolve(body, seen), scale=trip)
            if cond in comps:
                out.add(resolve(cond, seen), scale=trip)
        memo[name] = out
        return out

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: the computation with the most lines
        entry = max(comps, key=lambda k: len(comps[k].lines))
    return resolve(entry, frozenset())


def analyze_file(path: str) -> OpStats:
    import zstandard as zstd

    with open(path, "rb") as f:
        data = f.read()
    if path.endswith(".zst"):
        data = zstd.ZstdDecompressor().decompress(data)
    return total_stats(data.decode())
