"""Continuous-batching serving engine over the JAX model zoo.

This is the real end-to-end path: actual model prefill/decode on device,
slot-based batched decoding, paged KV-block accounting, agent-level
scheduling via the SAME scheduler objects as the simulator, vLLM's
non-preemptive semantics (App. C):

  * waiting requests never preempt running inferences;
  * when the block pool cannot host a new decode token, the running
    inference with the WORST scheduler key is swapped out (its KV rows are
    copied to host memory and its blocks freed);
  * the swapped queue outranks the waiting queue for (re-)admission, and
    while it is non-empty no new request is admitted.

Time is measured in engine iterations (one batched decode step == 1
iteration; a prefill costs ceil(prompt / prefill_chunk) iterations),
matching the cost model's token-iteration units (service_rate=1).

Agents arrive *online*: ``submit_agent`` may be called at any point — before
the first ``step()``, between steps, or with ``arrival_iter`` in the future,
in which case the agent sits in a pending heap until the engine clock
reaches it.  ``step()`` is re-entrant with submission, so a driver can
interleave ``run(until=...)`` with new arrivals; ``repro.api.AgentService``
builds its online-arrival serving loop on exactly this.

An optional ``listener`` receives lifecycle callbacks (``on_arrival``,
``on_admit``, ``on_swap_out``, ``on_swap_in``, ``on_token``,
``on_stage_complete``, ``on_agent_complete``) — duck-typed so this module
stays independent of the API layer that consumes the events.

Device-resident hot path (PR 4)
-------------------------------
The per-iteration work is batch-oriented and stays on device; the frozen
pre-rewrite core (``repro.engine.reference.ReferenceServeEngine``) is the
behavioural oracle that pins these rules:

* **Fused decode windows.**  Greedy sampling (argmax) is fused into the
  jitted decode; ``slot_last_tok``/``slot_pos`` live on device (host
  mirrors are kept for bookkeeping and rebuilt only when slot occupancy
  changes).  Whenever the next K iterations are provably event-free — no
  completion, no pending arrival due, and every running sequence's block
  growth fits the pool — the engine runs K decode steps in ONE jitted
  ``lax.scan`` and fetches the K x B sampled tokens with a single
  device->host transfer, then replays the per-token bookkeeping (events,
  scheduler service deals, allocator growth) host-side in exact per-step
  order.  K is bucketed to powers of two (<= ``max_window``) to bound
  compilations.  Closed-loop agents (``EngineAgent.closed_loop``, set for
  specs with a ``next_stage`` callback) bound every window at their stage
  boundaries: a listener callback may append a follow-up stage at any
  completion (``append_stage``), which the sizer could otherwise not
  foresee.
* **Donated buffers.**  The KV cache and the slot tensors are donated to
  every jitted hot-path call (decode window, prefill write, swap-in
  scatter), so XLA updates them in place instead of rebuilding the full
  cache per call.  Never reuse ``self.cache`` / ``self._d_*`` across a
  call that donates them — always rebind from the outputs.
* **Slot-wise swaps + staging pool.**  Swap-out gathers ONE slot's rows
  (jitted ``big[:, slot]``) into a host staging buffer drawn from a free
  pool (``self._staging``) so repeated swap cycles don't thrash large host
  allocations; swap-in scatters the staged rows back through a jitted
  donated ``big.at[:, slot].set``.
* **Batched bucketed prefill.**  One admission pass admits up to
  ``max_batch`` waiting requests and runs ONE multi-sequence prefill
  (padded to the group's 64-token bucket, lens-masked, chunked by
  ``prefill_chunk`` through ``Model.prefill_chunked``), scattering every
  admitted slot's cache rows in the same jitted call that computes the
  first sampled tokens.
* **Consistent admission clock.**  Prefill iteration costs
  (``ceil(p / prefill_chunk) - 1`` each) are accumulated and applied to
  ``self.now`` ONCE at the end of the admission pass, so every admission
  decision, scheduler key evaluation, and ``on_admit`` stamp within a pass
  sees the same ``now``.  (The retired per-request mid-pass bump changed
  ``now`` between admissions; scheduler keys must not read the clock, but
  the stamps were inconsistent.)  Total clock advance per pass is
  unchanged — completion iterations are bit-identical to the reference.
* **O(log n) swap-victim selection.**  Running requests live in a third
  ``OrderedQueue`` keyed like the waiting/swapped queues; the victim is
  ``pop_right()`` (worst key) instead of an O(running) ``max()`` scan, and
  swapped membership is an O(1) rid-set.  Scheduler ``Request`` views and
  their ``kv_token_time`` costs are cached per request, so key evaluation
  stops allocating.
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import InferenceSpec, kv_token_time
from repro.core.queueing import OrderedQueue
from repro.core.schedulers import AgentScheduler, Request
from repro.kvcache.allocator import BlockAllocator
from repro.kvcache.prefix import PrefixAwareAllocator
from repro.models import Model


# --------------------------------------------------------------------------
# Jitted hot-path kernels.  Module-level with the (frozen, hashable) Model
# as a static argument so the XLA executable cache is shared across engine
# instances — a benchmark sweep or a replicated fleet compiles each shape
# once, not once per engine.  Donated buffers: callers must rebind cache /
# slot tensors from the outputs and never touch the inputs again.
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=(3, 4))
def _decode_window_jit(model, k: int, params, cache, state):
    """K fused decode iterations: model.decode + greedy argmax + masked
    slot advance, scanned on device.  ``state`` is the stacked (3, B)
    int32 slot tensor [last_tok; pos; remaining]: one donated buffer, one
    upload when slot occupancy changes.  A slot whose remaining budget
    runs out mid-window freezes in place — exactly what the reference
    engine's stale freed-slot rows look like — so a window may span final
    completions.  Returns the K x B sampled tokens — the ONLY thing the
    host needs per window."""

    def body(carry, _):
        cache, state = carry
        last_tok, pos, rem = state
        logits, cache = model.decode(params, cache, last_tok[:, None], pos)
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        live = rem > 0
        state = jnp.stack([
            jnp.where(live, nxt, last_tok),
            jnp.where(live, pos + 1, pos),
            rem - live.astype(rem.dtype),
        ])
        return (cache, state), nxt

    (cache, state), toks = jax.lax.scan(
        body, (cache, state), None, length=k
    )
    return cache, state, toks


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(4, 5))
def _fused_window_jit(model, k: int, chunk: int, params, cache, state,
                      pf_tokens, pf_meta):
    """K fused decode+prefill iterations: each scanned step advances all B
    decode slots one token (same body as ``_decode_window_jit``) AND runs
    one bounded lens-masked prefill slice of the single admitted
    (prefilling) slot through ``Model.prefill_slice``.

    ``pf_tokens``: (K, chunk) int32 prompt slices (zero-padded);
    ``pf_meta``: (3,) int32 [slot, start0, total] — the prefilling cache
    row, the first slice's absolute write offset, and the full prompt
    length.  The prefilling slot rides the decode batch frozen (its
    ``rem`` row is 0) but its ``pos`` row is overridden to chase the next
    slice start: step i's frozen-slot decode garbage lands at
    ``start0 + i*chunk`` — exactly the rows the same step's slice
    immediately overwrites — and the carried-out ``pos`` equals the next
    window's ``start0``, so consecutive fused windows chain without a
    host round-trip.

    Returns the (K, B+1) token matrix: columns 0..B-1 are the decode
    samples, column B is the prefill slot's argmax at the prompt's final
    position — valid only at the step whose slice exhausts the prompt
    (the request's first token; garbage at earlier steps).  Still ONE
    device->host transfer per window."""
    slot, start0, total = pf_meta[0], pf_meta[1], pf_meta[2]
    n_slots = state.shape[1]
    is_pf = jnp.arange(n_slots, dtype=jnp.int32) == slot

    def body(carry, xs):
        cache, state = carry
        toks_slice, i = xs
        last_tok, pos, rem = state
        logits, cache = model.decode(params, cache, last_tok[:, None], pos)
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        live = rem > 0
        new_pos = jnp.where(live, pos + 1, pos)
        new_pos = jnp.where(is_pf, start0 + (i + 1) * chunk, new_pos)
        state = jnp.stack([
            jnp.where(live, nxt, last_tok),
            new_pos,
            rem - live.astype(rem.dtype),
        ])
        pf_logits, cache = model.prefill_slice(
            params, cache, toks_slice, slot, start0 + i * chunk, total
        )
        pf_tok = jnp.argmax(pf_logits, axis=-1).astype(jnp.int32)
        return (cache, state), jnp.concatenate([nxt, pf_tok[None]])

    (cache, state), toks = jax.lax.scan(
        body, (cache, state), (pf_tokens, jnp.arange(k, dtype=jnp.int32))
    )
    return cache, state, toks


@functools.partial(jax.jit, donate_argnums=(0,))
def _clear_slot_kvpos_jit(cache, slot):
    """Invalidate one slot's attention rows (``kv_pos = -1``) ahead of a
    fused prefill: the slices only write the prompt's own positions, so a
    reused slot's stale-but-valid rows from its previous occupant must be
    masked out first (the batched ``_prefill_write_jit`` path instead
    overwrites the whole slot, lens-masked)."""
    return dict(cache, kv_pos=cache["kv_pos"].at[:, slot].set(-1))


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(4,))
def _prefill_write_jit(model, cache_len: int, chunk: int, params, cache,
                       tokens, lens, slots):
    """Batched (chunked) prefill + first-token argmax + scatter of every
    admitted slot's cache rows, in one dispatch.  ``slots`` may contain
    out-of-bounds padding entries (batch padded to a power of two to bound
    compilations) — ``mode="drop"`` discards their rows."""
    logits, small = model.prefill_chunked(
        params, {"tokens": tokens, "lens": lens},
        cache_len=cache_len, chunk=chunk,
    )

    def write(big, sm):
        if big.ndim >= 2 and sm.shape[0] == big.shape[0]:
            # layer-stacked tensors (L, B, ...): scatter rows `slots`
            return big.at[:, slots].set(sm.astype(big.dtype), mode="drop")
        return big

    cache = jax.tree.map(write, cache, small)
    nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
    return cache, nxt


@jax.jit
def _gather_slot_jit(cache, slot):
    """One slot's cache rows (the swap-out unit), gathered on device."""
    return jax.tree.map(lambda big: big[:, slot], cache)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_slot_jit(cache, small, slot):
    """Write one slot's staged rows back into the (donated) cache."""
    return jax.tree.map(
        lambda big, sm: big.at[:, slot].set(sm), cache, small
    )


@dataclasses.dataclass
class EngineRequest:
    """One inference task: prompt tokens + a decode budget."""

    agent_id: int
    rid: int
    prompt: np.ndarray             # (p,) int32
    max_new_tokens: int
    submit_iter: int = 0
    #: expected cached-prefix length (engine-scale tokens) from workload
    #: metadata — a STATIC scheduler hint (locality_fair reads it through
    #: ``Request.cached_prefix``); keys must not query the live allocator
    cached_hint: float = 0.0
    # runtime
    slot: int = -1
    generated: int = 0
    done: bool = False
    #: measured prefix-cache hit at admission (engine-scale tokens)
    cached_tokens: int = 0
    swapped_kv: Any = None         # host copy when swapped out
    _last_tok: int = 0
    _sched_req: Optional[Request] = dataclasses.field(
        default=None, repr=False
    )

    @property
    def spec(self) -> InferenceSpec:
        return InferenceSpec(len(self.prompt), self.max_new_tokens)

    def to_sched_request(self) -> Request:
        """Scheduler view of this request — built ONCE and cached.

        Every field the built-in policies read (spec, submit time,
        predicted cost) is immutable after submission, and ``kv_token_time``
        is the expensive part; caching makes a key evaluation a couple of
        attribute loads instead of a dataclass + cost-model allocation.
        """
        if self._sched_req is None:
            self._sched_req = Request(
                agent_id=self.agent_id,
                rid=self.rid,
                spec=self.spec,
                submit_time=float(self.submit_iter),
                pred_cost=kv_token_time(len(self.prompt), self.max_new_tokens),
                cached_prefix=float(self.cached_hint),
            )
        return self._sched_req


@dataclasses.dataclass
class _FusedPrefill:
    """The single in-flight fused prefill (``fused_prefill=True`` only).

    The request holds a slot and its blocks (all allocated at admission)
    but is NOT in ``slot_req`` or the running queue until its last slice
    lands — it cannot decode, be a swap victim, or complete while
    prefilling.  ``written`` counts K/V rows already resident (starts at
    the prefix-cache hit); the remaining slices cover
    ``[written, total)``.
    """

    req: EngineRequest
    slot: int
    total: int          # len(prompt)
    written: int        # rows already written (prefix hit + done slices)


@dataclasses.dataclass
class EngineAgent:
    agent_id: int
    arrival_iter: int
    stages: list[list[tuple[np.ndarray, int]]]  # stage -> [(prompt, d)]
    predicted_cost: float
    #: closed-loop client: a listener callback may append stages at any
    #: stage boundary (``append_stage``), so fused decode windows must end
    #: at EVERY stage completion of this agent — the window sizer cannot
    #: prove a "final" completion schedules nothing when a callback can
    #: still submit work there
    closed_loop: bool = False
    #: optional per-stage expected cached-prefix hints (engine-scale
    #: tokens), aligned with ``stages``; entries may be None
    hints: Optional[list] = None
    #: per-stage think-time delays in ITERATIONS (PR 9), aligned with
    #: ``stages``: a positive entry suspends the agent that long before
    #: the stage submits (``None``: never)
    resume_delays: Optional[list] = None
    # runtime
    next_stage: int = 0
    live: int = 0
    finish_iter: int = -1


class EngineStalledError(RuntimeError):
    """``run_until_idle`` hit ``max_iters`` before draining.

    Carries the partial results so callers can post-mortem the stall:
    ``completions`` and ``metrics`` are snapshots of the engine state at the
    moment it gave up; the message itself describes queue depths, pool
    occupancy, and per-agent live inference counts.
    """

    def __init__(self, msg: str, completions: dict[int, int], metrics: dict):
        super().__init__(msg)
        self.completions = completions
        self.metrics = metrics


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        scheduler: AgentScheduler,
        *,
        pool_tokens: int = 4096,
        block_size: int = 16,
        max_batch: int = 8,
        cache_len: int = 512,
        prefill_chunk: int = 512,
        max_window: int = 32,
        listener: Any = None,
        prefix_cache: bool = False,
        fused_prefill: bool = False,
        admission_watermark: Any = None,
        suspend_retention: str = "hold",
    ):
        self.model = model
        self.params = params
        self.sched = scheduler
        self.listener = listener
        #: prefix-aware KV reuse (PR 6): admission looks up each prompt's
        #: cached full-block prefix, charges only the uncached suffix to
        #: prefill clock cost + scheduler service, and keeps released
        #: prompt blocks matchable until evicted.  Off (the default) the
        #: engine builds the plain allocator and is bit-identical to the
        #: pre-cache behaviour.
        self.prefix_cache = bool(prefix_cache)
        #: fused prefill-in-window (PR 7): admission claims a slot and its
        #: blocks at ZERO clock cost, then the prompt's uncached suffix is
        #: prefilled one bounded ``prefill_chunk`` slice per iteration
        #: INSIDE the fused decode windows (``_fused_window_jit``), so
        #: running decoders keep producing tokens while a prompt streams
        #: in instead of stalling ``ceil(suffix/chunk)-1`` iterations at
        #: every admission.  One fused prefill is in flight at a time;
        #: windows end exactly at slice exhaustion (the new ``_window_size``
        #: trigger — that is the first instant admission can become
        #: possible again).  Off (the default) no fused code path runs and
        #: the engine stays bit-identical to ``engine/reference.py``.
        self.fused_prefill = bool(fused_prefill)
        if self.fused_prefill:
            ring = bool(model.cfg.sliding_window) and min(
                cache_len, model.cfg.sliding_window
            ) < cache_len
            if model.cfg.kind not in ("dense", "moe", "vlm") or ring:
                raise ValueError(
                    "fused_prefill=True needs a full-cache attention "
                    f"family (dense/moe/vlm, no ring buffer); got "
                    f"kind={model.cfg.kind!r} ring={ring}"
                )
        self._pf: Optional[_FusedPrefill] = None
        alloc_cls = PrefixAwareAllocator if prefix_cache else BlockAllocator
        self.alloc = alloc_cls(pool_tokens, block_size)
        #: watermark admission control (PR 8): ``(low_frac, high_frac)``
        #: of the block pool.  While anything occupies a slot (or a fused
        #: prefill is in flight), a NEW admission that would lift block
        #: usage above the high watermark is deferred, and once gated the
        #: gate stays shut until usage drains to the low watermark
        #: (hysteresis) — the pool never enters the recurring swap-thrash
        #: regime just to squeeze one more prompt in.  Swapped
        #: re-admissions are never gated (their blocks hold paged state),
        #: and an idle pool bypasses the gate (progress guarantee).
        #: Strictly flag-gated: ``None`` leaves every admission path
        #: bit-identical to the frozen reference engine.
        if admission_watermark is not None:
            low, high = admission_watermark
            if not (0.0 < low <= high <= 1.0):
                raise ValueError(
                    f"admission_watermark must satisfy 0 < low <= high <= 1,"
                    f" got {admission_watermark!r}"
                )
            nb = self.alloc.n_blocks
            self._wm = (low * nb, high * nb)
        else:
            self._wm = None
        self._wm_gated = False
        self._wm_emitted: set[int] = set()
        #: suspended-agent KV retention (PR 9): a closed-loop stage
        #: appended with ``resume_delay`` iterations of think time does
        #: not submit at its stage boundary — the agent suspends, holding
        #: no decode slot, and the completed stage's final request falls
        #: under this policy: ``hold`` keeps its blocks allocated (with
        #: the prefix cache they stay pinned in the radix index, so the
        #: next turn's prompt is a guaranteed match), ``spill`` copies
        #: the slot's rows to a host staging buffer and releases the
        #: blocks, ``drop`` releases outright (still matchable under the
        #: prefix-aware allocator until evicted).  Under memory pressure
        #: held blocks are released (``_escalate_held``) BEFORE any
        #: running sequence is swapped out.  Strictly flag-gated: with no
        #: suspensions every path is bit-identical to the frozen
        #: reference engine.
        if suspend_retention not in ("hold", "spill", "drop"):
            raise ValueError(
                f"suspend_retention must be 'hold', 'spill' or 'drop',"
                f" got {suspend_retention!r}"
            )
        self.suspend_retention = suspend_retention
        # scheduled resumes: (resume_iter, seq, EngineAgent) min-heap;
        # _held maps a suspended agent to the rid whose blocks it pins
        # (insertion order == suspension order, the escalation order)
        self._resumes: list[tuple[int, int, EngineAgent]] = []
        self._rseq = 0
        self._held: dict[int, int] = {}
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.prefill_chunk = prefill_chunk
        self.max_window = max(1, int(max_window))

        self.cache = model.init_cache(params, max_batch, cache_len)
        self.slot_free = list(range(max_batch))
        self.slot_req: dict[int, EngineRequest] = {}
        # host mirrors of the device-resident slot tensors: authoritative
        # for bookkeeping (swap-out snapshots, stall reports) and the
        # source for rebuilding the device copies when occupancy changes
        self.slot_last_tok = np.zeros(max_batch, np.int32)
        self.slot_pos = np.zeros(max_batch, np.int32)
        self._d_state = jnp.zeros((3, max_batch), jnp.int32)
        self._slots_stale = True   # device copy needs a rebuild

        # waiting/swapped/running share the OrderedQueue (repro.core.
        # queueing): static-key policies keep them sorted by construction;
        # agent-keyed dynamic policies (VTC/SRJF) get grouped invalidation
        # (only the freshly-serviced agents' requests reposition per
        # admission pass); other dynamic policies re-sort lazily when the
        # scheduler's version counter moves.  The running queue orders the
        # in-flight requests by the same key so the swap victim (WORST key)
        # is its tail — O(log n) per eviction instead of an O(n) max scan.
        self._grouped = scheduler.dynamic and getattr(
            scheduler, "agent_keyed", False
        )
        self._dirty_agents: set[int] = set()
        _gf = (lambda req: req.agent_id) if self._grouped else None
        self.waiting: OrderedQueue = OrderedQueue(
            self._key, dynamic=scheduler.dynamic, group_fn=_gf
        )
        self.swapped: OrderedQueue = OrderedQueue(
            self._key, dynamic=scheduler.dynamic, group_fn=_gf
        )
        self.running: OrderedQueue = OrderedQueue(
            self._key, dynamic=scheduler.dynamic, group_fn=_gf
        )
        self._swapped_rids: set[int] = set()
        self._staging: list[Any] = []   # free host KV slot buffers
        self.agents: dict[int, EngineAgent] = {}
        # future arrivals: (arrival_iter, submit order, agent) min-heap
        self.pending: list[tuple[int, int, EngineAgent]] = []
        self.now = 0               # iteration counter
        self.completions: dict[int, int] = {}   # agent -> finish iter
        # re-entrancy guards (listener rule): _in_run covers the drivers,
        # _in_step catches a callback re-entering step() itself
        self._in_run = False
        self._in_step = False
        self._rid = 0
        self._submit_seq = 0
        self.metrics = {"prefills": 0, "decode_steps": 0, "swaps": 0,
                        "tokens": 0, "sorts": 0, "key_evals": 0,
                        "host_syncs": 0, "windows": 0,
                        "prefill_tokens_saved": 0, "prefix_hits": 0,
                        "fused_slices": 0, "admission_deferrals": 0,
                        "suspensions": 0, "resumes": 0,
                        "suspend_spills": 0}
        # per-agent prefix-cache accounting (engine-scale tokens)
        self.agent_prefill_tokens: dict[int, int] = {}
        self.agent_hit_tokens: dict[int, int] = {}

    # -------------------------------------------------------------- warmup

    def warmup(self, prompt_buckets: tuple[int, ...] = (64,)) -> None:
        """Pre-compile the jitted hot path so serving never stalls on XLA
        mid-run: every power-of-two decode window up to ``max_window``,
        the batched prefill programs for the given 64-token prompt buckets
        (every power-of-two batch pad), and the slot gather/scatter pair.
        Recurrent families (ssm/hybrid/encdec) prefill at exact prompt
        lengths, which warmup cannot know — their first admission per
        distinct length still compiles lazily; only the attention-cache
        families get fully precompiled prefills.

        Runs the real programs against the engine's own (donated) buffers:
        with no running slots the masked slot state is a no-op and the
        prefill scatter targets only out-of-bounds (dropped) rows, so the
        engine's observable state — clock, queues, metrics — is untouched.
        Call before the first ``step()`` (or never: compilation then
        happens lazily on first use, per shape).
        """
        if self.slot_req or self.busy:
            raise RuntimeError("warmup must run on an idle engine")
        k = 1
        while k <= self.max_window:
            self.cache, self._d_state, toks = _decode_window_jit(
                self.model, k, self.params, self.cache, self._d_state
            )
            jax.block_until_ready(toks)
            if self.fused_prefill:
                # fused windows: the dummy prefill targets the OOB slot
                # (scatter-dropped) and no slot is live, so state/cache are
                # untouched beyond one garbage row the first admission
                # clears or overwrites
                pf_tokens = jnp.zeros((k, self.prefill_chunk), jnp.int32)
                pf_meta = jnp.array([self.max_batch, 0, 1], jnp.int32)
                self.cache, self._d_state, toks = _fused_window_jit(
                    self.model, k, self.prefill_chunk, self.params,
                    self.cache, self._d_state, pf_tokens, pf_meta,
                )
                jax.block_until_ready(toks)
            k <<= 1
        if self.fused_prefill:
            self.cache = _clear_slot_kvpos_jit(self.cache, 0)
            jax.block_until_ready(self.cache["kv_pos"])
            self._slots_stale = True
        batched_ok = self.model.cfg.kind in ("dense", "moe", "vlm")
        # cover the pow2 CEILING of max_batch: _prefill_batch pads a
        # k-request pass to 1 << (k-1).bit_length(), which exceeds
        # max_batch itself when max_batch is not a power of two
        pad_cap = (
            1 << (self.max_batch - 1).bit_length() if batched_ok else 1
        )
        k_pad = 1
        while k_pad <= pad_cap:
            for bucket in prompt_buckets:
                toks = jnp.zeros((k_pad, bucket), jnp.int32)
                lens = jnp.ones((k_pad,), jnp.int32)
                slots = jnp.full((k_pad,), self.max_batch, jnp.int32)
                self.cache, nxt = _prefill_write_jit(
                    self.model, self.cache_len, self.prefill_chunk,
                    self.params, self.cache, toks, lens, slots,
                )
                jax.block_until_ready(nxt)
            k_pad <<= 1
        small = _gather_slot_jit(self.cache, 0)
        host = jax.tree.map(np.array, small)
        self.cache = _scatter_slot_jit(self.cache, host, 0)
        jax.block_until_ready(self.cache)
        self._slots_stale = True

    # ------------------------------------------------------------- events

    def hit_fractions(self) -> dict[int, float]:
        """Per-agent prefix-cache hit fraction: cached / total prefill
        tokens over every admission of the agent's requests (0.0 without
        hits; empty with the cache off and no admissions)."""
        return {
            aid: self.agent_hit_tokens.get(aid, 0) / tot
            for aid, tot in self.agent_prefill_tokens.items()
            if tot > 0
        }

    def _emit(self, event: str, *args) -> None:
        if self.listener is not None:
            fn = getattr(self.listener, event, None)
            if fn is not None:
                fn(*args)

    # ------------------------------------------------------------- submit

    def submit_agent(self, agent: EngineAgent) -> None:
        """Register an agent with the engine.

        If ``agent.arrival_iter`` lies in the future the agent is parked in
        the pending heap and released by ``step()`` when the clock reaches
        it — this is how online (non-upfront) arrivals are driven.  An
        arrival at or before ``self.now`` takes effect immediately, which
        matches the old submit-everything-upfront behaviour.
        """
        self._validate_stages(agent)
        if agent.arrival_iter > self.now:
            heapq.heappush(
                self.pending, (agent.arrival_iter, self._submit_seq, agent)
            )
            self._submit_seq += 1
            return
        self._arrive(agent)

    def _validate_stages(self, agent: EngineAgent) -> None:
        for stage in agent.stages:
            for prompt, d in stage:
                if len(prompt) + int(d) + 1 > self.cache_len:
                    raise ValueError(
                        f"request p={len(prompt)} d={d} exceeds cache_len "
                        f"{self.cache_len}"
                    )

    def _arrive(self, agent: EngineAgent) -> None:
        agent.arrival_iter = self.now
        self.agents[agent.agent_id] = agent
        self.sched.on_agent_arrival(
            agent.agent_id, float(self.now), agent.predicted_cost
        )
        self._emit("on_arrival", agent.agent_id, float(self.now))
        self._submit_stage(agent)

    def _release_arrivals(self) -> None:
        while self.pending and self.pending[0][0] <= self.now:
            _, _, agent = heapq.heappop(self.pending)
            self._arrive(agent)

    def _release_resumes(self) -> None:
        """Wake suspended agents whose think time has elapsed (PR 9)."""
        while self._resumes and self._resumes[0][0] <= self.now:
            _, _, agent = heapq.heappop(self._resumes)
            aid = agent.agent_id
            rid = self._held.pop(aid, None)
            if rid is not None:
                # hold retention: the pinned stage KV served its purpose
                # (the prefix cache re-matches it during admission of the
                # next stage) — release it so admission sees the blocks
                self.alloc.release(rid)
            self.metrics["resumes"] += 1
            self.sched.on_agent_resume(aid, float(self.now))
            self._emit("on_resume", aid, float(self.now))
            self._submit_stage(agent)

    def append_stage(
        self, agent_id: int, stage: list[tuple[np.ndarray, int]],
        hints: Optional[list[float]] = None,
        resume_delay: Optional[int] = None,
    ) -> None:
        """Append one follow-up stage to a live agent (closed-loop).

        May be called from inside an ``on_stage_complete`` listener
        callback — the engine emits it BEFORE the stage-exhaustion check
        in ``_complete``, so the appended stage keeps the agent alive and
        its requests enter the waiting queue in the same iteration.  The
        callback must not re-enter ``run``/``run_until_idle``/``step``.

        Requires ``agent.closed_loop`` (set automatically by the
        ``EngineBackend`` for specs with a ``next_stage`` callback): the
        window sizer only ends fused decode windows at stage boundaries
        of closed-loop agents, so appending to an agent submitted without
        the flag would let a window span its "final" completion and defer
        the appended stage by up to the window width — silently breaking
        the same-iteration cadence this method promises.
        """
        agent = self.agents.get(agent_id)
        if agent is None or agent.finish_iter >= 0:
            raise ValueError(f"agent {agent_id} is not live")
        if not agent.closed_loop:
            raise ValueError(
                f"agent {agent_id} was submitted without closed_loop=True; "
                "fused decode windows do not end at its stage boundaries, "
                "so appended stages would miss the same-iteration cadence"
            )
        for prompt, d in stage:
            if len(prompt) + int(d) + 1 > self.cache_len:
                raise ValueError(
                    f"request p={len(prompt)} d={d} exceeds cache_len "
                    f"{self.cache_len}"
                )
        if resume_delay is not None and int(resume_delay) > 0:
            # think time (PR 9): suspend the agent ``resume_delay``
            # iterations before this stage submits
            if agent.resume_delays is None:
                agent.resume_delays = [None] * len(agent.stages)
            while len(agent.resume_delays) < len(agent.stages):
                agent.resume_delays.append(None)
            agent.resume_delays.append(int(resume_delay))
        agent.stages.append(
            [(np.asarray(p, np.int32), int(d)) for p, d in stage]
        )
        if hints is not None:
            if agent.hints is None:
                agent.hints = [None] * (len(agent.stages) - 1)
            while len(agent.hints) < len(agent.stages) - 1:
                agent.hints.append(None)
            agent.hints.append(list(hints))

    def cancel(self, agent_id: int) -> bool:
        """Withdraw a never-admitted agent (fleet work stealing, PR 10).

        Mirrors ``ClusterSim.cancel``: legal only while the agent's whole
        opening stage still sits in the waiting queue (or its arrival is
        still pending) — a request that was ever admitted, swapped,
        mid-prefill, or suspended makes the agent ineligible and the call
        returns False without touching engine state.  Silent: no events,
        no completion entry; the fleet re-submits the agent elsewhere and
        emits the migration itself.
        """
        for i, (_, _, a) in enumerate(self.pending):
            if a.agent_id == agent_id:
                self.pending.pop(i)
                heapq.heapify(self.pending)
                return True
        agent = self.agents.get(agent_id)
        if agent is None or agent.finish_iter >= 0:
            return False
        if agent.next_stage != 1:
            return False
        if agent_id in self._held or any(
            a.agent_id == agent_id for _, _, a in self._resumes
        ):
            return False
        if any(
            req.agent_id == agent_id for req in self.slot_req.values()
        ) or any(req.agent_id == agent_id for req in self.swapped):
            return False
        if agent.live != len(agent.stages[0]):
            return False         # some opening request already ran
        reqs = [req for req in self.waiting if req.agent_id == agent_id]
        if len(reqs) != agent.live:
            return False         # a request is admitted / mid-prefill
        for req in reqs:
            self.waiting.remove(req)
        del self.agents[agent_id]
        self.sched.on_agent_cancel(agent_id, float(self.now))
        return True

    def _submit_stage(self, agent: EngineAgent) -> None:
        stage = agent.stages[agent.next_stage]
        hints = None
        if agent.hints is not None and agent.next_stage < len(agent.hints):
            hints = agent.hints[agent.next_stage]
        agent.next_stage += 1
        agent.live += len(stage)
        for i, (prompt, d) in enumerate(stage):
            self.waiting.push(
                EngineRequest(
                    agent_id=agent.agent_id,
                    rid=self._rid,
                    prompt=np.asarray(prompt, np.int32),
                    max_new_tokens=int(d),
                    submit_iter=self.now,
                    cached_hint=(
                        float(hints[i])
                        if hints is not None and i < len(hints) else 0.0
                    ),
                )
            )
            self._rid += 1

    # ----------------------------------------------------------- stepping

    def step(self, limit: Optional[int] = None) -> int:
        """Advance the engine: release arrivals, admit, decode.

        Returns the number of iterations consumed (>= 1): when the next K
        iterations are provably event-free the decode runs as one fused
        K-step window (see module doc) and the clock advances by K.
        ``limit`` caps the advance (``run`` passes ``until - now``).
        """
        if self._in_step:
            raise RuntimeError("re-entrant step() from a listener callback")
        self._in_step = True
        try:
            start = self.now
            self._release_arrivals()
            self._release_resumes()
            self._admit()
            if limit is not None:
                # the admission pass may itself advance the clock (chunked
                # prefill cost); shrink the decode budget so a fused window
                # never runs past the caller's `until` horizon
                limit = max(1, int(limit) - (self.now - start))
            k = self._decode_once(limit)
            self.now += 1
            return k
        finally:
            self._in_step = False

    @property
    def busy(self) -> bool:
        """Work is queued or running.  Pending future arrivals and
        scheduled resumes are excluded: both are future clock targets the
        run drivers jump to in O(1), not work the engine can advance."""
        return bool(
            self.waiting or self.swapped or self.slot_req
            or self._pf is not None
        )

    def _next_wake(self, default: int) -> int:
        """Earliest scheduled clock target: pending arrival or
        suspended-agent resume, else ``default`` (both heaps empty)."""
        cands = []
        if self.pending:
            cands.append(self.pending[0][0])
        if self._resumes:
            cands.append(self._resumes[0][0])
        return min(cands) if cands else default

    def run(self, until: int) -> None:
        """Advance the engine clock to iteration ``until`` (re-entrant).

        Idle stretches (nothing queued and no pending arrival due) are
        skipped in O(1) rather than stepped through, so a driver can submit
        agents with sparse future ``arrival_iter``s and simply ``run`` past
        them.
        """
        if self._in_run:
            raise RuntimeError("re-entrant run() from a listener callback")
        self._in_run = True
        try:
            while self.now < until:
                if not self.busy:
                    nxt = self._next_wake(until)
                    if nxt > self.now:
                        self.now = min(int(nxt), until)
                        if self.now >= until:
                            break
                        continue
                self.step(until - self.now)
        finally:
            self._in_run = False

    def run_until_idle(self, max_iters: int = 200_000) -> dict[int, int]:
        """Drain every queue (including pending future arrivals).

        ``max_iters`` budgets *executed* iterations (fused decode windows
        count their full width), not wall steps — idle gaps before
        scheduled arrivals are jumped in O(1) and don't count.
        """
        if self._in_run:
            raise RuntimeError(
                "re-entrant run_until_idle() from a listener callback"
            )
        self._in_run = True
        try:
            steps = 0
            while self.busy or self.pending or self._resumes:
                if steps >= max_iters:
                    raise EngineStalledError(
                        self._stall_report(max_iters),
                        dict(self.completions),
                        dict(self.metrics),
                    )
                if not self.busy:
                    # idle gap before the next scheduled arrival or
                    # suspended-agent resume: jump the clock
                    self.now = max(
                        self.now, int(self._next_wake(self.now))
                    )
                steps += self.step()
        finally:
            self._in_run = False
        return dict(self.completions)

    def _stall_report(self, max_iters: int) -> str:
        live = {
            aid: a.live
            for aid, a in sorted(self.agents.items())
            if a.finish_iter < 0
        }
        return (
            f"engine did not drain (step budget max_iters={max_iters} "
            f"exhausted at iteration "
            f"{self.now}): waiting={len(self.waiting)} "
            f"swapped={len(self.swapped)} running={len(self.slot_req)} "
            f"pending_arrivals={len(self.pending)} "
            f"suspended={len(self._resumes)} held_rids={len(self._held)} "
            f"fused_prefill_in_flight={self._pf is not None} "
            f"free_slots={len(self.slot_free)}/{self.max_batch} "
            f"free_blocks={self.alloc.free_blocks}/{self.alloc.n_blocks} "
            f"completed_agents={len(self.completions)}/{len(self.agents)} "
            f"live_per_agent={live}"
        )

    # ----------------------------------------------------------- admission

    def _key(self, req: EngineRequest):
        # NB: the clock argument is the PASS-consistent `now` — scheduler
        # keys must not read it (see repro.core.queueing module doc); it is
        # passed only to satisfy the policy signature.
        return self.sched.request_key(req.to_sched_request(), float(self.now))

    def _apply_dirty(self) -> None:
        """Propagate freshly-serviced agents to all grouped queues."""
        if self._grouped and self._dirty_agents:
            self.waiting.mark_dirty_many(self._dirty_agents)
            self.swapped.mark_dirty_many(self._dirty_agents)
            self.running.mark_dirty_many(self._dirty_agents)
            self._dirty_agents.clear()

    def _admit(self) -> None:
        # swapped queue has absolute priority and blocks the waiting queue.
        # refresh() is a no-op for static-key policies (sorted-by-
        # construction), a grouped repositioning for agent-keyed dynamic
        # ones, and a lazy version-gated re-sort otherwise.
        version = getattr(self.sched, "version", None)
        self._apply_dirty()
        self.swapped.refresh(version)
        while self.swapped and self.slot_free:
            req = self.swapped.peek()
            if not self.alloc.swap_in(req.rid):
                if self._escalate_held():
                    continue
                break
            self.swapped.popleft()
            self._swapped_rids.discard(req.rid)
            self._restore_slot(req)
        if self.swapped:
            self._sync_queue_metrics()
            return
        self.waiting.refresh(version)
        if self.fused_prefill:
            self._admit_fused()
            self._sync_queue_metrics()
            return
        batch: list[EngineRequest] = []
        while self.waiting and len(self.slot_free) > len(batch):
            req = self.waiting.peek()
            if self._wm is not None and self._wm_gate(req, in_pass=batch):
                break
            if self.prefix_cache:
                if not self.alloc.can_admit_prefix(req.prompt):
                    if self._escalate_held():
                        continue
                    break
                self.waiting.popleft()
                _, hit = self.alloc.admit_prefix(req.rid, req.prompt)
                req.cached_tokens = int(hit)
            else:
                if not self.alloc.can_admit(len(req.prompt) + 1):
                    if self._escalate_held():
                        continue
                    break
                self.waiting.popleft()
                self.alloc.admit(req.rid, len(req.prompt))
            batch.append(req)
        if batch:
            self._prefill_batch(batch)
        self._sync_queue_metrics()

    def _admit_fused(self) -> None:
        """Fused-mode admission: claim ONE waiting request at zero clock.

        The slot and every prompt block are allocated now, the scheduler
        service deal and ``on_admit`` are stamped now (at an unmoved
        ``now``), but the uncached suffix's K/V is produced one slice per
        iteration inside the following fused decode windows — running
        decoders never stall.  A prefix-cache hit's head is written
        immediately by the batched prefill program (its KV is presumed
        resident — the same zero-iteration assumption the unfused path
        makes); a fully-cached prompt therefore becomes a decoder with no
        fused slices at all, preserving the shortened-TTFT semantics.
        """
        if self._pf is not None or not self.slot_free or not self.waiting:
            return
        req = self.waiting.peek()
        if self._wm is not None and self._wm_gate(req):
            return
        if self.prefix_cache:
            while not self.alloc.can_admit_prefix(req.prompt):
                if not self._escalate_held():
                    return
            self.waiting.popleft()
            _, hit = self.alloc.admit_prefix(req.rid, req.prompt)
            req.cached_tokens = int(hit)
        else:
            while not self.alloc.can_admit(len(req.prompt) + 1):
                if not self._escalate_held():
                    return
            self.waiting.popleft()
            self.alloc.admit(req.rid, len(req.prompt))
        p = len(req.prompt)
        hit = req.cached_tokens
        slot = self.slot_free.pop()
        req.slot = slot
        self.metrics["prefills"] += 1
        self.sched.on_service(
            req.agent_id, prefill_tokens=float(p - hit)
        )
        if self._grouped:
            self._dirty_agents.add(req.agent_id)
        self._emit("on_admit", req.agent_id, req.rid, float(self.now))
        self.agent_prefill_tokens[req.agent_id] = (
            self.agent_prefill_tokens.get(req.agent_id, 0) + p
        )
        if hit:
            self.agent_hit_tokens[req.agent_id] = (
                self.agent_hit_tokens.get(req.agent_id, 0) + hit
            )
            self.metrics["prefill_tokens_saved"] += hit
            self.metrics["prefix_hits"] += 1
            self._emit(
                "on_prefix_hit", req.agent_id, req.rid,
                int(hit), int(p), float(self.now),
            )
        if hit >= p:
            # whole prompt cached: one batched write of the resident head
            # also samples the first token — zero fused slices, zero extra
            # iterations, exactly the unfused full-hit cost
            nxt = self._write_prefix_head(req, p, fetch_tok=True)
            self._fused_to_decoder(req, nxt)
            return
        if hit > 0:
            self._write_prefix_head(req, hit, fetch_tok=False)
        else:
            # slices only write the prompt's own rows: mask out the slot's
            # stale rows from its previous occupant first
            self.cache = _clear_slot_kvpos_jit(self.cache, slot)
        self._pf = _FusedPrefill(req=req, slot=slot, total=p, written=hit)
        self._slots_stale = True

    def _write_prefix_head(self, req: EngineRequest, n: int,
                           fetch_tok: bool):
        """Write the first ``n`` prompt tokens' K/V into the request's slot
        via the batched prefill program (single row, 64-token bucket)."""
        bucket = -(-max(n, 1) // 64) * 64
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt[:n]
        self.cache, nxt = _prefill_write_jit(
            self.model, self.cache_len, self.prefill_chunk,
            self.params, self.cache,
            jnp.asarray(toks), jnp.asarray([n], dtype=jnp.int32),
            jnp.asarray([req.slot], dtype=jnp.int32),
        )
        if fetch_tok:
            self.metrics["host_syncs"] += 1
            return int(np.asarray(nxt)[0])
        return None

    def _fused_to_decoder(self, req: EngineRequest, first_tok: int) -> None:
        """Promote a finished fused prefill to a running decoder: its first
        decode step — the request's first emitted token — runs in the next
        window."""
        slot = req.slot
        self.slot_req[slot] = req
        self.slot_last_tok[slot] = first_tok
        self.slot_pos[slot] = len(req.prompt)
        self.running.push(req)
        self._slots_stale = True

    def _sync_queue_metrics(self) -> None:
        self.metrics["sorts"] = (
            self.waiting.sorts + self.swapped.sorts + self.running.sorts
        )
        self.metrics["key_evals"] = (
            self.waiting.key_evals
            + self.swapped.key_evals
            + self.running.key_evals
        )

    # ------------------------------------------------------------- prefill

    def _prefill_batch(self, batch: list[EngineRequest]) -> None:
        """Prefill every admitted request of this pass.

        Attention-cache families run as ONE bucketed multi-sequence prefill
        (padded to the group's 64-token bucket and to a power-of-two batch;
        the lens mask keeps logits exact and invalid cache slots
        unattendable, out-of-bounds padding slots are scatter-dropped).
        Recurrent families (ssm/hybrid/encdec) prefill one sequence at a
        time — padding would pollute their recurrent state — but still go
        through the jitted scatter write.  The iteration cost of the pass,
        sum(ceil(p/prefill_chunk) - 1), is applied to the clock ONCE at the
        end so every admission decision and event stamp of the pass sees a
        consistent ``now``.
        """
        now0 = self.now
        batched_ok = self.model.cfg.kind in ("dense", "moe", "vlm")
        groups = [batch] if batched_ok else [[r] for r in batch]
        for group in groups:
            k = len(group)
            for req in group:
                req.slot = self.slot_free.pop()
                self.slot_req[req.slot] = req
            plens = [len(req.prompt) for req in group]
            if batched_ok:
                # bucket prompt lengths to multiples of 64 and the batch to
                # a power of two: each bucket compiles O(log max_batch)
                # prefill programs, padding rows cost only a little wasted
                # compute
                bucket = max(-(-max(p, 1) // 64) * 64 for p in plens)
                k_pad = 1 << (k - 1).bit_length() if k > 1 else 1
            else:
                bucket = max(max(p, 1) for p in plens)
                k_pad = 1
            toks = np.zeros((k_pad, bucket), np.int32)
            lens = np.ones(k_pad, np.int32)              # dummy rows: 1 tok
            slots = np.full(k_pad, self.max_batch, np.int32)   # OOB: dropped
            for i, req in enumerate(group):
                toks[i, : plens[i]] = req.prompt
                lens[i] = plens[i]
                slots[i] = req.slot
            self.cache, nxt = _prefill_write_jit(
                self.model, self.cache_len, self.prefill_chunk,
                self.params, self.cache,
                jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(slots),
            )
            nxt_host = np.asarray(nxt)[:k]
            self.metrics["host_syncs"] += 1
            for req, p, tok in zip(group, plens, nxt_host):
                self.slot_last_tok[req.slot] = tok
                self.slot_pos[req.slot] = p
                self.running.push(req)
                self.metrics["prefills"] += 1
                # a prefix-cache hit skips the cached chunk: only the
                # uncached suffix is charged to the scheduler's service
                # deal (cached_tokens is 0 with the cache off, so the
                # expression — and the off path — is unchanged)
                self.sched.on_service(
                    req.agent_id,
                    prefill_tokens=float(p - req.cached_tokens),
                )
                if self._grouped:
                    self._dirty_agents.add(req.agent_id)
                self._emit("on_admit", req.agent_id, req.rid, float(now0))
                self.agent_prefill_tokens[req.agent_id] = (
                    self.agent_prefill_tokens.get(req.agent_id, 0) + p
                )
                if req.cached_tokens:
                    self.agent_hit_tokens[req.agent_id] = (
                        self.agent_hit_tokens.get(req.agent_id, 0)
                        + req.cached_tokens
                    )
                    self.metrics["prefill_tokens_saved"] += req.cached_tokens
                    self.metrics["prefix_hits"] += 1
                    self._emit(
                        "on_prefix_hit", req.agent_id, req.rid,
                        int(req.cached_tokens), int(p), float(now0),
                    )
        self._slots_stale = True
        # prefill costs ceil(p / prefill_chunk) iterations of engine time
        # per request — with the prefix cache on, only the uncached suffix
        # is charged (a full hit costs 0 extra iterations); the accounting
        # stays serial-equivalent (sum, exactly as the reference engine
        # charged it) but lands after the pass
        self.now = now0 + sum(
            max(1, -(-p // self.prefill_chunk)) - 1
            for p in (
                len(r.prompt) - r.cached_tokens for r in batch
            )
        )

    # --------------------------------------------------------------- swaps

    def _stage_out(self, req: EngineRequest, slot: int) -> None:
        """Copy slot ``slot``'s cache rows into a host staging buffer."""
        dev = _gather_slot_jit(self.cache, slot)
        self.metrics["host_syncs"] += 1
        if self._staging:
            buf = self._staging.pop()
            for dst, src in zip(jax.tree.leaves(buf), jax.tree.leaves(dev)):
                np.copyto(dst, np.asarray(src))
            req.swapped_kv = buf
        else:
            # np.array (not asarray): on the CPU backend asarray is a
            # zero-copy READ-ONLY view of device memory — the staging pool
            # needs owned, writable host buffers it can recycle
            req.swapped_kv = jax.tree.map(np.array, dev)

    def _restore_slot(self, req: EngineRequest) -> None:
        slot = self.slot_free.pop()
        req.slot = slot
        self.slot_req[slot] = req
        self.cache = _scatter_slot_jit(self.cache, req.swapped_kv, slot)
        self.metrics["host_syncs"] += 1
        # recycling the staged buffer is safe without an explicit sync: it
        # is only overwritten inside a later _stage_out, whose device->host
        # fetch of the gathered slot forces every in-flight ancestor of the
        # cache — including this scatter, which is the only reader of the
        # staged rows — to complete first
        if len(self._staging) < 2 * self.max_batch:
            self._staging.append(req.swapped_kv)
        req.swapped_kv = None
        self.slot_last_tok[slot] = req._last_tok
        self.slot_pos[slot] = len(req.prompt) + req.generated
        self.running.push(req)
        self._slots_stale = True
        self.metrics["swaps"] += 1
        self._emit("on_swap_in", req.agent_id, req.rid, float(self.now))

    def _swap_out_worst(self) -> bool:
        """Evict the running request with the WORST scheduler key —
        after victimizing suspended agents' held KV first (PR 9): a
        thinker's retained blocks are always cheaper to give up than a
        running decoder's progress."""
        if self._escalate_held():
            return True
        if len(self.slot_req) <= 1:
            return False
        self._apply_dirty()
        self.running.refresh(getattr(self.sched, "version", None))
        req = self.running.pop_right()
        slot = req.slot
        self._stage_out(req, slot)
        req._last_tok = int(self.slot_last_tok[slot])
        self.alloc.swap_out(req.rid)
        self.slot_req.pop(slot)
        self.slot_free.append(slot)
        req.slot = -1
        self.swapped.push(req)
        self._swapped_rids.add(req.rid)
        self._slots_stale = True
        self._emit("on_swap_out", req.agent_id, req.rid, float(self.now))
        return True

    # -------------------------------------------------------------- decode

    def _refresh_device_slots(self) -> None:
        """Rebuild the device slot tensor from the host mirrors (only
        after slot occupancy changed: admit/swap/complete) — one upload."""
        state = np.zeros((3, self.max_batch), np.int32)
        state[0] = self.slot_last_tok
        state[1] = self.slot_pos
        for slot, req in self.slot_req.items():
            state[2, slot] = req.max_new_tokens - req.generated
        if self._pf is not None:
            # the prefilling slot rides the window frozen (rem 0) with its
            # pos at the next slice start — the fused program's choreography
            # relies on it (see _fused_window_jit)
            state[1, self._pf.slot] = self._pf.written
            state[2, self._pf.slot] = 0
        self._d_state = jnp.asarray(state)
        self._slots_stale = False
        self.metrics["host_syncs"] += 1

    def _queued_admittable(self) -> bool:
        """Could ANY queued request be (re-)admitted right now?

        Evaluated after the current step's token growth: its swap-outs may
        have freed more blocks than the growth consumed, making a request
        that failed this pass's ``_admit`` fit again (the reference engine
        would then admit it at the NEXT iteration — so a fused window must
        not span it).  Free blocks and slots only shrink inside a window,
        hence a False answer stays False for every step the window covers.
        Static policies check only the HEAD — ``_admit`` never looks past
        it and the order is frozen, so this is exact; dynamic policies may
        promote any item by the next pass, so the whole queue is scanned
        (long backlogs return a conservative True rather than pay an O(W)
        scan per window).
        """
        if not self.slot_free:
            return False          # both admission paths need a free slot
        free = self.alloc.free_blocks
        # prefix cache: a swapped sequence whose cached chain survived may
        # need 0 fresh blocks, so zero free is not conclusive there
        if free == 0 and not self.prefix_cache:
            return False
        if self._held and (
            self.swapped or (self.waiting and self._pf is None)
        ):
            # held-KV escalation can free blocks at the very next admit
            # pass, so a failed fit now is not conclusive (PR 9)
            return True
        static = not self.sched.dynamic
        if self.swapped:
            # a non-empty swapped queue blocks the waiting queue entirely
            if static:
                return self._swap_in_fits(self.swapped.peek(), free)
            if len(self.swapped) > 64:
                return True
            return any(
                self._swap_in_fits(req, free) for req in self.swapped
            )
        if self.waiting:
            if self._pf is not None:
                # fused mode runs ONE prefill at a time: while it is in
                # flight the waiting queue is blocked, and the window is
                # separately capped at slice exhaustion — the first
                # instant admission can become possible again
                return False
            if static:
                return self._admit_fits(self.waiting.peek(), free)
            if len(self.waiting) > 64:
                return True
            return any(
                self._admit_fits(req, free) for req in self.waiting
            )
        return False

    def _swap_in_fits(self, req: EngineRequest, free: int) -> bool:
        """Would ``swap_in`` succeed for this request right now?

        Prefix cache: fresh-block need shrinks by the surviving cached
        chain.  Within a fused window matches only disappear (eviction)
        and free blocks only shrink, so a False answer stays False — the
        monotonicity `_queued_admittable` relies on.
        """
        if self.prefix_cache:
            return self.alloc.can_swap_in(req.rid)
        s = self.alloc.seq(req.rid)
        return self.alloc.blocks_for(max(1, s.n_tokens)) <= free

    def _admit_fits(self, req: EngineRequest, free: int) -> bool:
        if self._wm is not None and self._wm_defers(req):
            return False
        if self.prefix_cache:
            return self.alloc.can_admit_prefix(req.prompt)
        return self.alloc.blocks_for(len(req.prompt) + 1) <= free

    # ------------------------------------------------- watermark admission

    def _wm_gate(self, req: EngineRequest, in_pass=()) -> bool:
        """Watermark verdict for the waiting head DURING an admission pass
        (updates the hysteresis gate and emits the deferral; ``in_pass``
        is the pass's already-admitted batch, so the idle-pool bypass only
        applies to a genuinely empty pool)."""
        if not (self.slot_req or self._pf is not None or in_pass):
            return False                       # idle-pool bypass
        low_b, high_b = self._wm
        used = self.alloc.n_blocks - self.alloc.free_blocks
        if self._wm_gated and used <= low_b:
            self._wm_gated = False
        need = self.alloc.blocks_for(len(req.prompt) + 1)
        if self._wm_gated or used + need > high_b:
            self._wm_gated = True
            if req.rid not in self._wm_emitted:
                self._wm_emitted.add(req.rid)
                self.metrics["admission_deferrals"] += 1
                self._emit(
                    "on_admission_deferred", req.agent_id, req.rid,
                    float(self.now),
                )
            return True
        return False

    def _wm_defers(self, req: EngineRequest) -> bool:
        """Pure watermark verdict (no gate mutation, no emission) — used
        by ``_queued_admittable`` via ``_admit_fits`` so window sizing and
        the next admission pass agree.  Monotone within a fused window:
        block usage only grows and the gate state only moves inside
        ``_admit``, so a True verdict stays True for every covered step.
        """
        if not (self.slot_req or self._pf is not None):
            return False
        low_b, high_b = self._wm
        used = self.alloc.n_blocks - self.alloc.free_blocks
        if self._wm_gated and used > low_b:
            return True
        return used + self.alloc.blocks_for(len(req.prompt) + 1) > high_b

    def _window_size(self, limit: Optional[int]) -> int:
        """Largest provably scheduling-free decode window (pow2 capped).

        A window of K iterations is safe iff within it (after the current
        step's token growth has already been committed):

        * no pending arrival comes due (K <= next arrival - now);
        * no queued request could be admitted with the current pool state
          (``_queued_admittable`` — free blocks/slots only shrink inside a
          window, so the check holds for every covered step);
        * every sequence's remaining token appends fit the block pool (so
          swap-outs cannot trigger and the queues stay untouched);
        * no completion that would SCHEDULE anything happens before the
          window's last step.  With the queues empty a final-stage
          completion schedules nothing — the freed slot cannot be refilled
          and the device row freezes exactly like the reference engine's
          stale freed slot — so the window may span it; a completion that
          finishes a STAGE with a successor submits new work and bounds
          the window instead.  With a backlog queued, every completion
          frees a slot an admission could take, so the window ends at the
          first one;
        * (fused prefill only) the in-flight prefill's slices do not run
          out before the window's last step (K <= remaining slices): its
          last slice completing turns the slot into a decoder AND unblocks
          waiting-queue admission, both scheduling actions — the window
          ends exactly there.
        """
        cap = self.max_window if limit is None else min(
            self.max_window, max(1, int(limit))
        )
        if self.pending:
            cap = min(cap, int(self.pending[0][0]) - self.now)
        if self._resumes:
            # a suspended agent's resume submits new work (PR 9) — any
            # mid-run scheduling trigger must bound the window
            cap = min(cap, int(self._resumes[0][0]) - self.now)
        if self._pf is not None:
            chunk = self.prefill_chunk
            cap = min(cap, -(-(self._pf.total - self._pf.written) // chunk))
        if cap <= 1:
            return 1
        if self.waiting or self.swapped:
            if self._queued_admittable():
                return 1
            # backlog: a completion frees a slot -> window ends at the
            # first one
            for req in self.slot_req.values():
                cap = min(cap, req.max_new_tokens - req.generated)
        elif self.slot_req:
            # empty queues: only stage-submitting completions schedule.
            # An agent's stage completes when its LAST live request does
            # (queues empty => all its live requests are running here;
            # a fused prefill's request is NOT — its stage cannot complete
            # within the window, so it binds nothing).
            last_done: dict[int, int] = {}
            for req in self.slot_req.values():
                rem = req.max_new_tokens - req.generated
                aid = req.agent_id
                last_done[aid] = max(last_done.get(aid, 0), rem)
            # never run past the final live completion — the reference
            # idles there, so extra frozen steps would inflate the clock.
            # With a fused prefill in flight the engine is NOT idle after
            # the last decoder completes: the slice-exhaustion cap above
            # already bounds the window, so the decoder bound is only
            # applied when it is the binding one
            if self._pf is None:
                cap = min(cap, max(last_done.values()))
            for aid, t_stage in last_done.items():
                agent = self.agents[aid]
                # closed-loop agents: a callback may append a stage at ANY
                # completion, so every stage boundary bounds the window
                if agent.closed_loop or agent.next_stage < len(agent.stages):
                    cap = min(cap, t_stage)
        if cap <= 1:
            return 1
        bs = self.alloc.block_size
        free = self.alloc.free_blocks
        slack = []
        for req in self.slot_req.values():
            s = self.alloc.seq(req.rid)
            slack.append(s.n_blocks * bs - s.n_tokens)

        def blocks_needed(m: int) -> int:
            return sum(max(0, -(-(m - sl) // bs)) for sl in slack)

        while cap > 1 and blocks_needed(cap - 1) > free:
            cap -= 1
        if cap <= 1:
            return 1
        return 1 << (cap.bit_length() - 1)   # bucket: bounds compilations

    def _decode_once(self, limit: Optional[int] = None) -> int:
        if not self.slot_req and self._pf is None:
            return 1
        # grow each running sequence by one token (may trigger swaps)
        for slot in sorted(self.slot_req):
            req = self.slot_req.get(slot)
            if req is None:
                continue
            while not self.alloc.append_token(req.rid):
                if not self._swap_out_worst():
                    break
                if req.rid not in self._swapped_rids:
                    continue
                break
            # note: if req itself was swapped out it no longer decodes
        active = sorted(self.slot_req)
        if not active and self._pf is None:
            return 1
        k = self._window_size(limit)
        snapshot = [(slot, self.slot_req[slot]) for slot in active]
        if k > 1:
            # commit the window's remaining token growth up front (the
            # step-1 append already ran above; a request completing at
            # window step r appends exactly r tokens, like the reference's
            # per-step growth loop) — _window_size proved it all fits, so
            # no swap decision is being skipped
            for slot, req in snapshot:
                extra = min(k, req.max_new_tokens - req.generated) - 1
                if extra and not self.alloc.append_tokens(req.rid, extra):
                    raise AssertionError("window over-committed the pool")
        if self._slots_stale:
            self._refresh_device_slots()
        pf = self._pf
        if pf is not None:
            # slice the next k prompt chunks host-side; the fused program
            # advances one per scanned step alongside the decoders
            chunk = self.prefill_chunk
            sl = np.zeros((k, chunk), np.int32)
            for j in range(k):
                seg = pf.req.prompt[pf.written + j * chunk:
                                    pf.written + (j + 1) * chunk]
                sl[j, :len(seg)] = seg
            meta = np.array([pf.slot, pf.written, pf.total], np.int32)
            self.cache, self._d_state, toks_dev = _fused_window_jit(
                self.model, k, chunk, self.params, self.cache,
                self._d_state, jnp.asarray(sl), jnp.asarray(meta),
            )
            out = np.asarray(toks_dev)       # (k, B+1): THE per-window sync
            toks, pf_toks = out[:, :-1], out[:, -1]
            self.metrics["fused_slices"] += k
        else:
            self.cache, self._d_state, toks_dev = _decode_window_jit(
                self.model, k, self.params, self.cache, self._d_state
            )
            toks = np.asarray(toks_dev)      # (k, B): THE per-window sync
        self.metrics["host_syncs"] += 1
        self.metrics["decode_steps"] += k
        self.metrics["windows"] += 1

        # replay the per-token bookkeeping host-side in exact step order;
        # a request whose budget ran out at an earlier window step is
        # frozen (mirrors the device-side rem mask)
        rem0 = {slot: req.max_new_tokens - req.generated
                for slot, req in snapshot}
        for i in range(k):
            if i:
                self.now += 1
            for slot, req in snapshot:
                if i >= rem0[slot]:
                    continue
                req.generated += 1
                self.metrics["tokens"] += 1
                self._emit(
                    "on_token", req.agent_id, req.rid, int(toks[i, slot]),
                    float(self.now),
                )
                self.slot_last_tok[slot] = toks[i, slot]
                self.slot_pos[slot] += 1
                occ = len(req.prompt) + req.generated
                self.sched.on_service(
                    req.agent_id, kv_token_time=float(occ), decode_tokens=1.0
                )
                if self._grouped:
                    self._dirty_agents.add(req.agent_id)
                if req.generated >= req.max_new_tokens:
                    self._complete(slot, req)
        if pf is not None:
            pf.written += k * self.prefill_chunk
            if pf.written >= pf.total:
                # slice exhaustion — the window's last step (the sizer
                # capped K at exactly this): the final slice's argmax is
                # the request's first token; it decodes from the next
                # iteration on
                self._pf = None
                self._fused_to_decoder(pf.req, int(pf_toks[k - 1]))
        return k

    def _complete(self, slot: int, req: EngineRequest) -> None:
        req.done = True
        self.slot_req.pop(slot)
        self.slot_free.append(slot)
        self.running.remove(req)
        self._slots_stale = True
        agent = self.agents[req.agent_id]
        agent.live -= 1
        if agent.live > 0:
            self.alloc.release(req.rid)
            return
        # the stage-complete callback may append a follow-up stage WITH a
        # resume delay, so the KV release decision (hold retention keeps
        # the final rid pinned through think time) must wait for the emit
        self._emit(
            "on_stage_complete", agent.agent_id, agent.next_stage - 1,
            float(self.now),
        )
        if agent.next_stage < len(agent.stages):
            delay = self._stage_delay(agent)
            if delay > 0:
                self._suspend(agent, req, slot, delay)
                return
            self.alloc.release(req.rid)
            self._submit_stage(agent)
        else:
            self.alloc.release(req.rid)
            agent.finish_iter = self.now
            self.completions[agent.agent_id] = self.now
            self.sched.on_agent_complete(agent.agent_id, float(self.now))
            self._emit(
                "on_agent_complete", agent.agent_id, float(self.now)
            )

    def _stage_delay(self, agent: EngineAgent) -> int:
        """Resume delay (iterations) attached to the agent's NEXT stage."""
        delays = agent.resume_delays
        if delays is None or agent.next_stage >= len(delays):
            return 0
        d = delays[agent.next_stage]
        return int(d) if d is not None else 0

    def _suspend(
        self, agent: EngineAgent, req: EngineRequest, slot: int, delay: int
    ) -> None:
        """Park a closed-loop agent through tool-call think time (PR 9).

        The agent holds NO decode slot while suspended (it was freed by
        ``_complete`` before this call).  Its finished stage's KV falls
        under the retention policy:

        * ``hold``  — the final rid stays allocated (pinned blocks); the
          next stage re-matches it byte-for-byte via the prefix cache.
          ``_escalate_held`` releases it under memory pressure.
        * ``spill`` — the slot's cache rows are gathered to a host
          staging buffer (counted as a host sync) and the blocks are
          released; the radix index may still serve the prefix until
          eviction.
        * ``drop``  — blocks released outright; with the prefix cache on,
          reprefill is cheap while the chain survives in the radix index.
        """
        aid = agent.agent_id
        if self.suspend_retention == "hold":
            self._held[aid] = req.rid
        else:
            if self.suspend_retention == "spill":
                dev = _gather_slot_jit(self.cache, slot)
                self.metrics["host_syncs"] += 1
                if len(self._staging) < 2 * self.max_batch:
                    self._staging.append(jax.tree.map(np.array, dev))
                self.metrics["suspend_spills"] += 1
            self.alloc.release(req.rid)
        until = self.now + int(delay)
        self._rseq += 1
        heapq.heappush(self._resumes, (until, self._rseq, agent))
        self.metrics["suspensions"] += 1
        self.sched.on_agent_suspend(aid, float(self.now))
        self._emit(
            "on_suspend", aid, agent.next_stage - 1, float(until),
            float(self.now),
        )

    def _escalate_held(self) -> bool:
        """Release the oldest suspended agent's pinned KV (hold -> drop).

        Called when admission, swap-in, or victim selection cannot make
        progress: suspended agents are victimized BEFORE running ones.
        With the prefix cache on, the released blocks stay matchable in
        the radix index until evicted, so escalation degrades hold into
        an effective drop rather than wedging the pool.
        """
        if not self._held:
            return False
        aid = next(iter(self._held))
        rid = self._held.pop(aid)
        self.alloc.release(rid)
        self.metrics["suspend_spills"] += 1
        return True
