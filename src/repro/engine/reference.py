"""Frozen pre-PR-4 serving engine: the behavioural oracle and perf baseline.

This is the ``ServeEngine`` hot path exactly as it stood before the
device-resident rewrite (PR 4), kept verbatim so that

  * ``benchmarks/perf_engine.py`` can PROVE the rewrite behaviour-
    preserving — both engines must produce identical completion dicts and
    swap/prefill/token counts on every seeded benchmark cell before any
    throughput number is recorded — and measure the real speedup against
    the very code that was replaced;
  * regression tests (``tests/test_engine_pressure.py``) can pin the
    optimized engine against this oracle on swap-heavy workloads.

Like ``repro.sim.reference``, this core is deliberately FROZEN: semantic
changes to the engine must patch ``repro.engine.engine`` and, if they are
meant to change behaviour, retire the corresponding oracle assertions —
never edit this file to make a mismatch go away.

Known per-iteration costs retained here (what PR 4 removed): host round
trips for decode tokens and slot positions every step, eager full-cache
``jax.tree.map`` rebuilds on every prefill write and swap, one-at-a-time
prefill admission, and O(running) ``max()`` swap-victim scans.
"""

from __future__ import annotations

import heapq
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queueing import OrderedQueue
from repro.core.schedulers import AgentScheduler
from repro.engine.engine import EngineAgent, EngineRequest, EngineStalledError
from repro.kvcache.allocator import BlockAllocator
from repro.models import Model


class ReferenceServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        scheduler: AgentScheduler,
        *,
        pool_tokens: int = 4096,
        block_size: int = 16,
        max_batch: int = 8,
        cache_len: int = 512,
        prefill_chunk: int = 512,
        listener: Any = None,
    ):
        self.model = model
        self.params = params
        self.sched = scheduler
        self.listener = listener
        self.alloc = BlockAllocator(pool_tokens, block_size)
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.prefill_chunk = prefill_chunk

        self.cache = model.init_cache(params, max_batch, cache_len)
        self.slot_free = list(range(max_batch))
        self.slot_req: dict[int, EngineRequest] = {}
        self.slot_last_tok = np.zeros(max_batch, np.int32)
        self.slot_pos = np.zeros(max_batch, np.int32)

        # waiting/swapped are the shared OrderedQueue (repro.core.queueing):
        # static-key policies keep them sorted by construction; agent-keyed
        # dynamic policies (VTC/SRJF) get grouped invalidation (only the
        # freshly-serviced agents' requests reposition per admission pass);
        # other dynamic policies re-sort lazily when the scheduler's
        # version counter moves
        self._grouped = scheduler.dynamic and getattr(
            scheduler, "agent_keyed", False
        )
        self._dirty_agents: set[int] = set()
        _gf = (lambda req: req.agent_id) if self._grouped else None
        self.waiting: OrderedQueue = OrderedQueue(
            self._key, dynamic=scheduler.dynamic, group_fn=_gf
        )
        self.swapped: OrderedQueue = OrderedQueue(
            self._key, dynamic=scheduler.dynamic, group_fn=_gf
        )
        self.agents: dict[int, EngineAgent] = {}
        # future arrivals: (arrival_iter, submit order, agent) min-heap
        self.pending: list[tuple[int, int, EngineAgent]] = []
        self.now = 0               # iteration counter
        self.completions: dict[int, int] = {}   # agent -> finish iter
        self._rid = 0
        self._submit_seq = 0
        self.metrics = {"prefills": 0, "decode_steps": 0, "swaps": 0,
                        "tokens": 0, "sorts": 0, "key_evals": 0}

        self._jit_decode = jax.jit(self.model.decode)
        self._jit_prefill = jax.jit(
            self.model.prefill, static_argnames=("cache_len",)
        )

    # ------------------------------------------------------------- events

    def _emit(self, event: str, *args) -> None:
        if self.listener is not None:
            fn = getattr(self.listener, event, None)
            if fn is not None:
                fn(*args)

    # ------------------------------------------------------------- submit

    def submit_agent(self, agent: EngineAgent) -> None:
        """Register an agent with the engine.

        If ``agent.arrival_iter`` lies in the future the agent is parked in
        the pending heap and released by ``step()`` when the clock reaches
        it — this is how online (non-upfront) arrivals are driven.  An
        arrival at or before ``self.now`` takes effect immediately, which
        matches the old submit-everything-upfront behaviour.
        """
        self._validate_stages(agent)
        if agent.arrival_iter > self.now:
            heapq.heappush(
                self.pending, (agent.arrival_iter, self._submit_seq, agent)
            )
            self._submit_seq += 1
            return
        self._arrive(agent)

    def _validate_stages(self, agent: EngineAgent) -> None:
        for stage in agent.stages:
            for prompt, d in stage:
                if len(prompt) + int(d) + 1 > self.cache_len:
                    raise ValueError(
                        f"request p={len(prompt)} d={d} exceeds cache_len "
                        f"{self.cache_len}"
                    )

    def _arrive(self, agent: EngineAgent) -> None:
        agent.arrival_iter = self.now
        self.agents[agent.agent_id] = agent
        self.sched.on_agent_arrival(
            agent.agent_id, float(self.now), agent.predicted_cost
        )
        self._emit("on_arrival", agent.agent_id, float(self.now))
        self._submit_stage(agent)

    def _release_arrivals(self) -> None:
        while self.pending and self.pending[0][0] <= self.now:
            _, _, agent = heapq.heappop(self.pending)
            self._arrive(agent)

    def _submit_stage(self, agent: EngineAgent) -> None:
        stage = agent.stages[agent.next_stage]
        agent.next_stage += 1
        agent.live += len(stage)
        for prompt, d in stage:
            self.waiting.push(
                EngineRequest(
                    agent_id=agent.agent_id,
                    rid=self._rid,
                    prompt=np.asarray(prompt, np.int32),
                    max_new_tokens=int(d),
                    submit_iter=self.now,
                )
            )
            self._rid += 1

    # ----------------------------------------------------------- stepping

    def step(self) -> None:
        """One engine iteration: release arrivals, admit, one decode step."""
        self._release_arrivals()
        self._admit()
        self._decode_once()
        self.now += 1

    @property
    def busy(self) -> bool:
        """Work is queued or running (pending future arrivals excluded)."""
        return bool(self.waiting or self.swapped or self.slot_req)

    def run(self, until: int) -> None:
        """Advance the engine clock to iteration ``until`` (re-entrant).

        Idle stretches (nothing queued and no pending arrival due) are
        skipped in O(1) rather than stepped through, so a driver can submit
        agents with sparse future ``arrival_iter``s and simply ``run`` past
        them.
        """
        while self.now < until:
            if not self.busy:
                nxt = self.pending[0][0] if self.pending else until
                if nxt > self.now:
                    self.now = min(int(nxt), until)
                    if self.now >= until:
                        break
                    continue
            self.step()

    def run_until_idle(self, max_iters: int = 200_000) -> dict[int, int]:
        """Drain every queue (including pending future arrivals).

        ``max_iters`` budgets *executed* steps, not the clock value — idle
        gaps before scheduled arrivals are jumped in O(1) and don't count.
        """
        steps = 0
        while self.busy or self.pending:
            if steps >= max_iters:
                raise EngineStalledError(
                    self._stall_report(max_iters),
                    dict(self.completions),
                    dict(self.metrics),
                )
            if not self.busy:
                # idle gap before the next scheduled arrival: jump the clock
                self.now = max(self.now, int(self.pending[0][0]))
            self.step()
            steps += 1
        return dict(self.completions)

    def _stall_report(self, max_iters: int) -> str:
        live = {
            aid: a.live
            for aid, a in sorted(self.agents.items())
            if a.finish_iter < 0
        }
        return (
            f"engine did not drain (step budget max_iters={max_iters} "
            f"exhausted at iteration "
            f"{self.now}): waiting={len(self.waiting)} "
            f"swapped={len(self.swapped)} running={len(self.slot_req)} "
            f"pending_arrivals={len(self.pending)} "
            f"free_slots={len(self.slot_free)}/{self.max_batch} "
            f"free_blocks={self.alloc.free_blocks}/{self.alloc.n_blocks} "
            f"completed_agents={len(self.completions)}/{len(self.agents)} "
            f"live_per_agent={live}"
        )

    # ----------------------------------------------------------- admission

    def _key(self, req: EngineRequest):
        return self.sched.request_key(req.to_sched_request(), float(self.now))

    def _admit(self) -> None:
        # swapped queue has absolute priority and blocks the waiting queue.
        # refresh() is a no-op for static-key policies (sorted-by-
        # construction), a grouped repositioning for agent-keyed dynamic
        # ones, and a lazy version-gated re-sort otherwise.
        version = getattr(self.sched, "version", None)
        if self._grouped and self._dirty_agents:
            self.waiting.mark_dirty_many(self._dirty_agents)
            self.swapped.mark_dirty_many(self._dirty_agents)
            self._dirty_agents.clear()
        self.swapped.refresh(version)
        while self.swapped and self.slot_free:
            req = self.swapped.peek()
            if not self.alloc.swap_in(req.rid):
                break
            self.swapped.popleft()
            self._restore_slot(req)
        if self.swapped:
            self._sync_queue_metrics()
            return
        self.waiting.refresh(version)
        while self.waiting and self.slot_free:
            req = self.waiting.peek()
            if not self.alloc.can_admit(len(req.prompt) + 1):
                break
            self.waiting.popleft()
            self.alloc.admit(req.rid, len(req.prompt))
            self._prefill_into_slot(req)
            self._emit("on_admit", req.agent_id, req.rid, float(self.now))
        self._sync_queue_metrics()

    def _sync_queue_metrics(self) -> None:
        self.metrics["sorts"] = self.waiting.sorts + self.swapped.sorts
        self.metrics["key_evals"] = (
            self.waiting.key_evals + self.swapped.key_evals
        )

    # ------------------------------------------------------------- prefill

    def _prefill_into_slot(self, req: EngineRequest) -> None:
        slot = self.slot_free.pop()
        req.slot = slot
        self.slot_req[slot] = req
        p = len(req.prompt)
        prompt = req.prompt
        if self.model.cfg.kind in ("dense", "moe", "vlm"):
            # bucket prompt lengths to multiples of 64 to bound the number
            # of prefill compilations; the lens mask keeps logits exact
            bucket = -(-max(p, 1) // 64) * 64
            prompt = np.pad(prompt, (0, bucket - p))
        toks = jnp.asarray(prompt[None, :], jnp.int32)
        logits, small_cache = self._jit_prefill(
            self.params,
            {"tokens": toks, "lens": jnp.asarray([p], jnp.int32)},
            cache_len=self.cache_len,
        )
        self._write_cache_slot(slot, small_cache)
        nxt = int(jnp.argmax(logits[0, -1]))
        self.slot_last_tok[slot] = nxt
        self.slot_pos[slot] = p
        # prefill costs ceil(p / prefill_chunk) iterations of engine time
        self.now += max(1, -(-p // self.prefill_chunk)) - 1
        self.metrics["prefills"] += 1
        self.sched.on_service(req.agent_id, prefill_tokens=float(p))
        if self._grouped:
            self._dirty_agents.add(req.agent_id)

    def _write_cache_slot(self, slot: int, small_cache: dict) -> None:
        """Copy a B=1 prefill cache into row ``slot`` of the engine cache."""

        def write(big, small):
            if big.ndim >= 2 and small.shape[0] == big.shape[0]:
                # layer-stacked tensors: (L, B, ...)
                sl = small.shape[2] if small.ndim > 2 else None
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=1
                )
            return big

        self.cache = jax.tree.map(write, self.cache, small_cache)

    def _restore_slot(self, req: EngineRequest) -> None:
        slot = self.slot_free.pop()
        req.slot = slot
        self.slot_req[slot] = req
        self.cache = jax.tree.map(
            lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                big, jnp.asarray(small)[:, None], slot, axis=1
            ),
            self.cache,
            req.swapped_kv,
        )
        req.swapped_kv = None
        self.slot_last_tok[slot] = req._last_tok
        self.slot_pos[slot] = len(req.prompt) + req.generated
        self.metrics["swaps"] += 1
        self._emit("on_swap_in", req.agent_id, req.rid, float(self.now))

    def _swap_out_worst(self) -> bool:
        """Evict the running request with the WORST scheduler key."""
        if len(self.slot_req) <= 1:
            return False
        slot, req = max(
            self.slot_req.items(), key=lambda kv: self._key(kv[1])
        )
        req.swapped_kv = jax.tree.map(
            lambda big: np.asarray(big[:, slot]), self.cache
        )
        req._last_tok = int(self.slot_last_tok[slot])
        self.alloc.swap_out(req.rid)
        self.slot_req.pop(slot)
        self.slot_free.append(slot)
        req.slot = -1
        self.swapped.push(req)
        self._emit("on_swap_out", req.agent_id, req.rid, float(self.now))
        return True

    # -------------------------------------------------------------- decode

    def _decode_once(self) -> None:
        if not self.slot_req:
            return
        # grow each running sequence by one token (may trigger swaps)
        for slot in sorted(self.slot_req):
            req = self.slot_req.get(slot)
            if req is None:
                continue
            while not self.alloc.append_token(req.rid):
                if not self._swap_out_worst():
                    break
                if not any(r.rid == req.rid for r in self.swapped):
                    continue
                break
            # note: if req itself was swapped out it no longer decodes
        active = sorted(self.slot_req)
        if not active:
            return
        toks = jnp.asarray(self.slot_last_tok[:, None], jnp.int32)
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.cache = self._jit_decode(
            self.params, self.cache, toks, pos
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        self.metrics["decode_steps"] += 1

        for slot in list(active):
            req = self.slot_req.get(slot)
            if req is None:
                continue
            req.generated += 1
            self.metrics["tokens"] += 1
            self._emit(
                "on_token", req.agent_id, req.rid, int(nxt[slot]),
                float(self.now),
            )
            self.slot_last_tok[slot] = nxt[slot]
            self.slot_pos[slot] += 1
            occ = len(req.prompt) + req.generated
            self.sched.on_service(
                req.agent_id, kv_token_time=float(occ), decode_tokens=1.0
            )
            if self._grouped:
                self._dirty_agents.add(req.agent_id)
            if req.generated >= req.max_new_tokens:
                self._complete(slot, req)

    def _complete(self, slot: int, req: EngineRequest) -> None:
        req.done = True
        self.alloc.release(req.rid)
        self.slot_req.pop(slot)
        self.slot_free.append(slot)
        agent = self.agents[req.agent_id]
        agent.live -= 1
        if agent.live == 0:
            self._emit(
                "on_stage_complete", agent.agent_id, agent.next_stage - 1,
                float(self.now),
            )
            if agent.next_stage < len(agent.stages):
                self._submit_stage(agent)
            else:
                agent.finish_iter = self.now
                self.completions[agent.agent_id] = self.now
                self.sched.on_agent_complete(agent.agent_id, float(self.now))
                self._emit(
                    "on_agent_complete", agent.agent_id, float(self.now)
                )
