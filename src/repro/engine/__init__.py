"""Continuous-batching serving engine (vLLM semantics, JAX backend).

``ServeEngine`` is the device-resident hot path; ``ReferenceServeEngine``
(``repro.engine.reference``) is the frozen pre-rewrite core kept as the
behavioural oracle and perf baseline for ``benchmarks/perf_engine.py``.
"""

from repro.engine.engine import (
    EngineAgent,
    EngineRequest,
    EngineStalledError,
    ServeEngine,
)
from repro.engine.reference import ReferenceServeEngine

__all__ = [
    "EngineAgent",
    "EngineRequest",
    "EngineStalledError",
    "ReferenceServeEngine",
    "ServeEngine",
]
