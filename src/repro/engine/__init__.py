"""Continuous-batching serving engine (vLLM semantics, JAX backend)."""

from repro.engine.engine import EngineAgent, EngineRequest, ServeEngine

__all__ = ["EngineAgent", "EngineRequest", "ServeEngine"]
