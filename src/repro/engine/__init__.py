"""Continuous-batching serving engine (vLLM semantics, JAX backend)."""

from repro.engine.engine import (
    EngineAgent,
    EngineRequest,
    EngineStalledError,
    ServeEngine,
)

__all__ = [
    "EngineAgent",
    "EngineRequest",
    "EngineStalledError",
    "ServeEngine",
]
