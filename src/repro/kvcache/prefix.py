"""Prefix-aware KV block reuse: a content-hash radix index over
:class:`BlockAllocator` blocks (PR 6).

Design (vLLM-style automatic prefix caching, adapted to the paper's
model-independent token units):

* **Hashing granularity** — only FULL blocks (``block_size`` tokens) are
  content-addressed.  A block's identity is the chain key
  ``(parent_node_id, tokens_in_block)``: two prompts share a block only
  when every earlier block also matched, so the index is a radix trie
  keyed by block-sized token runs.  The final partial block of a prompt
  is always private — partial-block sharing is what forces eager COW in
  other designs, so we exclude it by construction.
* **Refcounts / pinning** — a cached node's refcount is the number of
  live sequences whose block table references it.  Referenced blocks are
  pinned: they can never be evicted or handed out.  Because every
  reference is a root-contiguous chain, ``refcount(parent) >=
  refcount(child)`` always holds.
* **LRU free-list** — when a node's refcount drops to zero its block is
  NOT returned to the free list; the node parks in an LRU ordered dict
  and stays matchable.  Allocation prefers truly-free blocks and only
  then evicts LRU nodes, oldest first, leaves first (a node with cached
  children is skipped so a chain never loses an interior block).
  ``free_blocks`` therefore counts ``free + unreferenced-cached``.
* **Copy-on-write** — engine paths never write into a cached block
  (appends land in the private partial block or a fresh block), but
  :meth:`fork` can branch a sequence mid-block, leaving its write cursor
  inside a shared block.  The first append then unshares every chain
  block at or past the cursor: dereference the node, allocate a private
  replacement (a refcount-0 node may be reclaimed in place), and count a
  ``cow_copies``.  Appends stay all-or-nothing: availability is checked
  before any state changes, counting one fresh block per COW target
  whose node is still shared (``refcount > 1``).

The allocator stays pure bookkeeping — the engine's tensor cache is
slot-indexed, so block sharing models the *accounting and timing* of
prefix reuse (blocks held, prefill iterations charged) while tensor
prefill still computes full prompts bit-identically.
"""

from __future__ import annotations

import dataclasses
from collections import Counter, OrderedDict
from typing import Optional, Sequence

from repro.kvcache.allocator import BlockAllocator, OutOfBlocks, SeqAlloc

TokenRun = tuple[int, ...]
_ROOT = -1


@dataclasses.dataclass
class PrefixNode:
    """One cached full block in the radix index."""

    node_id: int
    block: int
    key: tuple  # (parent node_id, block token tuple)
    parent: int  # parent node_id, _ROOT at depth 0
    refcount: int = 0
    n_children: int = 0  # cached (not evicted) children


class PrefixAwareAllocator(BlockAllocator):
    """Block allocator with a content-hash prefix index and COW refcounts."""

    def __init__(self, total_tokens: int, block_size: int = 16):
        super().__init__(total_tokens, block_size)
        self._nodes: dict[int, PrefixNode] = {}
        self._index: dict[tuple, PrefixNode] = {}
        # refcount-0 nodes, oldest first (insertion order = eviction order)
        self._lru: "OrderedDict[int, PrefixNode]" = OrderedDict()
        # per-seq root-contiguous referenced node ids (block_table prefix)
        self._chains: dict[int, list[int]] = {}
        # per-seq full-block token runs, for swap-in re-matching; kept in
        # lockstep with the chain under COW truncation
        self._chain_tokens: dict[int, list[TokenRun]] = {}
        self._next_node = 0
        self.hit_tokens = 0
        self.evictions = 0
        self.cow_copies = 0

    # ------------------------------------------------------------- queries

    @property
    def free_blocks(self) -> int:
        # unreferenced cached blocks are evictable on demand
        return len(self._free) + len(self._lru)

    @property
    def cached_blocks(self) -> int:
        return len(self._nodes)

    def _full_runs(self, tokens: Sequence[int]) -> list[TokenRun]:
        bs = self.block_size
        return [
            tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
            for i in range(len(tokens) // bs)
        ]

    def _walk(self, runs: Sequence[TokenRun]) -> list[PrefixNode]:
        """Longest cached chain matching ``runs`` (lookup only)."""
        out: list[PrefixNode] = []
        parent = _ROOT
        for run in runs:
            node = self._index.get((parent, run))
            if node is None:
                break
            out.append(node)
            parent = node.node_id
        return out

    def match_tokens(self, tokens: Sequence[int]) -> int:
        """Cached-prefix length (tokens) for a prompt, without admitting."""
        return len(self._walk(self._full_runs(tokens))) * self.block_size

    def can_admit_prefix(self, tokens: Sequence[int],
                         n_tokens: Optional[int] = None) -> bool:
        """Admission check for :meth:`admit_prefix`.

        ``n_tokens`` defaults to ``len(tokens) + 1`` — the engine's
        ``can_admit(len(prompt) + 1)`` convention (room for the first
        decode token).  Matched blocks cost nothing, but matched
        refcount-0 blocks leave the evictable pool once re-referenced.
        """
        n = len(tokens) + 1 if n_tokens is None else n_tokens
        matched = self._walk(self._full_runs(tokens))
        in_lru = sum(1 for nd in matched if nd.refcount == 0)
        need = self.blocks_for(max(1, n)) - len(matched)
        return need <= len(self._free) + len(self._lru) - in_lru

    def swap_in_need(self, seq_id: int) -> int:
        """Fresh blocks a swapped sequence would need to come back now."""
        s = self._seqs[seq_id]
        if not s.swapped:
            return 0
        matched = self._walk(self._chain_tokens.get(seq_id, []))
        return max(0, self.blocks_for(max(1, s.n_tokens)) - len(matched))

    def can_swap_in(self, seq_id: int) -> bool:
        """Would :meth:`swap_in` succeed right now (lookup only)?"""
        s = self._seqs[seq_id]
        if not s.swapped:
            return True
        matched = self._walk(self._chain_tokens.get(seq_id, []))
        in_lru = sum(1 for nd in matched if nd.refcount == 0)
        need = self.blocks_for(max(1, s.n_tokens)) - len(matched)
        return need <= len(self._free) + len(self._lru) - in_lru

    # ------------------------------------------------------- node plumbing

    def _ref(self, node: PrefixNode) -> None:
        node.refcount += 1
        if node.refcount == 1:
            self._lru.pop(node.node_id, None)

    def _deref(self, node: PrefixNode) -> None:
        node.refcount -= 1
        assert node.refcount >= 0, "negative refcount"
        if node.refcount == 0:
            self._lru[node.node_id] = node  # newest end

    def _register(self, parent: int, run: TokenRun, block: int) -> PrefixNode:
        node = PrefixNode(
            node_id=self._next_node, block=block, key=(parent, run),
            parent=parent, refcount=1,
        )
        self._next_node += 1
        self._nodes[node.node_id] = node
        self._index[node.key] = node
        if parent != _ROOT:
            self._nodes[parent].n_children += 1
        return node

    def _evict(self, node: PrefixNode) -> int:
        del self._lru[node.node_id]
        del self._index[node.key]
        del self._nodes[node.node_id]
        if node.parent != _ROOT:
            self._nodes[node.parent].n_children -= 1
        self.evictions += 1
        return node.block

    def _pop_block(self) -> int:
        if self._free:
            return self._free.pop()
        # oldest evictable leaf; any LRU node's cached children are also
        # refcount-0 (chains reference root-contiguously), so scanning
        # always finds a leaf while the LRU is non-empty
        for node in self._lru.values():
            if node.n_children == 0:
                return self._evict(node)
        raise OutOfBlocks("no free or evictable blocks")

    # ------------------------------------------------------------ mutation

    def admit(self, seq_id: int, n_tokens: int) -> SeqAlloc:
        """Content-free admission (no prompt ids): nothing is cached, but
        allocation may still evict unreferenced cached blocks."""
        need = self.blocks_for(max(1, n_tokens))
        if need > len(self._free) + len(self._lru):
            raise OutOfBlocks(f"need {need} blocks, have {self.free_blocks}")
        blocks = [self._pop_block() for _ in range(need)]
        alloc = SeqAlloc(seq_id=seq_id, block_table=blocks, n_tokens=n_tokens)
        self._seqs[seq_id] = alloc
        self._used_tokens += n_tokens
        self._chains[seq_id] = []
        self._chain_tokens[seq_id] = []
        return alloc

    def admit_prefix(self, seq_id: int,
                     tokens: Sequence[int]) -> tuple[SeqAlloc, int]:
        """Admit a prompt, sharing its longest cached full-block prefix.

        Returns ``(alloc, hit_tokens)``.  Every fresh FULL block is
        registered in the index (refcount 1) so later prompts can share
        it; the partial tail block stays private.  ``n_tokens`` counts
        the full logical prompt — ``used_tokens`` stays a logical
        occupancy measure, sharing only dedups physical blocks.
        """
        n = len(tokens)
        runs = self._full_runs(tokens)
        matched = self._walk(runs)
        for node in matched:
            self._ref(node)
        chain = [nd.node_id for nd in matched]
        need = self.blocks_for(max(1, n)) - len(chain)
        if need > len(self._free) + len(self._lru):
            for nid in reversed(chain):
                self._deref(self._nodes[nid])
            raise OutOfBlocks(f"need {need} blocks, have {self.free_blocks}")
        table = [self._nodes[nid].block for nid in chain]
        parent = chain[-1] if chain else _ROOT
        for i in range(need):
            block = self._pop_block()
            j = len(chain)
            if j < len(runs):  # fresh full prompt block: cacheable
                node = self._register(parent, runs[j], block)
                chain.append(node.node_id)
                parent = node.node_id
            table.append(block)
        alloc = SeqAlloc(seq_id=seq_id, block_table=table, n_tokens=n)
        self._seqs[seq_id] = alloc
        self._used_tokens += n
        self._chains[seq_id] = chain
        self._chain_tokens[seq_id] = runs
        hit = len(matched) * self.block_size
        self.hit_tokens += hit
        return alloc, hit

    def fork(self, seq_id: int, new_seq_id: int,
             n_tokens: Optional[int] = None) -> SeqAlloc:
        """Copy-on-write branch of a live sequence at ``n_tokens``.

        The branch re-references every cached chain block covering its
        kept prefix — including a final *partially kept* block when
        ``n_tokens`` lands mid-block, which the next append unshares (the
        COW path).  Tokens past the chain get fresh private blocks.
        """
        src = self._seqs[seq_id]
        if src.swapped:
            raise ValueError(f"seq {seq_id} is swapped out")
        if new_seq_id in self._seqs:
            raise ValueError(f"seq {new_seq_id} already exists")
        n = src.n_tokens if n_tokens is None else n_tokens
        if not 0 < n <= src.n_tokens:
            raise ValueError(f"fork point {n} outside (0, {src.n_tokens}]")
        src_chain = self._chains.get(seq_id, [])
        total = self.blocks_for(max(1, n))
        keep = min(len(src_chain), total)
        need = total - keep
        if need > len(self._free) + len(self._lru):
            raise OutOfBlocks(f"need {need} blocks, have {self.free_blocks}")
        chain = []
        for nid in src_chain[:keep]:
            self._ref(self._nodes[nid])
            chain.append(nid)
        table = [self._nodes[nid].block for nid in chain]
        table.extend(self._pop_block() for _ in range(need))
        alloc = SeqAlloc(seq_id=new_seq_id, block_table=table, n_tokens=n)
        self._seqs[new_seq_id] = alloc
        self._used_tokens += n
        self._chains[new_seq_id] = chain
        self._chain_tokens[new_seq_id] = self._chain_tokens.get(
            seq_id, [])[:keep]
        return alloc

    def _cow_targets(self, s: SeqAlloc) -> list[int]:
        """Chain node ids the next append would write into (normally
        empty: chains cover full blocks and the write cursor sits past
        them — only a mid-block fork leaves it inside a shared block)."""
        chain = self._chains.get(s.seq_id)
        if not chain:
            return []
        tgt = s.n_tokens // self.block_size
        return chain[tgt:] if tgt < len(chain) else []

    def _cow_unshare(self, s: SeqAlloc, targets: list[int]) -> None:
        tgt = len(self._chains[s.seq_id]) - len(targets)
        for nid in reversed(targets):
            self._deref(self._nodes[nid])
        for i in range(tgt, tgt + len(targets)):
            s.block_table[i] = self._pop_block()
        del self._chains[s.seq_id][tgt:]
        runs = self._chain_tokens.get(s.seq_id)
        if runs is not None:
            del runs[tgt:]
        self.cow_copies += len(targets)

    def append_token(self, seq_id: int) -> bool:
        s = self._seqs[seq_id]
        if s.swapped:
            raise ValueError(f"seq {seq_id} is swapped out")
        need = 1 if s.n_tokens + 1 > s.n_blocks * self.block_size else 0
        targets = self._cow_targets(s)
        # a still-shared COW target needs a genuinely fresh block; a
        # refcount-1 target's own block becomes reclaimable on deref
        fresh = need + sum(
            1 for nid in targets if self._nodes[nid].refcount > 1)
        if fresh > len(self._free) + len(self._lru):
            return False
        if targets:
            self._cow_unshare(s, targets)
        if need:
            s.block_table.append(self._pop_block())
        s.n_tokens += 1
        self._used_tokens += 1
        return True

    def append_tokens(self, seq_id: int, k: int) -> bool:
        if k <= 0:
            return True
        s = self._seqs[seq_id]
        if s.swapped:
            raise ValueError(f"seq {seq_id} is swapped out")
        need = self.blocks_for(s.n_tokens + k) - s.n_blocks
        targets = self._cow_targets(s)
        fresh = max(0, need) + sum(
            1 for nid in targets if self._nodes[nid].refcount > 1)
        if fresh > len(self._free) + len(self._lru):
            return False
        if targets:
            self._cow_unshare(s, targets)
        for _ in range(max(0, need)):
            s.block_table.append(self._pop_block())
        s.n_tokens += k
        self._used_tokens += k
        return True

    def swap_out(self, seq_id: int) -> int:
        s = self._seqs[seq_id]
        if s.swapped:
            return 0
        chain = self._chains.get(seq_id, [])
        freed = len(s.block_table)
        self._free.extend(s.block_table[len(chain):])
        for nid in reversed(chain):
            self._deref(self._nodes[nid])
        self._chains[seq_id] = []
        s.block_table = []
        s.swapped = True
        self.swap_events += 1
        self._used_tokens -= s.n_tokens
        return freed

    def swap_in(self, seq_id: int) -> bool:
        s = self._seqs[seq_id]
        if not s.swapped:
            return True
        runs = self._chain_tokens.get(seq_id, [])
        matched = self._walk(runs)
        for node in matched:
            self._ref(node)
        chain = [nd.node_id for nd in matched]
        need = self.blocks_for(max(1, s.n_tokens)) - len(chain)
        if need > len(self._free) + len(self._lru):
            for nid in reversed(chain):
                self._deref(self._nodes[nid])
            return False
        table = [self._nodes[nid].block for nid in chain]
        parent = chain[-1] if chain else _ROOT
        for _ in range(need):
            block = self._pop_block()
            j = len(chain)
            if j < len(runs):  # re-register the restored prompt block
                node = self._register(parent, runs[j], block)
                chain.append(node.node_id)
                parent = node.node_id
            table.append(block)
        s.block_table = table
        s.swapped = False
        self._chains[seq_id] = chain
        self._used_tokens += s.n_tokens
        return True

    def release(self, seq_id: int) -> None:
        s = self._seqs.pop(seq_id)
        chain = self._chains.pop(seq_id, [])
        self._chain_tokens.pop(seq_id, None)
        if not s.swapped:
            self._free.extend(s.block_table[len(chain):])
            # deepest first so later eviction drains chains leaf-first
            for nid in reversed(chain):
                self._deref(self._nodes[nid])
            self._used_tokens -= s.n_tokens

    # ---------------------------------------------------------- invariants

    def check_invariants(self) -> None:
        cached = [nd.block for nd in self._nodes.values()]
        private: list[int] = []
        refs: Counter = Counter()
        for sid, s in self._seqs.items():
            chain = self._chains.get(sid, [])
            if s.swapped:
                assert not s.block_table, "swapped seq holds blocks"
                assert not chain, "swapped seq holds references"
                continue
            assert s.n_blocks * self.block_size >= s.n_tokens
            assert len(chain) <= len(s.block_table), "chain exceeds table"
            for i, nid in enumerate(chain):
                node = self._nodes[nid]
                assert s.block_table[i] == node.block, "chain/table mismatch"
                refs[nid] += 1
            private.extend(s.block_table[len(chain):])
        all_blocks = cached + private + self._free
        assert len(all_blocks) == len(set(all_blocks)), "double allocation"
        assert len(all_blocks) == self.n_blocks, "block leak"
        kids: Counter = Counter(
            nd.parent for nd in self._nodes.values() if nd.parent != _ROOT)
        assert len(self._index) == len(self._nodes), "index drift"
        for nid, node in self._nodes.items():
            assert node.refcount == refs.get(nid, 0), (
                f"refcount drift on node {nid}: "
                f"{node.refcount} != {refs.get(nid, 0)}"
            )
            assert (node.refcount == 0) == (nid in self._lru), (
                "LRU holds a referenced node" if node.refcount
                else "unreferenced node missing from LRU"
            )
            assert self._index.get(node.key) is node, "index drift"
            assert node.n_children == kids.get(nid, 0), "child count drift"
            if node.parent != _ROOT:
                parent = self._nodes.get(node.parent)
                assert parent is not None, "child outlived evicted parent"
                assert parent.refcount >= node.refcount, (
                    "chain reference not root-contiguous"
                )
        live = sum(s.n_tokens for s in self._seqs.values() if not s.swapped)
        assert self._used_tokens == live, (
            f"used_tokens counter drifted: {self._used_tokens} != {live}"
        )
