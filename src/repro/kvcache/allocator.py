"""Paged KV-cache block allocator (vLLM-style bookkeeping, TPU-adapted).

Tracks a fixed pool of KV blocks (block_size tokens each, in the paper's
model-independent per-token units — footnote 1).  Sequences own block
tables; admission, growth, swap-out and swap-in are all expressed in whole
blocks.  The allocator is pure bookkeeping: the tensor cache lives in the
engine; on TPU the block table is what the Pallas paged-attention kernel
walks (kernels/paged_attention.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


class OutOfBlocks(Exception):
    pass


@dataclasses.dataclass
class SeqAlloc:
    seq_id: int
    block_table: list[int]
    n_tokens: int = 0
    swapped: bool = False

    @property
    def n_blocks(self) -> int:
        return len(self.block_table)


class BlockAllocator:
    def __init__(self, total_tokens: int, block_size: int = 16):
        if total_tokens <= 0 or block_size <= 0:
            raise ValueError("positive sizes required")
        self.block_size = block_size
        self.n_blocks = total_tokens // block_size
        self._free: list[int] = list(range(self.n_blocks))
        self._seqs: dict[int, SeqAlloc] = {}
        self.swap_events = 0
        # incremental occupancy counter: sum of n_tokens over LIVE (non-
        # swapped) sequences, maintained by every mutator so used_tokens is
        # O(1) — the engine reads it per admission pass and per decode
        # window; check_invariants re-derives and asserts it
        self._used_tokens = 0

    # ------------------------------------------------------------- queries

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def free_tokens(self) -> int:
        return self.free_blocks * self.block_size

    @property
    def used_tokens(self) -> int:
        return self._used_tokens

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= self.free_blocks

    def seq(self, seq_id: int) -> SeqAlloc:
        return self._seqs[seq_id]

    def live_seqs(self) -> list[int]:
        return [k for k, s in self._seqs.items() if not s.swapped]

    # ------------------------------------------------------------ mutation

    def admit(self, seq_id: int, n_tokens: int) -> SeqAlloc:
        need = self.blocks_for(max(1, n_tokens))
        if need > self.free_blocks:
            raise OutOfBlocks(f"need {need} blocks, have {self.free_blocks}")
        blocks = [self._free.pop() for _ in range(need)]
        alloc = SeqAlloc(seq_id=seq_id, block_table=blocks, n_tokens=n_tokens)
        self._seqs[seq_id] = alloc
        self._used_tokens += n_tokens
        return alloc

    def append_token(self, seq_id: int) -> bool:
        """Grow a sequence by one token; returns False if a new block was
        needed but the pool is exhausted (caller must swap someone out)."""
        s = self._seqs[seq_id]
        if s.swapped:
            raise ValueError(f"seq {seq_id} is swapped out")
        if s.n_tokens + 1 > s.n_blocks * self.block_size:
            if not self._free:
                return False
            s.block_table.append(self._free.pop())
        s.n_tokens += 1
        self._used_tokens += 1
        return True

    def append_tokens(self, seq_id: int, k: int) -> bool:
        """Grow a sequence by ``k`` tokens at once (all-or-nothing).

        Equivalent to ``k`` successful ``append_token`` calls but O(new
        blocks) instead of O(k): the engine's multi-iteration decode
        windows pre-size ``k`` so every append is known to fit, then
        commit the growth in one call.  Returns False (and allocates
        nothing) if the pool cannot host all ``k`` tokens.
        """
        if k <= 0:
            return True
        s = self._seqs[seq_id]
        if s.swapped:
            raise ValueError(f"seq {seq_id} is swapped out")
        need = self.blocks_for(s.n_tokens + k) - s.n_blocks
        if need > len(self._free):
            return False
        for _ in range(need):
            s.block_table.append(self._free.pop())
        s.n_tokens += k
        self._used_tokens += k
        return True

    def swap_out(self, seq_id: int) -> int:
        """Release a live sequence's blocks (KV content moves to host in the
        engine).  Returns the number of freed blocks."""
        s = self._seqs[seq_id]
        if s.swapped:
            return 0
        freed = len(s.block_table)
        self._free.extend(s.block_table)
        s.block_table = []
        s.swapped = True
        self.swap_events += 1
        self._used_tokens -= s.n_tokens
        return freed

    def swap_in(self, seq_id: int) -> bool:
        """Re-allocate blocks for a swapped sequence; False if no room."""
        s = self._seqs[seq_id]
        if not s.swapped:
            return True
        need = self.blocks_for(max(1, s.n_tokens))
        if need > self.free_blocks:
            return False
        s.block_table = [self._free.pop() for _ in range(need)]
        s.swapped = False
        self._used_tokens += s.n_tokens
        return True

    def release(self, seq_id: int) -> None:
        s = self._seqs.pop(seq_id)
        self._free.extend(s.block_table)
        if not s.swapped:
            self._used_tokens -= s.n_tokens

    def check_invariants(self) -> None:
        owned = [b for s in self._seqs.values() for b in s.block_table]
        all_blocks = owned + self._free
        assert len(all_blocks) == len(set(all_blocks)), "double allocation"
        assert len(all_blocks) == self.n_blocks, "block leak"
        for s in self._seqs.values():
            if not s.swapped:
                assert s.n_blocks * self.block_size >= s.n_tokens
        live = sum(s.n_tokens for s in self._seqs.values() if not s.swapped)
        assert self._used_tokens == live, (
            f"used_tokens counter drifted: {self._used_tokens} != {live}"
        )
