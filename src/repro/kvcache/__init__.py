"""Paged KV-cache block accounting."""

from repro.kvcache.allocator import BlockAllocator, OutOfBlocks, SeqAlloc
from repro.kvcache.prefix import PrefixAwareAllocator, PrefixNode

__all__ = [
    "BlockAllocator",
    "OutOfBlocks",
    "PrefixAwareAllocator",
    "PrefixNode",
    "SeqAlloc",
]
