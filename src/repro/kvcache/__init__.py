"""Paged KV-cache block accounting."""

from repro.kvcache.allocator import BlockAllocator, OutOfBlocks, SeqAlloc

__all__ = ["BlockAllocator", "OutOfBlocks", "SeqAlloc"]
