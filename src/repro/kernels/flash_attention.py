"""Causal (optionally sliding-window) GQA flash attention — Pallas TPU
kernel for the prefill path.

Standard two-level online-softmax tiling adapted to the TPU memory
hierarchy: q tiles of (block_q, hd) stay resident in VMEM while (block_k,
hd) K/V tiles stream in; the kv-block grid axis is sequential ('arbitrary')
so m/l/acc scratch carries across kv tiles; causal (and SWA) tiles that
cannot contribute are skipped entirely with pl.when — for window W the work
drops from O(S^2) to O(S*W), which is what makes the dense archs' long-
context serving variant honest (DESIGN.md §4).

Layouts:
  q: (B, nh, S, hd) -> grid (B, nh, S/bq, S/bk)
  k/v: (B, n_kv, S, hd), kv head = q head // qpk
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    q_ref,   # (1, 1, bq, hd)
    k_ref,   # (1, 1, bk, hd)
    v_ref,   # (1, 1, bk, hd)
    o_ref,   # (1, 1, bq, hd)
    m_ref,   # (bq, 1)
    l_ref,   # (bq, 1)
    acc_ref, # (bq, hd)
    *,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    window: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # causal: this kv block contributes iff k_start <= q_end
    in_causal = k_start <= q_start + block_q - 1
    # SWA: skip blocks entirely left of every query's window
    in_window = (window == 0) | (k_start + block_k - 1 > q_start - window)

    @pl.when(in_causal & in_window)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                            # (bq, bk)
        q_ids = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        k_ids = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        mask = k_ids <= q_ids
        if window:
            mask &= k_ids > q_ids - window
        s = jnp.where(mask, s, -jnp.inf)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        # rows with all -inf (fully masked) keep m = -inf; guard exp
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(
            jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0
        )
        p = jnp.where(
            jnp.isfinite(s), jnp.exp(s - safe_m[:, None]), 0.0
        )
        l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
        acc_ref[...] = alpha[:, None] * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q,   # (B, nh, S, hd), pre-scaled by hd**-0.5
    k,   # (B, n_kv, S, hd)
    v,
    *,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    b, nh, s, hd = q.shape
    n_kv = k.shape[1]
    qpk = nh // n_kv
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must be divisible by block sizes")
    nq, nk = s // block_q, s // block_k

    grid = (b, nh, nq, nk)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, n_kv_blocks=nk,
        window=window,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b_, h_, iq_, ik_: (b_, h_, iq_, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h_, iq_, ik_: (b_, h_ // qpk, ik_, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h_, iq_, ik_: (b_, h_ // qpk, ik_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, h_, iq_, ik_: (b_, h_, iq_, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out
