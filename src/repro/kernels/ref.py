"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """q: (B, n_kv, qpk, hd) pre-scaled; pages: (P, bs, n_kv, hd)."""
    b, n_kv, qpk, hd = q.shape
    max_pages = block_tables.shape[1]
    bs = k_pages.shape[1]
    tables = jnp.clip(block_tables, 0, k_pages.shape[0] - 1)
    # gather each sequence's pages: (B, max_pages, bs, n_kv, hd)
    k = k_pages[tables]
    v = v_pages[tables]
    k = k.reshape(b, max_pages * bs, n_kv, hd)
    v = v.reshape(b, max_pages * bs, n_kv, hd)
    s = jnp.einsum("bngh,btnh->bngt", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    ids = jnp.arange(max_pages * bs)[None]
    mask = ids < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngt,btnh->bngh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_ref(q, k, v, window: int = 0):
    """q: (B,nh,S,hd) pre-scaled; k/v: (B,n_kv,S,hd); causal (+SWA)."""
    b, nh, s, hd = q.shape
    n_kv = k.shape[1]
    qpk = nh // n_kv
    kr = jnp.repeat(k, qpk, axis=1)
    vr = jnp.repeat(v, qpk, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32))
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = ki <= qi
    if window:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
