"""Chunkwise mLSTM sequence mixer — Pallas TPU kernel.

The training hot-spot of the xLSTM architecture (xlstm-350m in the assigned
pool): the matrix-memory recurrence

    C_t = f_t C_{t-1} + i_t (k_t/√d) v_tᵀ ,  h_t = (q_t·C_t) / max(|q_t·n_t|, e^{-m_t})

computed in its chunkwise-parallel form (quadratic only within a chunk,
O(hd²) recurrent state handed across chunks).  TPU mapping: the chunk axis
is a SEQUENTIAL grid dimension; the (hd, hd) matrix state C, the normalizer
n and the stabilizer m live in VMEM scratch across grid steps — the same
carried-accumulator pattern as flash attention, but the carry is the
model's recurrent state rather than softmax statistics.  All intra-chunk
math is (c × c) and (c × hd) MXU work.

Layouts:
  q, k, v: (B, H, S, hd)   i_raw, log_f: (B, H, S)
  out:     (B, H, S, hd)
  Grid (B, H, S/c) with the chunk axis 'arbitrary' (sequential).

The pure-jnp oracle is ``repro.models.ssm.mlstm_forward`` (the exact
per-step recurrence); equivalence of the chunkwise math is additionally
property-tested at the model level (tests/test_model_consistency.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    q_ref,    # (1, 1, c, hd)
    k_ref,    # (1, 1, c, hd)
    v_ref,    # (1, 1, c, hd)
    i_ref,    # (1, 1, c)
    f_ref,    # (1, 1, c)
    o_ref,    # (1, 1, c, hd)
    c_state,  # (hd, hd) f32 scratch
    n_state,  # (1, hd)  f32 scratch
    m_state,  # (1, 1)   f32 scratch
    *,
    chunk: int,
    scale: float,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        c_state[...] = jnp.zeros_like(c_state)
        n_state[...] = jnp.zeros_like(n_state)
        m_state[...] = jnp.full_like(m_state, -1e30)

    q = q_ref[0, 0].astype(jnp.float32)                  # (c, hd)
    k = k_ref[0, 0].astype(jnp.float32) * scale
    v = v_ref[0, 0].astype(jnp.float32)
    i_raw = i_ref[0, 0].astype(jnp.float32)              # (c,)
    log_f = f_ref[0, 0].astype(jnp.float32)

    m0 = m_state[0, 0]
    fcum = jnp.cumsum(log_f)                             # F_t
    # D_tj = F_t - F_j + i_j   (j <= t), else -inf
    d = fcum[:, None] - fcum[None, :] + i_raw[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    d = jnp.where(causal, d, -jnp.inf)
    m_intra = jnp.max(d, axis=1)                         # (c,)
    m_inter = fcum + m0
    m_t = jnp.maximum(m_intra, m_inter)
    w = jnp.exp(d - m_t[:, None])                        # (c, c)
    inter = jnp.exp(m_inter - m_t)                       # (c,)

    qk = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (c, c)
    num = jax.lax.dot_general(
        qk * w, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) + inter[:, None] * jax.lax.dot_general(
        q, c_state[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (c, hd)
    den_sum = jnp.sum(qk * w, axis=1) + inter * jnp.sum(
        q * n_state[0][None, :], axis=1
    )
    den = jnp.maximum(jnp.abs(den_sum), jnp.exp(-m_t))
    o_ref[0, 0] = (num / den[:, None]).astype(o_ref.dtype)

    # chunk-final state handoff
    m_new = m_t[chunk - 1]
    wj = jnp.exp(fcum[chunk - 1] - fcum + i_raw - m_new)  # (c,)
    decay = jnp.exp(m_inter[chunk - 1] - m_new)
    c_state[...] = decay * c_state[...] + jax.lax.dot_general(
        k * wj[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    n_state[0, :] = decay * n_state[0, :] + jnp.sum(k * wj[:, None], axis=0)
    m_state[0, 0] = m_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk_kernel(
    q,       # (B, H, S, hd)
    k,
    v,
    i_raw,   # (B, H, S)
    log_f,   # (B, H, S)
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    b, h, s, hd = q.shape
    if s % chunk:
        raise ValueError(f"S={s} must be divisible by chunk={chunk}")
    nc = s // chunk
    grid = (b, h, nc)
    kernel = functools.partial(_kernel, chunk=chunk, scale=hd ** -0.5)
    qkv_spec = pl.BlockSpec((1, 1, chunk, hd),
                            lambda b_, h_, j_: (b_, h_, j_, 0))
    gate_spec = pl.BlockSpec((1, 1, chunk),
                             lambda b_, h_, j_: (b_, h_, j_))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qkv_spec, qkv_spec, qkv_spec, gate_spec, gate_spec],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, i_raw, log_f)
