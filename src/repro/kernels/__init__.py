"""Pallas TPU kernels for the serving hot-spots (+ jnp oracles)."""

from repro.kernels.mlstm_chunk import mlstm_chunk_kernel
from repro.kernels.ops import flash_prefill, paged_gqa_decode
from repro.kernels.ref import flash_attention_ref, paged_attention_ref

__all__ = [
    "mlstm_chunk_kernel",
    "flash_prefill",
    "paged_gqa_decode",
    "flash_attention_ref",
    "paged_attention_ref",
]
