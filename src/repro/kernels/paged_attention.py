"""Paged GQA decode attention — Pallas TPU kernel.

The serving hot-spot of a vLLM-style engine: one new query token per
sequence attends to that sequence's KV cache, which lives in a PAGED pool
(pages of ``block_size`` tokens) indexed by a per-sequence block table.
This is the TPU adaptation of vLLM's PagedAttention (DESIGN.md §3): instead
of GPU pointer-chasing, the grid walks the block table via scalar prefetch
and DMAs (page, kv_head)-tiles HBM->VMEM, accumulating an online softmax
over pages.

Layouts (token-major pages, MXU/VPU aligned: page tiles are
(block_size, head_dim) with head_dim in {64, 80, 128, 256}):

  q:            (B, n_kv, qpk, hd)   qpk = q heads per kv head
  k_pages:      (n_pages, block_size, n_kv, hd)
  v_pages:      (n_pages, block_size, n_kv, hd)
  block_tables: (B, max_pages) int32  (entries beyond the length clamped 0)
  lengths:      (B,) int32            context length per sequence
  out:          (B, n_kv, qpk, hd)

Grid: (B, n_kv, max_pages); the page axis is 'arbitrary' (sequential) so
the m/l/acc scratch carries across pages; the output block is revisited and
written once on the final page.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    # scalar-prefetch operands
    block_tables_ref,   # (B, max_pages) int32, SMEM
    lengths_ref,        # (B,) int32, SMEM
    # array operands (VMEM tiles per BlockSpec)
    q_ref,              # (1, 1, qpk, hd)
    k_ref,              # (1, block_size, 1, hd)
    v_ref,              # (1, block_size, 1, hd)
    o_ref,              # (1, 1, qpk, hd)
    # scratch
    m_ref,              # (qpk, 1) f32
    l_ref,              # (qpk, 1) f32
    acc_ref,            # (qpk, hd) f32
    *,
    block_size: int,
    max_pages: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    length = lengths_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j * block_size < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (qpk, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                               # (qpk, bs)
        token_ids = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1
        )
        s = jnp.where(token_ids < length, s, -jnp.inf)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)                 # (qpk,)
        p = jnp.exp(s - m_new[:, None])                 # (qpk, bs)
        l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
        acc_ref[...] = alpha[:, None] * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_new

    @pl.when(j == max_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_size", "interpret")
)
def paged_attention(
    q,              # (B, n_kv, qpk, hd), already scaled by hd**-0.5
    k_pages,        # (n_pages, block_size, n_kv, hd)
    v_pages,
    block_tables,   # (B, max_pages) int32
    lengths,        # (B,) int32
    *,
    block_size: int = 16,
    interpret: bool = True,
):
    b, n_kv, qpk, hd = q.shape
    max_pages = block_tables.shape[1]
    # clamp table entries so masked-out pages still index a real page
    tables = jnp.clip(block_tables, 0, k_pages.shape[0] - 1).astype(jnp.int32)

    grid = (b, n_kv, max_pages)

    def q_map(b_, h_, j_, tables_, lengths_):
        return (b_, h_, 0, 0)

    def kv_map(b_, h_, j_, tables_, lengths_):
        return (tables_[b_, j_], 0, h_, 0)

    def o_map(b_, h_, j_, tables_, lengths_):
        return (b_, h_, 0, 0)

    kernel = functools.partial(
        _kernel, block_size=block_size, max_pages=max_pages
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, qpk, hd), q_map),
                pl.BlockSpec((1, block_size, 1, hd), kv_map),
                pl.BlockSpec((1, block_size, 1, hd), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, qpk, hd), o_map),
            scratch_shapes=[
                pltpu.VMEM((qpk, 1), jnp.float32),
                pltpu.VMEM((qpk, 1), jnp.float32),
                pltpu.VMEM((qpk, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tables, lengths.astype(jnp.int32), q, k_pages, v_pages)
    return out
