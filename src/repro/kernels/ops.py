"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True when no TPU is present (this container), so
the same call sites run the kernel body in interpret mode on CPU and compile
to Mosaic on a real TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import paged_attention as _paged


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def paged_gqa_decode(
    q,              # (B, nh, hd) one query token per sequence
    k_pages,        # (P, block_size, n_kv, hd)
    v_pages,
    block_tables,   # (B, max_pages) int32
    lengths,        # (B,) int32
    *,
    block_size: int = 16,
    interpret: bool | None = None,
):
    """Paged decode attention; returns (B, nh, hd)."""
    b, nh, hd = q.shape
    n_kv = k_pages.shape[2]
    qpk = nh // n_kv
    qg = (q * hd ** -0.5).reshape(b, n_kv, qpk, hd)
    out = _paged(
        qg, k_pages, v_pages, block_tables, lengths,
        block_size=block_size,
        interpret=_default_interpret() if interpret is None else interpret,
    )
    return out.reshape(b, nh, hd)


def flash_prefill(
    q,   # (B, S, nh, hd)
    k,   # (B, S, n_kv, hd)
    v,
    *,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """Causal (optionally SWA) prefill attention; returns (B, S, nh, hd)."""
    hd = q.shape[-1]
    qt = jnp.swapaxes(q * hd ** -0.5, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _flash(
        qt, kt, vt,
        window=window, block_q=block_q, block_k=block_k,
        interpret=_default_interpret() if interpret is None else interpret,
    )
    return jnp.swapaxes(out, 1, 2)
