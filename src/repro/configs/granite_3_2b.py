"""granite-3-2b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base].

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
32 heads divisible by 16 -> head sharding.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    kind="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    rope_theta=10_000.0,
    tie_embeddings=True,
)

LONG_CONTEXT_OVERRIDES = {"sliding_window": 8192}
