"""starcoder2-7b [dense] — GQA, RoPE [arXiv:2402.19173].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
36 heads % 16 != 0 -> head_dim sharding.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    kind="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    rope_theta=1_000_000.0,
)

LONG_CONTEXT_OVERRIDES = {"sliding_window": 8192}
