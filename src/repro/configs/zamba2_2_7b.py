"""zamba2-2.7b [hybrid] — Mamba2 backbone + one SHARED attention block
applied every 6 layers [arXiv:2411.15242].

54L d_model=2560 32H (kv=32, MHA in the shared block) d_ff=10240
vocab=32000, ssm_state=64.  54 = 9 super-blocks x 6 mamba2 layers; the
shared attention+MLP block's weights are reused at each application
(Zamba's parameter-sharing trick), each application keeping its own KV
cache.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    kind="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    rope_theta=10_000.0,
    ssm_state=64,
    attn_every=6,
)

LONG_CONTEXT_OVERRIDES = {}  # mamba state is O(1); attn KV sharded over seq
