"""whisper-tiny [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

4L d_model=384 6H (kv=6, MHA) d_ff=1536 vocab=51865.  The mel+conv frontend
is a stub per the assignment carve-out: input_specs() supplies precomputed
frame embeddings (B, 1500, 384).  Whisper uses learned absolute positions
(use_rope=False); max_position is stretched to cover the assigned 32k
shapes (the model card caps decode at 448 — noted in DESIGN.md).
long_500k: SKIPPED (full-attention enc-dec; no long-context variant).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    kind="encdec",
    n_layers=4,            # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    use_rope=False,
    max_position=32_776,
    n_audio_frames=1500,
)

LONG_CONTEXT_OVERRIDES = None  # long_500k not applicable (DESIGN.md §4)
