"""h2o-danube-1.8b [dense] — llama+mistral mix with native sliding-window
attention [arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
Native SWA: long_500k runs with the arch's own window (ring-buffer cache).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    kind="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    rope_theta=10_000.0,
    sliding_window=4096,
)

LONG_CONTEXT_OVERRIDES = {}  # native SWA already sub-quadratic
