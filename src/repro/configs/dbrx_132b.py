"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    kind="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    rope_theta=500_000.0,
    n_experts=16,
    top_k=4,
)

LONG_CONTEXT_OVERRIDES = {"sliding_window": 8192}
