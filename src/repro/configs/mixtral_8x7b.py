"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2,
sliding window 4096 (Mixtral v0.1 card).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    kind="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    n_experts=8,
    top_k=2,
)

LONG_CONTEXT_OVERRIDES = {}  # native SWA
