"""llava-next-34b [vlm] — anyres tiling, ViT frontend stubbed
[hf:llava-hf/llava-v1.6-mistral-7b-hf family scaled to 34B].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  The vision encoder
+ projector are a stub per the assignment carve-out: input_specs() provides
anyres patch embeddings (B, 2880, 7168) prepended to the text tokens.
56 heads % 16 != 0 -> head_dim sharding.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    kind="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    rope_theta=5_000_000.0,
    n_image_tokens=2880,   # anyres: base 576 + 4 tiles x 576
)

LONG_CONTEXT_OVERRIDES = {"sliding_window": 8192}
