"""llama3.2-3b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B family].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256, RoPE theta 500k.
24 heads % 16 model-parallel != 0 -> head_dim sharding (DESIGN.md §5).
long_500k uses the sliding-window decode variant (ring buffer 8192) — the
honest sub-quadratic mechanism for a full-attention dense arch (DESIGN §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    kind="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    rope_theta=500_000.0,
)

# selected only by the long_500k input shape
LONG_CONTEXT_OVERRIDES = {"sliding_window": 8192}
