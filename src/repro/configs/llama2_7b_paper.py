"""llama2-7b [dense] — the paper's own testbed model (Touvron et al. 2023),
kept as an eleventh config so the paper's serving experiments (Fig. 3/7)
have their exact backend architecture available.

32L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=32000.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    kind="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=32000,
    rope_theta=10_000.0,
)

LONG_CONTEXT_OVERRIDES = {"sliding_window": 8192}
