"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H d_ff=0 (no separate FFN; the xLSTM block is the mixer)
vocab=50304.  Layers alternate mLSTM/sLSTM (slstm_every=2 -> 12 pairs).
Recurrent state is O(1) per sequence: long_500k runs natively, and the
paper's memory-centric cost model degenerates to linear (DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    kind="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=2,   # recurrence encodes position; no pos table / rope used
)

LONG_CONTEXT_OVERRIDES = {}  # native O(1) state
