"""Assigned architecture registry: ``get_config(arch_id)`` + input shapes.

Every entry cites its source in the module docstring.  ``--arch <id>`` in
the launchers resolves through this registry.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "llama3.2-3b": "llama3_2_3b",
    "whisper-tiny": "whisper_tiny",
    "granite-3-2b": "granite_3_2b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "mixtral-8x7b": "mixtral_8x7b",
    "dbrx-132b": "dbrx_132b",
    "llava-next-34b": "llava_next_34b",
    "xlstm-350m": "xlstm_350m",
    "zamba2-2.7b": "zamba2_2_7b",
    "starcoder2-7b": "starcoder2_7b",
    "llama2-7b": "llama2_7b_paper",
}

ASSIGNED_ARCHS = [k for k in _MODULES if k != "llama2-7b"]
ALL_ARCHS = list(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str, *, shape: str | None = None) -> ModelConfig:
    """Resolve an arch id (optionally specialized for an input shape).

    ``shape='long_500k'`` applies the arch's LONG_CONTEXT_OVERRIDES (e.g.
    the sliding-window decode variant for dense archs).  Raises ValueError
    if the arch skips that shape (whisper x long_500k).
    """
    mod = _module(arch)
    cfg: ModelConfig = mod.CONFIG
    if shape == "long_500k":
        over = getattr(mod, "LONG_CONTEXT_OVERRIDES", {})
        if over is None:
            raise ValueError(
                f"{arch} skips long_500k (see DESIGN.md §4 skip notes)"
            )
        if over:
            cfg = dataclasses.replace(cfg, **over)
    return cfg


def supports_shape(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return getattr(_module(arch), "LONG_CONTEXT_OVERRIDES", {}) is not None
    return True


__all__ = [
    "ALL_ARCHS",
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "get_config",
    "supports_shape",
]
