"""Training substrate: AdamW, LM loss/train step, data pipeline, ckpt."""

from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.data import DataConfig, SyntheticLM, data_iterator
from repro.training.optimizer import (
    AdamState,
    AdamWConfig,
    adamw_update,
    init_adamw,
    lr_schedule,
)
from repro.training.train import chunked_lm_loss, lm_loss, make_eval_step, make_train_step

__all__ = [
    "restore_checkpoint",
    "save_checkpoint",
    "DataConfig",
    "SyntheticLM",
    "data_iterator",
    "AdamState",
    "AdamWConfig",
    "adamw_update",
    "init_adamw",
    "lr_schedule",
    "chunked_lm_loss",
    "lm_loss",
    "make_eval_step",
    "make_train_step",
]
