"""Synthetic token data pipeline: deterministic, shardable, dependency-free.

Produces next-token-predictable streams (a mixture of ngram-Markov chains
and copy patterns) so a ~100M-param model visibly learns within a few
hundred steps — used by the end-to-end training example.  The pipeline is
an iterator of host numpy batches; the launcher shards them onto the mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-chain order and determinism level of the synthetic language
    order: int = 2
    temperature: float = 0.35


class SyntheticLM:
    """Order-k Markov chain over the vocab with a sparse transition table."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # each context hashes to a row of 8 plausible next tokens
        self.n_rows = 8192
        self.table = rng.integers(0, v, size=(self.n_rows, 8))
        self.weights = rng.dirichlet(
            np.full(8, cfg.temperature), size=self.n_rows
        )

    def _ctx_hash(self, ctx: np.ndarray) -> np.ndarray:
        h = np.zeros(ctx.shape[0], np.int64)
        for k in range(ctx.shape[1]):
            h = h * 1000003 + ctx[:, k]
        return np.abs(h) % self.n_rows

    def sample_batch(self, rng: np.random.Generator, batch: int,
                     seq: int) -> np.ndarray:
        cfg = self.cfg
        out = np.zeros((batch, seq), np.int64)
        out[:, : cfg.order] = rng.integers(0, cfg.vocab,
                                           size=(batch, cfg.order))
        for t in range(cfg.order, seq):
            rows = self._ctx_hash(out[:, t - cfg.order : t])
            choices = self.table[rows]                      # (B, 8)
            w = self.weights[rows]
            cum = np.cumsum(w, axis=1)
            u = rng.random((batch, 1))
            idx = (u > cum).sum(axis=1)
            out[:, t] = choices[np.arange(batch), idx]
        return out.astype(np.int32)


def data_iterator(cfg: DataConfig) -> Iterator[dict]:
    lm = SyntheticLM(cfg)
    rng = np.random.default_rng(cfg.seed + 1)
    while True:
        yield {"tokens": lm.sample_batch(rng, cfg.global_batch, cfg.seq_len)}
