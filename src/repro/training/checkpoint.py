"""Msgpack checkpointing for param/optimizer pytrees (orbax-free).

Trees are flattened to (path, array) pairs; arrays are serialized with
dtype/shape headers.  Works for any pytree of jnp/np arrays + scalars.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import msgpack
import numpy as np


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {"step": step, "arrays": {}}
    for kpath, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        payload["arrays"][_key_str(kpath)] = {
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore_checkpoint(path: str, tree_like: Any) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    arrays = payload["arrays"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for kpath, leaf in flat:
        k = _key_str(kpath)
        if k not in arrays:
            raise KeyError(f"checkpoint missing leaf {k}")
        rec = arrays[k]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(
            rec["shape"]
        )
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {k}: ckpt {arr.shape} vs {np.shape(leaf)}"
            )
        leaves.append(arr)
    return (
        jax.tree_util.tree_unflatten(treedef, leaves),
        int(payload["step"]),
    )
