"""Training step: next-token cross-entropy + AdamW, shared by the smoke
tests, the end-to-end training example, and the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import Model, ModelConfig
from repro.training.optimizer import AdamState, AdamWConfig, adamw_update


def lm_loss(logits, tokens, loss_mask=None, moe_aux=0.0, aux_w: float = 0.01):
    """Shifted next-token CE.  logits: (B,S,V); tokens: (B,S)."""
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if loss_mask is not None:
        msk = loss_mask[:, 1:].astype(jnp.float32)
        loss = (nll * msk).sum() / jnp.maximum(msk.sum(), 1.0)
    else:
        loss = nll.mean()
    return loss + aux_w * moe_aux


def chunked_lm_loss(
    x, head, tokens, loss_mask=None, moe_aux=0.0, aux_w: float = 0.01,
    chunk: int = 512,
):
    """Shifted next-token CE with a CHUNKED vocab projection.

    ``x``: final-normed hidden (B,S,D); ``head``: (D,V).  Full (B,S,V)
    logits do not fit HBM at the 4k-train shape for 100k+ vocabs; scanning
    over sequence chunks keeps only (B,chunk,V) live (the standard MaxText
    trick).  Numerics identical to ``lm_loss``.
    """
    b, s, d = x.shape
    # targets shifted left; the final position is masked out
    tgt = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    if loss_mask is None:
        msk = jnp.ones((b, s), jnp.float32)
    else:
        msk = loss_mask.astype(jnp.float32)
    msk = jnp.concatenate(
        [msk[:, 1:], jnp.zeros((b, 1), jnp.float32)], axis=1
    )
    c = s // max(1, s // min(chunk, s))
    while s % c:
        c += 1
    ng = s // c
    xg = jnp.moveaxis(x.reshape(b, ng, c, d), 1, 0)
    tg = jnp.moveaxis(tgt.reshape(b, ng, c), 1, 0)
    mg = jnp.moveaxis(msk.reshape(b, ng, c), 1, 0)

    @jax.checkpoint
    def body(acc, inp):
        # checkpointed: the backward pass re-projects the chunk instead of
        # keeping every chunk's (B,c,V) logits alive (33 GiB at train_4k)
        x_c, t_c, m_c = inp
        lg = jnp.einsum(
            "bcd,dv->bcv", x_c, head, preferred_element_type=jnp.float32
        )
        logz = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, t_c[..., None], axis=-1)[..., 0]
        return (
            acc[0] + jnp.sum((logz - gold) * m_c),
            acc[1] + jnp.sum(m_c),
        ), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (xg, tg, mg)
    )
    return tot / jnp.maximum(cnt, 1.0) + aux_w * moe_aux


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch``: {"tokens": (B,S) int32, optional "embeds", optional
    "loss_mask": (B,S)}.  Pure function — jit/pjit it at the call site with
    the mesh + shardings of your choice (see repro.launch).
    """

    def loss_fn(params, batch):
        x, aux = model.hidden(params, batch)
        return chunked_lm_loss(
            x,
            model.head_matrix(params),
            batch["tokens"],
            batch.get("loss_mask"),
            moe_aux=aux,
        )

    def train_step(params, opt_state: AdamState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(
            opt_cfg, grads, opt_state, params
        )
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        logits, aux = model.forward(params, batch)
        return lm_loss(logits, batch["tokens"], batch.get("loss_mask"),
                       moe_aux=aux)

    return eval_step
