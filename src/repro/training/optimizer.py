"""AdamW from scratch (no optax offline) with a linear-warmup cosine decay.

State is a pytree mirroring the params (m, v) plus a scalar step; everything
shards with the same PartitionSpecs as the parameters (ZeRO-style: optimizer
states live wherever the parameter shard lives).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray          # ()
    m: Any                     # pytree like params
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def init_adamw(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        m=zeros,
        v=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
    )


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, state: AdamState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(
        lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, state.m, grads
    )
    v = jax.tree.map(
        lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g, state.v, grads
    )
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = lr_schedule(cfg, step)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamState(step=step, m=m, v=v), {
        "grad_norm": gnorm,
        "lr": lr,
    }
