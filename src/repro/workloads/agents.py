"""The paper's 9-class agent workload suite (§5.1 Workloads).

Classes: (a) MapReduce Summarization (MRS), (b) Plan-and-Execution (PE),
(c) Code Checking (CC), (d) KBQA Verification (KBQAV), (e) Equation
Verification (EV), (f) Fact Verification (FV), (g) ALFWorld Interaction
(ALFWI), (h) Document Merging (DM), (i) Self Consistency (SC).

Sampling probabilities follow the paper: small 72%, medium 26%, large 2%
(small = EV, FV, CC, ALFWI, KBQAV; medium = PE, SC; large = DM, MRS — the
paper's "CG" in the medium list is its own enumeration's CC).

Per Appendix A, each inference stage of an agent class has a *stable*
demand distribution across trial runs, modeled as a skew-normal over
prefill/decode token lengths.  Each sampled agent also carries a synthetic
prompt whose token statistics encode the latent demand (length and keyword
counts correlate with cost), which is what makes the per-class TF-IDF→MLP
predictor learnable exactly as the paper exploits.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Callable, Optional

import numpy as np

from repro.core.cost import InferenceSpec, MemoryFamily, agent_cost
from repro.sim.metrics import SloTier


def skew_normal(
    rng: np.random.Generator, loc: float, scale: float, alpha: float, size=None
):
    """Azzalini skew-normal sampler (scipy-free)."""
    delta = alpha / math.sqrt(1.0 + alpha * alpha)
    z0 = np.abs(rng.standard_normal(size))
    z1 = rng.standard_normal(size)
    x = delta * z0 + math.sqrt(1.0 - delta * delta) * z1
    return loc + scale * x


@dataclasses.dataclass(frozen=True)
class StageTemplate:
    """One stage of an agent's task graph."""

    n_parallel: tuple[int, int]          # [lo, hi] parallel inferences
    prefill: tuple[float, float, float]  # skew-normal (loc, scale, alpha)
    decode: tuple[float, float, float]
    # prefill of this stage scales with outputs of the previous stage
    # (e.g. MapReduce's reduce step reads all the map summaries)
    prefill_from_prev_outputs: float = 0.0


@dataclasses.dataclass(frozen=True)
class AgentClass:
    name: str
    size: str                            # small / medium / large
    stages: tuple[StageTemplate, ...]
    keywords: tuple[str, ...]
    # latent complexity multiplies decode lengths; the prompt encodes it
    complexity_spread: float = 0.35


@dataclasses.dataclass
class SampledAgent:
    cls: AgentClass
    stages: list[list[InferenceSpec]]
    prompt: str
    true_cost: float
    family: MemoryFamily = MemoryFamily.DENSE

    @property
    def name(self) -> str:
        return self.cls.name


# --------------------------------------------------------------------------
# The nine classes.  Token budgets chosen so that, at the simulator's default
# 30 tok/s/seq decode rate, solo JCTs land in the paper's buckets
# (small < 1 min, medium 1–10 min, large > 10 min).

AGENT_CLASSES: dict[str, AgentClass] = {
    "EV": AgentClass(
        "EV", "small",
        (StageTemplate((2, 4), (180, 40, 2.0), (60, 20, 2.0)),),
        ("equation", "verify", "algebra", "derivation", "lhs", "rhs"),
    ),
    "FV": AgentClass(
        "FV", "small",
        (
            StageTemplate((1, 1), (350, 15, 1.0), (90, 25, 2.0)),   # gen queries
            StageTemplate((2, 5), (260, 60, 2.0), (80, 25, 2.0)),   # verify claims
        ),
        ("fact", "claim", "evidence", "source", "citation", "react"),
    ),
    "CC": AgentClass(
        "CC", "small",
        (StageTemplate((2, 6), (420, 90, 2.5), (110, 35, 2.0)),),
        ("code", "lint", "bug", "unittest", "stacktrace", "patch"),
    ),
    "ALFWI": AgentClass(
        "ALFWI", "small",
        (
            StageTemplate((1, 2), (240, 50, 1.5), (50, 15, 1.5)),
            StageTemplate((1, 3), (280, 50, 1.5), (60, 15, 1.5)),
        ),
        ("household", "navigate", "pickup", "drawer", "goal", "action"),
    ),
    "KBQAV": AgentClass(
        "KBQAV", "small",
        (StageTemplate((2, 5), (300, 70, 2.0), (70, 20, 2.0)),),
        ("knowledge", "entity", "triple", "sparql", "answer", "wikidata"),
    ),
    "PE": AgentClass(
        "PE", "medium",
        (
            StageTemplate((1, 1), (500, 100, 2.0), (250, 60, 2.0)),  # plan
            StageTemplate((3, 8), (450, 120, 2.0), (450, 140, 2.5)), # execute
            StageTemplate((1, 1), (300, 60, 1.0), (200, 60, 2.0),
                          prefill_from_prev_outputs=1.0),            # report
        ),
        ("plan", "subtask", "tool", "execute", "huggingface", "schedule"),
    ),
    "SC": AgentClass(
        "SC", "medium",
        (StageTemplate((8, 16), (380, 80, 2.0), (620, 180, 2.5)),),
        ("reasoning", "chain", "math", "vote", "consistency", "solution"),
    ),
    "DM": AgentClass(
        "DM", "large",
        (
            StageTemplate((6, 12), (2400, 500, 2.5), (700, 180, 2.0)),  # merge
            StageTemplate((6, 12), (900, 200, 2.0), (120, 40, 2.0)),    # score
            StageTemplate((1, 2), (1200, 250, 2.0), (800, 200, 2.0),
                          prefill_from_prev_outputs=0.5),               # final
        ),
        ("document", "merge", "paragraph", "outline", "dedupe", "graph"),
    ),
    "MRS": AgentClass(
        "MRS", "large",
        (
            StageTemplate((16, 40), (2600, 600, 2.5), (380, 100, 2.0)),  # map
            StageTemplate((1, 1), (500, 100, 1.0), (900, 220, 2.0),
                          prefill_from_prev_outputs=1.0),                # reduce
        ),
        ("summarize", "chunk", "mapreduce", "section", "digest", "corpus"),
    ),
}

SIZE_BUCKETS = {
    "small": ["EV", "FV", "CC", "ALFWI", "KBQAV"],
    "medium": ["PE", "SC"],
    "large": ["DM", "MRS"],
}
SIZE_PROBS = {"small": 0.72, "medium": 0.26, "large": 0.02}

_FILLER = (
    "the of and to in that it for with as on be at this by from or an are "
    "was but not have had they you his her its which will one all would "
    "there what about out up into than them can only other time new some"
).split()


def _synth_prompt(
    rng: np.random.Generator, cls: AgentClass, complexity: float, total_prefill: int
) -> str:
    """Prompt whose statistics encode the latent demand.

    Length tracks total prefill; per-class keyword *counts* track the
    complexity multiplier, so TF-IDF features carry the cost signal.
    """
    n_words = max(12, int(total_prefill / 14))
    n_kw = max(2, int(6 * complexity))
    words = list(rng.choice(_FILLER, size=n_words))
    for _ in range(n_kw):
        words.insert(int(rng.integers(0, len(words))), str(rng.choice(cls.keywords)))
    return " ".join(words)


def sample_agent(
    rng: np.random.Generator,
    cls_name: str,
    family: MemoryFamily = MemoryFamily.DENSE,
) -> SampledAgent:
    cls = AGENT_CLASSES[cls_name]
    complexity = float(
        np.clip(np.exp(rng.normal(0.0, cls.complexity_spread)), 0.4, 3.0)
    )
    stages: list[list[InferenceSpec]] = []
    prev_outputs = 0.0
    total_prefill = 0
    for st in cls.stages:
        n = int(rng.integers(st.n_parallel[0], st.n_parallel[1] + 1))
        specs = []
        for _ in range(n):
            p = st.prefill_from_prev_outputs * prev_outputs / max(1, n)
            p += float(np.clip(skew_normal(rng, *st.prefill), 16, 65536))
            p = min(p, 4096.0)  # context-window clamp (single inference)
            d = complexity * float(np.clip(skew_normal(rng, *st.decode), 4, 8192))
            specs.append(InferenceSpec(prefill=int(p), decode=max(1, int(d))))
        prev_outputs = float(sum(s.decode for s in specs))
        total_prefill += int(sum(s.prefill for s in specs))
        stages.append(specs)
    flat = [s for st in stages for s in st]
    cost = agent_cost(flat, family)
    prompt = _synth_prompt(rng, cls, complexity, total_prefill)
    return SampledAgent(
        cls=cls, stages=stages, prompt=prompt, true_cost=cost, family=family
    )


def sample_mixed_suite(
    rng: np.random.Generator, n_agents: int
) -> list[SampledAgent]:
    """The paper's 300-agent mixed suite (72/26/2 small/medium/large)."""
    out = []
    sizes = rng.choice(
        list(SIZE_PROBS), size=n_agents, p=list(SIZE_PROBS.values())
    )
    for s in sizes:
        cls_name = str(rng.choice(SIZE_BUCKETS[str(s)]))
        out.append(sample_agent(rng, cls_name))
    return out


# --------------------------------------------------------------------------
# Closed-loop workload family: agents whose NEXT stage is only known once
# the previous stage finished — the interactive regime the paper's workload
# suite abstracts away (its task graphs are fixed at arrival).  Each session
# is a stateful callable compatible with ``repro.api.AgentSpec.next_stage``:
# the serving layer feeds it the completed stage's ``StageOutcome`` and it
# returns the next turn's InferenceSpecs (or None to end the session).
# Turn demands are sampled LAZILY from the session's own child RNG, so the
# spec sequence is deterministic per session and — because it depends only
# on the turn counter, never on backend-specific outcome fields — identical
# across sim/engine/replicated backends (what the cross-backend conformance
# suite pins).  ``StageOutcome.new_tokens``/``time`` are available to custom
# sessions that want genuinely reactive behaviour.


@dataclasses.dataclass(frozen=True)
class ClosedLoopClass:
    """One closed-loop session family."""

    name: str
    turns: tuple[int, int]               # [lo, hi] total turns
    prefill: tuple[float, float, float]  # fresh per-turn prompt (skew-normal)
    decode: tuple[float, float, float]
    #: fraction of the session's accumulated outputs re-read each turn
    #: (chat: the whole conversation history; react: the last observations)
    carry: float
    fanout: tuple[int, int] = (1, 1)     # parallel tool calls per turn
    stop_prob: float = 0.0               # per-turn early stop (react loops)
    #: shared system-prompt length (tokens) prepended to EVERY turn's
    #: prompt — identical across all sessions of the family, so a
    #: prefix-aware KV cache reuses it across agents (and across turns)
    sys_prefix: int = 0
    #: [lo, hi] tool-call think time (workload seconds) between turns —
    #: the wall-clock gap while the agent executes tools / awaits a human
    #: before its next stage submits.  (0, 0) disables suspension (the
    #: default: legacy families consume no extra RNG draws and stay
    #: bit-identical to their pre-suspension streams)
    think: tuple = (0.0, 0.0)


CLOSED_LOOP_CLASSES: dict[str, ClosedLoopClass] = {
    # multi-turn chat: one inference per turn, prompt grows with the full
    # conversation history behind a family-shared system prompt
    "chat": ClosedLoopClass(
        "chat", (3, 8), (140, 40, 1.5), (90, 30, 2.0), carry=1.0,
        sys_prefix=256,
    ),
    # tool-call react loop: thought -> 1-3 parallel tool calls, short
    # decodes, carries only the recent observations, may stop early;
    # the (larger) shared prefix models the tool-catalog preamble
    "react": ClosedLoopClass(
        "react", (2, 10), (240, 60, 2.0), (48, 16, 2.0), carry=0.35,
        fanout=(1, 3), stop_prob=0.2, sys_prefix=384,
    ),
    # --- SLO-tiered family (PR 7): the two classes below are served
    # TOGETHER — latency-sensitive chat-style sessions sharing the fleet
    # with long-prompt batch summarizers whose big prefills are exactly
    # the admission stalls fused prefill absorbs ---
    # interactive tier: short turns, human in the loop, tight TTFT/TBT
    "interactive": ClosedLoopClass(
        "interactive", (3, 8), (120, 30, 1.5), (64, 20, 2.0), carry=1.0,
        sys_prefix=256,
    ),
    # batch tier: few turns, very long fresh prompts (document chunks),
    # long decodes, loose targets — throughput-oriented
    "batch": ClosedLoopClass(
        "batch", (1, 3), (900, 200, 1.5), (320, 80, 1.5), carry=0.25,
        sys_prefix=256,
    ),
    # --- think-time-heavy family (PR 9): agentic tool use where each
    # turn's decode is short but the tool call between turns takes
    # seconds of wall clock — the agent holds no decode slot while it
    # thinks, and its KV falls under the backend's retention policy ---
    "tooluse": ClosedLoopClass(
        "tooluse", (3, 8), (220, 50, 2.0), (40, 12, 2.0), carry=0.3,
        fanout=(1, 2), sys_prefix=384, think=(4.0, 12.0),
    ),
}


#: the SLO family's class names, in submission-interleave order
SLO_CLASSES: tuple[str, ...] = ("interactive", "batch")

#: per-tier latency targets (workload seconds) for the SLO closed-loop
#: family.  Calibrated for the canonical serving configurations
#: (sim: decode_rate=30 tok/s; engine: time_scale mapping one iteration
#: to ``token_scale/decode_rate`` seconds — see benchmarks/perf_slo.py):
#: interactive agents expect a first token while a human is still
#: watching and a readable streaming cadence; batch agents only need to
#: start within the minute and keep moving.
SLO_TIERS: dict[str, "SloTier"] = {
    "interactive": SloTier("interactive", ttft=20.0, tbt=2.0),
    "batch": SloTier("batch", ttft=120.0, tbt=8.0),
}


def slo_tier_of(cls_name: str) -> "Optional[SloTier]":
    """The latency tier of a closed-loop class (None if untiered)."""
    return SLO_TIERS.get(cls_name)


#: canonical (workload-scale) token-id space for the deterministic prompt
#: streams; engine backends fold ids into their own vocab with ``%``
CANON_VOCAB = 1 << 20

_PREFIX_IDS: dict[str, np.ndarray] = {}


def family_prefix_ids(cls_name: str) -> np.ndarray:
    """The family's shared system-prompt token ids (deterministic).

    Seeded from a CRC of the family name — stable across processes and
    runs (unlike ``hash``), so every session of a family, in every
    backend and every benchmark process, sees the byte-identical prefix.
    """
    ids = _PREFIX_IDS.get(cls_name)
    if ids is None:
        cls = CLOSED_LOOP_CLASSES[cls_name]
        seed = zlib.crc32(f"sys-prefix:{cls_name}".encode())
        ids = np.random.default_rng(seed).integers(
            0, CANON_VOCAB, size=int(cls.sys_prefix)
        )
        _PREFIX_IDS[cls_name] = ids
    return ids


@dataclasses.dataclass
class ClosedLoopSession:
    """Stateful ``next_stage`` generator for one closed-loop agent.

    ``first_stage`` seeds ``AgentSpec.stages``; every later turn is drawn
    from ``_rng`` when the serving layer asks for it.  ``expected_cost``
    is the a-priori cost estimate (expected turns x expected per-turn
    demand through the cost model) — the honest analogue of the paper's
    predictor output, since a closed-loop agent's true cost is unknowable
    at arrival.
    """

    cls: ClosedLoopClass
    first_stage: list[InferenceSpec]
    expected_cost: float
    max_turns: int
    _rng: np.random.Generator
    _turn: int = 1
    _history: float = 0.0                # accumulated output tokens
    #: separate RNG for the session's canonical prompt token stream —
    #: decoupled from ``_rng`` so demand sampling is unaffected by how
    #: many prompt ids a turn consumes.  ``None``: no pinned prompts
    #: (manually built sessions), backends synthesize instead.
    _token_rng: Optional[np.random.Generator] = None
    _stream: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64)
    )
    _seen_prompt: int = 0                # longest prompt issued so far
    #: canonical prompt ids / expected cached-prefix lengths of the most
    #: recently sampled stage (what the serving layer forwards through
    #: ``Backend.submit_stage``)
    last_prompt_ids: Optional[list] = None
    last_cached_hints: Optional[list] = None
    #: think time preceding the most recently sampled stage (seconds) —
    #: the serving layer forwards it as ``submit_stage(resume_delay=...)``
    #: so the backend suspends the agent for that long first.  ``None``
    #: for think-free families (kept ``None`` without touching the RNG,
    #: preserving their pre-suspension demand streams bit-for-bit)
    last_resume_delay: Optional[float] = None

    def _prompt_for(self, p: int) -> np.ndarray:
        """Canonical ids for a ``p``-token prompt: the family's shared
        system prefix followed by this session's private stream.  Every
        prompt of a session is a prefix of every longer one — each turn
        literally re-sends the conversation so far, which is the reuse
        a prefix cache exploits."""
        base = family_prefix_ids(self.cls.name)
        if p <= len(base):
            return base[:p]
        need = p - len(base)
        while len(self._stream) < need:
            grow = max(1024, need - len(self._stream))
            self._stream = np.concatenate(
                [self._stream, self._token_rng.integers(0, CANON_VOCAB,
                                                        size=grow)]
            )
        return np.concatenate([base, self._stream[:need]])

    def _sample_stage(self) -> list[InferenceSpec]:
        c = self.cls
        n = int(self._rng.integers(c.fanout[0], c.fanout[1] + 1))
        specs = []
        prompt_ids: list[np.ndarray] = []
        hints: list[float] = []
        for _ in range(n):
            p = c.sys_prefix + c.carry * self._history / max(1, n)
            p += float(np.clip(skew_normal(self._rng, *c.prefill), 16, 65536))
            p = min(p, 4096.0)           # context-window clamp
            d = float(np.clip(skew_normal(self._rng, *c.decode), 4, 8192))
            specs.append(InferenceSpec(prefill=int(p), decode=max(1, int(d))))
            # the hint is what THIS session knows it already sent (turn 1
            # hints 0 even though the family prefix may be warm — the
            # sim's group seeding / the engine's allocator add that part)
            hints.append(float(min(int(p), self._seen_prompt)))
            if self._token_rng is not None:
                prompt_ids.append(self._prompt_for(int(p)))
            self._seen_prompt = max(self._seen_prompt, int(p))
        self._history += float(sum(s.decode for s in specs))
        self.last_prompt_ids = prompt_ids if self._token_rng is not None \
            else None
        self.last_cached_hints = hints
        return specs

    def __call__(self, outcome) -> Optional[list[InferenceSpec]]:
        if self._turn >= self.max_turns:
            return None
        if self.cls.stop_prob and self._rng.random() < self.cls.stop_prob:
            return None
        self._turn += 1
        lo, hi = self.cls.think
        if hi > 0.0:
            self.last_resume_delay = float(lo + (hi - lo) * self._rng.random())
        else:
            self.last_resume_delay = None
        return self._sample_stage()


def sample_closed_loop(
    rng: np.random.Generator, cls_name: str
) -> ClosedLoopSession:
    """Sample one closed-loop session (first turn eager, rest lazy)."""
    cls = CLOSED_LOOP_CLASSES[cls_name]
    child = np.random.default_rng(int(rng.integers(0, 2**63)))
    # the prompt-stream RNG is seeded by one dedicated draw so demand
    # sampling and token-id generation cannot perturb each other
    token_rng = np.random.default_rng(int(child.integers(0, 2**63)))
    max_turns = int(child.integers(cls.turns[0], cls.turns[1] + 1))
    session = ClosedLoopSession(
        cls=cls,
        first_stage=[],
        expected_cost=0.0,
        max_turns=max_turns,
        _rng=child,
        _token_rng=token_rng,
    )
    session.first_stage = session._sample_stage()

    # expected cost from the family's location parameters: E[turns] more
    # stages shaped like the mean turn, history growing by the mean decode
    exp_turns = 0.5 * (cls.turns[0] + cls.turns[1])
    if cls.stop_prob:
        exp_turns = min(exp_turns, 1.0 / max(cls.stop_prob, 1e-9))
    fan = 0.5 * (cls.fanout[0] + cls.fanout[1])
    est, hist = [], 0.0
    for _ in range(max(1, int(round(exp_turns)))):
        p = min(
            4096.0,
            cls.sys_prefix + cls.prefill[0] + cls.carry * hist / max(1.0, fan),
        )
        est.extend(
            [InferenceSpec(int(p), int(cls.decode[0]))]
            * max(1, int(round(fan)))
        )
        hist += fan * cls.decode[0]
    session.expected_cost = agent_cost(est)
    return session
