"""Arrival-time synthesis following the Mooncake production trace shape.

The paper replays request arrival times from the Mooncake trace (Qin et al.,
2024) with submission windows of 6/9/18 minutes (3x/2x/1x density).  The
trace itself is not bundled offline; we synthesize arrivals with the same
statistical character reported for it — bursty arrivals, i.e. a doubly
stochastic (Cox) process: Poisson arrivals whose rate is modulated by a
Gamma-renewal burst process — and note the substitution in DESIGN.md §7.
"""

from __future__ import annotations

import numpy as np

DENSITY_WINDOWS_S = {1: 18 * 60.0, 2: 9 * 60.0, 3: 6 * 60.0}


def mooncake_like_arrivals(
    rng: np.random.Generator,
    n: int,
    window_s: float,
    burstiness: float = 2.5,
) -> np.ndarray:
    """n sorted arrival times in [0, window_s] with bursty clustering."""
    if n <= 0:
        return np.zeros(0)
    # burst centers from a Gamma renewal process
    n_bursts = max(1, int(n / 12))
    centers = np.sort(rng.uniform(0.0, window_s, size=n_bursts))
    weights = rng.gamma(shape=1.0 / burstiness, scale=burstiness, size=n_bursts)
    weights = weights / weights.sum()
    counts = rng.multinomial(n, weights)
    times = []
    for c, k in zip(centers, counts):
        if k == 0:
            continue
        spread = window_s / n_bursts / 2.0
        times.append(np.clip(rng.normal(c, spread, size=k), 0.0, window_s))
    t = np.sort(np.concatenate(times)) if times else np.zeros(0)
    # pad if multinomial rounding dropped any (it cannot, but be safe)
    if t.size < n:
        t = np.sort(np.concatenate([t, rng.uniform(0, window_s, n - t.size)]))
    return t


def arrivals_for_density(
    rng: np.random.Generator, n: int, density: int
) -> np.ndarray:
    return mooncake_like_arrivals(rng, n, DENSITY_WINDOWS_S[density])
