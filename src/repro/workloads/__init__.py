"""Agent workload suite: the paper's 9 agent classes + arrival synthesis."""

from repro.workloads.agents import (
    AGENT_CLASSES,
    CANON_VOCAB,
    CLOSED_LOOP_CLASSES,
    SIZE_BUCKETS,
    SIZE_PROBS,
    SLO_CLASSES,
    SLO_TIERS,
    AgentClass,
    ClosedLoopClass,
    ClosedLoopSession,
    SampledAgent,
    family_prefix_ids,
    sample_agent,
    sample_closed_loop,
    sample_mixed_suite,
    skew_normal,
    slo_tier_of,
)
from repro.workloads.arrivals import (
    DENSITY_WINDOWS_S,
    arrivals_for_density,
    mooncake_like_arrivals,
)

__all__ = [
    "AGENT_CLASSES",
    "CANON_VOCAB",
    "CLOSED_LOOP_CLASSES",
    "SIZE_BUCKETS",
    "SIZE_PROBS",
    "SLO_CLASSES",
    "SLO_TIERS",
    "slo_tier_of",
    "AgentClass",
    "ClosedLoopClass",
    "ClosedLoopSession",
    "SampledAgent",
    "family_prefix_ids",
    "sample_agent",
    "sample_closed_loop",
    "sample_mixed_suite",
    "skew_normal",
    "DENSITY_WINDOWS_S",
    "arrivals_for_density",
    "mooncake_like_arrivals",
]
