"""The ``Backend`` protocol and its two implementations.

A backend is anything that can accept :class:`AgentSpec` submissions with
arrival times, advance a clock, and drain to completion — the
:class:`repro.api.AgentService` facade drives simulator and engine through
this one surface, so a workload script switches backend with one flag.

Contract (all times in *workload seconds*):

  * ``submit(spec, agent_id)`` registers an agent arriving at
    ``max(spec.arrival, now)``; submissions may happen at any point, also
    interleaved with ``run`` — both backends support online arrivals.
  * ``run(until)`` advances the backend clock to ``until`` (the simulator
    is event-driven and advances lazily at drain; the engine really steps).
  * ``drain(max_time)`` runs everything submitted so far to completion and
    returns a :class:`BackendResult`.
  * ``set_listener(listener)`` installs the duck-typed lifecycle callback
    receiver (``on_arrival``/``on_admit``/``on_swap_out``/``on_swap_in``/
    ``on_token``/``on_stage_complete``/``on_agent_complete``) in backend-
    native time; ``to_workload_time`` converts those stamps back to seconds.

To add a backend: implement this protocol over your runtime, map workload
seconds onto its native clock, and forward its scheduler interactions to a
``repro.core.SchedulerPolicy`` — see ROADMAP.md "Serving API".
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core import make_scheduler
from repro.core.cost import InferenceSpec, MemoryFamily, agent_cost
from repro.core.schedulers import AgentScheduler
from repro.engine import EngineAgent, ServeEngine
from repro.sim import ClusterSim, SimAgent


@dataclasses.dataclass
class AgentSpec:
    """Backend-agnostic description of one task-parallel agent.

    ``stages`` uses the cost model's :class:`InferenceSpec` (full-scale
    token counts, as the paper's workload suite samples them); backends map
    them onto their own granularity (the engine divides by its
    ``token_scale``).  ``prompts`` optionally pins exact engine prompt
    token arrays per stage, used verbatim (already engine-scale); decode
    budgets still come from ``stages`` and are scaled.  When ``prompts``
    is absent the engine synthesizes prompts of the scaled lengths.

    ``next_stage`` makes the agent CLOSED-LOOP: after every stage
    completes, :class:`repro.api.AgentService` feeds the callback a
    :class:`repro.api.events.StageOutcome` (prior stage's events: index,
    completion time, tokens observed) and, if it returns a non-empty
    ``InferenceSpec`` list, submits that list as the agent's next stage
    mid-run through ``Backend.submit_stage`` — the agent only completes
    once the callback declines.  ``stages`` then holds just the opening
    turn(s); ``predicted_cost``/``true_cost`` should be supplied
    explicitly (``resolved_costs`` can only see the static prefix).  The
    callback runs inside the backend's event loop and must not call
    ``run``/``drain`` (see ROADMAP "closed-loop clients").

    Prefix-cache metadata (all optional — see ROADMAP "Prefix cache"):

      * ``prompt_ids`` pins CANONICAL full-scale prompt token ids per
        stage/inference.  Unlike ``prompts`` (engine-scale, verbatim),
        these are workload-scale streams the engine down-converts with
        ``ids[:ceil_scaled_len] % vocab`` — a conversion that preserves
        prefix-extension, so two prompts sharing a canonical prefix
        share an engine-token prefix too.  ``prompts`` wins when both
        are set.
      * ``prefix_group`` names the shared-system-prompt family (e.g. the
        closed-loop class) and ``shared_prefix`` the family's shared
        prefix length in full-scale tokens — the simulator's analytic
        cache model grants cross-agent hits of ``shared_prefix`` once
        any group member has been admitted.
      * ``cached_hints`` gives the a-priori expected cached-prefix
        length (full-scale tokens) per stage/inference.  Backends pass
        it to the scheduler as the STATIC ``Request.cached_prefix``
        hint (locality-aware policies sort on it) and the simulator's
        analytic model uses it for within-session hits.  It never
        touches the engine's real allocator, which matches by content.
    """

    stages: list[list[InferenceSpec]]
    arrival: float = 0.0
    predicted_cost: Optional[float] = None   # default: true memory-centric cost
    true_cost: Optional[float] = None
    family: MemoryFamily = MemoryFamily.DENSE
    name: str = "agent"
    prompts: Optional[list[list[np.ndarray]]] = None
    #: closed-loop stage generator: StageOutcome -> next stage's specs|None
    next_stage: Optional[Any] = None
    prompt_ids: Optional[list[list[np.ndarray]]] = None
    prefix_group: str = ""
    shared_prefix: float = 0.0
    cached_hints: Optional[list[list[float]]] = None

    def flat_specs(self) -> list[InferenceSpec]:
        return [s for stage in self.stages for s in stage]

    def resolved_costs(self) -> tuple[float, float]:
        """(predicted, true) cost with defaults filled from the cost model."""
        true = self.true_cost
        if true is None:
            true = agent_cost(self.flat_specs(), self.family)
        pred = self.predicted_cost
        if pred is None:
            pred = true
        return float(pred), float(true)


@dataclasses.dataclass
class BackendResult:
    """What a drained backend hands back, in workload seconds."""

    finish: dict[int, float]              # agent_id -> absolute completion
    jct: dict[int, float]                 # agent_id -> completion - arrival
    makespan: float
    swaps: int = 0
    sched_decisions: int = 0
    sched_time: float = 0.0               # wall-clock spent in scheduler code
    metrics: dict = dataclasses.field(default_factory=dict)


@runtime_checkable
class Backend(Protocol):
    name: str

    @property
    def now(self) -> float: ...

    @property
    def virtual_capacity(self) -> float:
        """GPS service capacity in workload cost-units per workload second.

        This is the rate at which the backend's virtual clock advances when
        one agent is active — what a ``ReplicatedBackend`` feeds to the
        :class:`repro.core.GlobalVirtualClock` so per-replica virtual times
        are comparable across heterogeneous children.
        """
        ...

    def set_listener(self, listener: Any) -> None: ...

    def to_workload_time(self, t: float) -> float: ...

    def submit(self, spec: AgentSpec, agent_id: int) -> float: ...

    def submit_stage(
        self,
        agent_id: int,
        specs: Sequence[InferenceSpec],
        *,
        prompt_ids: Optional[Sequence[np.ndarray]] = None,
        hints: Optional[Sequence[float]] = None,
        resume_delay: Optional[float] = None,
    ) -> None:
        """Append one follow-up stage to a live agent (closed-loop).

        Legal until the agent completes — including from inside an
        ``on_stage_complete`` listener callback, which every backend
        emits BEFORE deciding whether the agent is done, so an appended
        stage seamlessly continues the agent.

        ``prompt_ids``/``hints`` carry the stage's canonical prompt
        token streams and expected cached-prefix lengths (same
        semantics as the :class:`AgentSpec` fields); both optional.

        ``resume_delay`` (workload seconds, PR 9) suspends the agent
        for that long BEFORE this stage starts — tool-call / user think
        time: the agent holds no decode slot, its KV falls under the
        backend's ``suspend_retention`` policy, and the backend emits
        ``on_suspend``/``on_resume`` around the gap.  ``None``/``0``
        submits immediately (bit-identical to pre-PR-9 behaviour).
        """
        ...

    def cancel(self, agent_id: int) -> bool:
        """Withdraw a never-admitted agent (fleet work stealing, PR 10).

        Returns True and silently removes the agent — no events, no
        result entry — when its whole opening stage is still queued (or
        its arrival is still pending); returns False, leaving the
        backend untouched, for any agent that was ever admitted,
        suspended, or has completed.  The fleet uses this to migrate
        queued backlog off an overloaded replica.
        """
        ...

    def run(self, until: float) -> None: ...

    def drain(self) -> BackendResult: ...


def _resolve_scheduler(
    scheduler: "str | AgentScheduler", total_kv: float, service_rate: float
) -> AgentScheduler:
    if isinstance(scheduler, str):
        return make_scheduler(scheduler, total_kv, service_rate)
    return scheduler


class SimBackend:
    """Discrete-event cluster simulator behind the ``Backend`` protocol.

    The event-indexed simulator is incremental: ``submit`` registers the
    agent with the sim immediately (online arrival) and ``run(until)``
    really advances the event loop, so completions are *observed* mid-run —
    lifecycle listeners fire as the clock sweeps them, and load-aware fleet
    routers (``least_loaded``) see the sim's in-flight count drop without
    waiting for ``drain``.  Results are cumulative across submit/drain
    rounds, matching the engine backend's ``completions`` dict.

    ``token_events=True`` turns on the sim's discretized token streaming
    (``TokenGenerated`` at the closed-form boundary instants — see the
    ``repro.sim.cluster`` module doc); off by default because the emission
    sweep costs O(running) per event.
    """

    name = "sim"

    def __init__(
        self,
        scheduler: "str | AgentScheduler" = "justitia",
        *,
        total_kv: float = 16384.0,
        decode_rate: float = 30.0,
        prefill_rate: float = 4000.0,
        swap_penalty: float = 0.2,
        token_events: bool = False,
        prefix_cache: bool = False,
        admission_watermark: Optional[tuple] = None,
        suspend_retention: str = "hold",
        retain_results: bool = True,
    ):
        sched = _resolve_scheduler(scheduler, total_kv, decode_rate)
        self.sim = ClusterSim(
            sched,
            total_kv,
            decode_rate=decode_rate,
            prefill_rate=prefill_rate,
            swap_penalty=swap_penalty,
            token_events=token_events,
            prefix_cache=prefix_cache,
            admission_watermark=admission_watermark,
            suspend_retention=suspend_retention,
            retain_results=retain_results,
        )
        self.scheduler = sched

    @property
    def now(self) -> float:
        return self.sim.t

    @property
    def virtual_capacity(self) -> float:
        # pool size (KV tokens) x decode rate = KV token-time per second
        return self.sim.m * self.sim.decode_rate

    @property
    def in_flight(self) -> int:
        """Agents submitted but not completed (the sim's own live counter)."""
        return self.sim.live_agents

    def set_listener(self, listener: Any) -> None:
        self.sim.listener = listener

    def to_workload_time(self, t: float) -> float:
        return float(t)

    def submit(self, spec: AgentSpec, agent_id: int) -> float:
        pred, true = spec.resolved_costs()
        return self.sim.submit(
            SimAgent(
                agent_id=agent_id,
                arrival=float(spec.arrival),
                stages=[list(s) for s in spec.stages],
                predicted_cost=pred,
                true_cost=true,
                family=spec.family,
                name=spec.name,
                prefix_group=spec.prefix_group,
                shared_prefix=float(spec.shared_prefix),
                cached_hints=(
                    None
                    if spec.cached_hints is None
                    else [list(h) for h in spec.cached_hints]
                ),
            )
        )

    def submit_stage(
        self,
        agent_id: int,
        specs: Sequence[InferenceSpec],
        *,
        prompt_ids: Optional[Sequence[np.ndarray]] = None,
        hints: Optional[Sequence[float]] = None,
        resume_delay: Optional[float] = None,
    ) -> None:
        # the sim's analytic cache model needs only the hints; canonical
        # prompt ids are an engine-side concern
        self.sim.append_stage(
            agent_id,
            [list(specs)],
            hints=None if hints is None else [list(hints)],
            resume_delay=0.0 if resume_delay is None else float(resume_delay),
        )

    def cancel(self, agent_id: int) -> bool:
        return self.sim.cancel(agent_id)

    def run(self, until: float) -> None:
        # stale horizons (at-or-before the clock) are no-ops by the sim's
        # own contract: advance() only raises the clock floor
        self.sim.advance(until)

    def drain(self) -> BackendResult:
        res = self.sim.drain()
        return BackendResult(
            finish=dict(res.finish),
            jct=dict(res.jct),
            makespan=res.makespan,
            swaps=res.swaps,
            sched_decisions=res.sched_decisions,
            sched_time=res.sched_time,
            metrics={
                "swaps": res.swaps,
                "events": res.events,
                "key_evals": res.key_evals,
                "sorts": res.sorts,
                "peak_occupancy": res.peak_occupancy,
                "admission_deferrals": res.admission_deferrals,
                "wm_admit_peak": res.wm_admit_peak,
                "wm_bypass_admits": res.wm_bypass_admits,
                "prefill_tokens_saved": res.prefill_tokens_saved,
                "hit_fractions": self.sim.hit_fractions(),
                "suspensions": res.suspensions,
                "resumes": res.resumes,
                "suspend_spills": res.suspend_spills,
                "held_peak": res.held_peak,
            },
        )


class EngineBackend:
    """Real JAX continuous-batching engine behind the ``Backend`` protocol.

    ``token_scale`` divides the workload's token demands down to engine
    scale (predicted KV token-time costs scale by ``token_scale**2`` since
    cost is quadratic-ish in token counts); ``time_scale`` maps workload
    seconds onto engine iterations for arrival scheduling and converts
    event/finish stamps back.
    """

    name = "engine"

    def __init__(
        self,
        model,
        params,
        scheduler: "str | AgentScheduler" = "justitia",
        *,
        pool_tokens: int = 4096,
        block_size: int = 16,
        max_batch: int = 8,
        cache_len: int = 512,
        prefill_chunk: int = 512,
        max_window: int = 32,
        token_scale: int = 1,
        time_scale: float = 1.0,
        seed: int = 0,
        max_iters: int = 200_000,
        prefix_cache: bool = False,
        fused_prefill: bool = False,
        admission_watermark: Optional[tuple] = None,
        suspend_retention: str = "hold",
    ):
        sched = _resolve_scheduler(scheduler, float(pool_tokens), 1.0)
        self.engine = ServeEngine(
            model,
            params,
            sched,
            pool_tokens=pool_tokens,
            block_size=block_size,
            max_batch=max_batch,
            cache_len=cache_len,
            prefill_chunk=prefill_chunk,
            max_window=max_window,
            prefix_cache=prefix_cache,
            fused_prefill=fused_prefill,
            admission_watermark=admission_watermark,
            suspend_retention=suspend_retention,
        )
        self.scheduler = sched
        self.token_scale = int(token_scale)
        self.time_scale = float(time_scale)
        self.max_iters = int(max_iters)
        self.pool_tokens = int(pool_tokens)
        self._vocab = int(model.cfg.vocab)
        self._rng = np.random.default_rng(seed)

    @property
    def now(self) -> float:
        return self.engine.now / self.time_scale

    @property
    def virtual_capacity(self) -> float:
        # engine pool tokens serve workload costs divided by token_scale**2
        # at time_scale iterations per workload second
        return self.pool_tokens * self.token_scale**2 * self.time_scale

    @property
    def in_flight(self) -> int:
        """Agents submitted but not completed (mirrors SimBackend's) —
        load-aware routers and the fleet watchdog's diagnostics read it."""
        eng = self.engine
        return (len(eng.agents) + len(eng.pending)) - len(eng.completions)

    def set_listener(self, listener: Any) -> None:
        self.engine.listener = listener

    def to_workload_time(self, t: float) -> float:
        return float(t) / self.time_scale

    def _scale_spec(
        self, s: InferenceSpec, prompt=None
    ) -> tuple[np.ndarray, int]:
        """One full-scale spec -> (engine prompt, scaled decode budget).

        Decode budgets always come from the (full-scale) spec and are
        scaled down; a pinned ``prompt`` is used verbatim (engine tokens
        already), otherwise one is synthesized at the scaled length.  The
        ONE scaling rule for opening stages and closed-loop follow-ups
        alike — the cross-backend token-count conformance pin depends on
        both paths rounding identically.
        """
        d = max(1, int(round(s.decode / self.token_scale)))
        if prompt is None:
            p = max(1, int(round(s.prefill / self.token_scale)))
            prompt = self._rng.integers(0, self._vocab, size=p)
        else:
            prompt = np.asarray(prompt)
        return prompt, d

    def _canon_prompt(self, s: InferenceSpec, ids) -> np.ndarray:
        """Canonical full-scale token ids -> engine prompt.

        Engine token ``k`` is canonical token ``k * token_scale``
        (stride subsampling), folded into the engine vocab.  The stride
        — not a head slice of the scaled length — is what keeps scaled
        prompts faithful: two canonical streams sharing an L-token
        prefix map to engine prompts sharing a ``~L / token_scale``
        prefix (matching ``_scale_hints``), and a prompt that is 60%
        shared content at full scale stays 60% shared at engine scale.
        A head slice would instead keep only the stream's head — at
        scale 8 every chat prompt up to 2048 canonical tokens would
        collapse into the family's 256-id system prefix, making all
        sessions' engine prompts identical.  The stream must be at
        least ``prefill`` ids long (the sessions guarantee it).
        """
        p = max(1, int(round(s.prefill / self.token_scale)))
        return np.asarray(ids)[:: self.token_scale][:p] % self._vocab

    def _stage_prompt(
        self, spec: AgentSpec, i: int, j: int, s: InferenceSpec
    ) -> Optional[np.ndarray]:
        if spec.prompts is not None:
            return spec.prompts[i][j]
        if spec.prompt_ids is not None:
            return self._canon_prompt(s, spec.prompt_ids[i][j])
        return None

    def _engine_stages(
        self, spec: AgentSpec
    ) -> list[list[tuple[np.ndarray, int]]]:
        return [
            [
                self._scale_spec(s, self._stage_prompt(spec, i, j, s))
                for j, s in enumerate(stage)
            ]
            for i, stage in enumerate(spec.stages)
        ]

    def _scale_hints(self, hints) -> Optional[list]:
        """Full-scale cached-prefix hints -> engine-token hints."""
        if hints is None:
            return None
        return [
            None if h is None else float(h) / self.token_scale
            for h in hints
        ]

    def submit(self, spec: AgentSpec, agent_id: int) -> float:
        pred, _ = spec.resolved_costs()
        arrival_iter = max(
            self.engine.now, int(round(spec.arrival * self.time_scale))
        )
        self.engine.submit_agent(
            EngineAgent(
                agent_id=agent_id,
                arrival_iter=arrival_iter,
                stages=self._engine_stages(spec),
                predicted_cost=pred / (self.token_scale * self.token_scale),
                closed_loop=spec.next_stage is not None,
                hints=(
                    None
                    if spec.cached_hints is None
                    else [self._scale_hints(h) for h in spec.cached_hints]
                ),
            )
        )
        return arrival_iter / self.time_scale

    def submit_stage(
        self,
        agent_id: int,
        specs: Sequence[InferenceSpec],
        *,
        prompt_ids: Optional[Sequence[np.ndarray]] = None,
        hints: Optional[Sequence[float]] = None,
        resume_delay: Optional[float] = None,
    ) -> None:
        """Append a follow-up stage to a live agent (closed-loop pacing).

        Token demands are scaled exactly like ``submit``'s; prompts come
        from ``prompt_ids`` (canonical full-scale streams, converted as
        in ``AgentSpec.prompt_ids``) or are synthesized from the
        backend's RNG.  Legal from inside an ``on_stage_complete``
        callback: the engine emits it before the stage-exhaustion check,
        and its fused decode windows already end at every closed-loop
        agent's stage boundary, so the appended stage is admitted at the
        next iteration — the same cadence the per-step reference engine
        would give it.
        """
        self.engine.append_stage(
            agent_id,
            [
                self._scale_spec(
                    s,
                    None
                    if prompt_ids is None
                    else self._canon_prompt(s, prompt_ids[j]),
                )
                for j, s in enumerate(specs)
            ],
            hints=self._scale_hints(hints),
            # a positive workload-seconds delay maps to >= 1 iteration so
            # a think time shorter than one engine tick still suspends
            resume_delay=(
                None
                if resume_delay is None or resume_delay <= 0.0
                else max(1, int(round(resume_delay * self.time_scale)))
            ),
        )

    def cancel(self, agent_id: int) -> bool:
        return self.engine.cancel(agent_id)

    def run(self, until: float) -> None:
        # ceil (with an fp guard): run must advance AT LEAST to `until`, or
        # a fleet's post-drain re-anchor could leave this engine's clock
        # trailing the reconciled horizon by a fraction of an iteration.
        # But a horizon at-or-before the current clock must be a NO-OP:
        # ceil lands one iteration PAST the clock when `until * time_scale`
        # floats a hair above the integer `now` (stale-target regression)
        if until <= self.now:
            return
        self.engine.run(math.ceil(until * self.time_scale - 1e-9))

    def drain(self) -> BackendResult:
        completions = self.engine.run_until_idle(max_iters=self.max_iters)
        self.engine.alloc.check_invariants()
        metrics = dict(self.engine.metrics)
        metrics["hit_fractions"] = self.engine.hit_fractions()
        finish = {
            aid: it / self.time_scale for aid, it in completions.items()
        }
        jct = {
            aid: (completions[aid] - self.engine.agents[aid].arrival_iter)
            / self.time_scale
            for aid in completions
        }
        return BackendResult(
            finish=finish,
            jct=jct,
            makespan=self.now,
            swaps=self.engine.metrics["swaps"],
            metrics=metrics,
        )
