"""Multi-replica serving: shard agents across N child backends.

:class:`ReplicatedBackend` implements the :class:`repro.api.Backend`
protocol over a fleet of children (any mix of ``SimBackend`` /
``EngineBackend`` — the children only need the protocol).  Incoming
``AgentSpec`` submissions are placed by a pluggable *router*, all children
advance in lockstep through ``run(until)``, and the per-replica GPS clocks
are reconciled into one global virtual time by a
:class:`repro.core.GlobalVirtualClock` — so Justitia's selective-pampering
order and the worst-case delay bound can be stated fleet-wide, not just per
replica (naive per-replica fair queuing loses global fairness exactly when
the replica clocks drift; the reconciled lag measures that drift).

Routers register with ``@register_router(name)`` the same way schedulers
register with ``@register_scheduler``:

  * ``round_robin`` — placement by submission order, oblivious to load;
  * ``least_loaded`` — fewest live (uncompleted) agents;
  * ``memory_cost_aware`` — smallest outstanding predicted KV token-time
    after adding this agent, normalized by replica capacity (greedy
    balancing on the predictor's memory-centric cost estimate).

Routers are deterministic given the submission sequence (ties break toward
the lowest replica index), which is what makes the engine-vs-sim replicated
equivalence testable: same routing seed => same per-replica assignment.

Listener callbacks from child k are forwarded in *workload seconds* with a
``replica=k`` keyword, so the service's dispatcher (and the typed events in
``repro.api.events``) know which replica served each lifecycle step.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import numpy as np

from repro.api.backend import AgentSpec, Backend, BackendResult
from repro.core.virtual_time import GlobalClockSnapshot, GlobalVirtualClock

# ---------------------------------------------------------------- routers

_ROUTERS: dict[str, type] = {}
_ROUTER_ALIASES: dict[str, str] = {}


def register_router(name: str, *aliases: str):
    """Class decorator: register a :class:`Router` under ``name``.

    Name and aliases must not collide with any existing canonical name or
    alias (same shadowing protection as ``@register_scheduler``).
    """

    def deco(cls):
        for n in (name, *aliases):
            if n in _ROUTERS or n in _ROUTER_ALIASES:
                raise ValueError(f"router name {n!r} already registered")
        cls.name = name
        _ROUTERS[name] = cls
        for alias in aliases:
            _ROUTER_ALIASES[alias] = name
        return cls

    return deco


def router_names() -> list[str]:
    """Canonical router names (aliases excluded), registration order."""
    return list(_ROUTERS)


def resolve_router(name: str) -> type:
    canonical = _ROUTER_ALIASES.get(name, name)
    try:
        return _ROUTERS[canonical]
    except KeyError:
        raise ValueError(
            f"unknown router {name!r} (have: {', '.join(_ROUTERS)})"
        ) from None


class Router:
    """Placement policy: pick a replica for each submitted agent.

    Subclasses read fleet state off the bound backend (live agent counts,
    outstanding predicted cost, per-replica capacities) and must be
    deterministic given the submission sequence and ``seed``.
    """

    name = "base"

    def __init__(self, n_replicas: int, seed: int = 0):
        self.n = int(n_replicas)
        self.rng = np.random.default_rng(seed)
        self._backend: Optional["ReplicatedBackend"] = None

    def bind(self, backend: "ReplicatedBackend") -> None:
        self._backend = backend

    def pick(self, spec: AgentSpec, agent_id: int, pred_cost: float) -> int:
        raise NotImplementedError


@register_router("round_robin", "rr")
class RoundRobinRouter(Router):
    def __init__(self, n_replicas: int, seed: int = 0):
        super().__init__(n_replicas, seed)
        self._next = 0

    def pick(self, spec: AgentSpec, agent_id: int, pred_cost: float) -> int:
        r = self._next % self.n
        self._next += 1
        return r


@register_router("least_loaded", "ll")
class LeastLoadedRouter(Router):
    def pick(self, spec: AgentSpec, agent_id: int, pred_cost: float) -> int:
        loads = self._backend.live_agents
        return min(range(self.n), key=lambda k: (loads[k], k))


@register_router("memory_cost_aware", "cost_aware", "mca")
class MemoryCostAwareRouter(Router):
    """Greedy balancing of outstanding predicted KV token-time.

    Routes to the replica whose post-placement load-to-capacity ratio is
    smallest — the predictor's memory-centric cost estimate stands in for
    the agent's true KV footprint, exactly as it does for Justitia's
    virtual finish times.
    """

    def pick(self, spec: AgentSpec, agent_id: int, pred_cost: float) -> int:
        costs = self._backend.live_cost
        caps = self._backend.virtual_capacities
        return min(
            range(self.n),
            key=lambda k: ((costs[k] + pred_cost) / caps[k], k),
        )


# ------------------------------------------------------ replica channel


class _ReplicaChannel:
    """Child k's listener: tags callbacks with ``replica=k``, converts the
    child's native timestamps to workload seconds, and keeps the fleet's
    load accounting current (completions decrement the router's view)."""

    def __init__(self, fleet: "ReplicatedBackend", replica: int):
        self.fleet = fleet
        self.replica = replica

    def _forward(self, event: str, agent_id: int, t: float, *args) -> None:
        listener = self.fleet._listener
        if listener is None:
            return
        fn = getattr(listener, event, None)
        if fn is None:
            return
        tw = self.fleet.children[self.replica].to_workload_time(t)
        fn(agent_id, *args, tw, replica=self.replica)

    def on_arrival(self, agent_id: int, t: float) -> None:
        self._forward("on_arrival", agent_id, t)

    def on_admit(self, agent_id: int, rid: int, t: float) -> None:
        self._forward("on_admit", agent_id, t, rid)

    def on_swap_out(self, agent_id: int, rid: int, t: float) -> None:
        self._forward("on_swap_out", agent_id, t, rid)

    def on_swap_in(self, agent_id: int, rid: int, t: float) -> None:
        self._forward("on_swap_in", agent_id, t, rid)

    def on_token(self, agent_id: int, rid: int, token: int, t: float) -> None:
        self._forward("on_token", agent_id, t, rid, token)

    def on_prefix_hit(
        self, agent_id: int, rid: int, cached: int, prefill: int, t: float
    ) -> None:
        self._forward("on_prefix_hit", agent_id, t, rid, cached, prefill)

    def on_stage_complete(self, agent_id: int, stage: int, t: float) -> None:
        self._forward("on_stage_complete", agent_id, t, stage)

    def on_agent_complete(self, agent_id: int, t: float) -> None:
        self.fleet._on_child_complete(self.replica, agent_id)
        self._forward("on_agent_complete", agent_id, t)


# ---------------------------------------------------- replicated backend


class ReplicatedBackend:
    """N child backends behind the single-backend protocol (see module doc).

    ``submit`` places each agent on one child via the router; ``run``
    advances every child to the same workload time; ``drain`` drains them
    all, merges their results, and reconciles the per-replica virtual
    clocks (the snapshot lands in ``BackendResult.metrics`` as
    ``global_virtual_time`` / ``virtual_lag`` / ``virtual_times``).
    """

    name = "replicated"

    def __init__(
        self,
        children: Sequence[Backend],
        *,
        router: "str | Router" = "round_robin",
        seed: int = 0,
    ):
        self.children: list[Backend] = list(children)
        if not self.children:
            raise ValueError("need at least one child backend")
        if isinstance(router, str):
            router = resolve_router(router)(len(self.children), seed)
        elif router.n != len(self.children):
            raise ValueError(
                f"router sized for {router.n} replicas, have "
                f"{len(self.children)}"
            )
        self.router = router
        self.router.bind(self)
        self.virtual_capacities = [c.virtual_capacity for c in self.children]
        self.global_clock = GlobalVirtualClock(self.virtual_capacities)
        self.assignment: dict[int, int] = {}     # agent_id -> replica
        self.live_agents = [0] * len(self.children)
        self.live_cost = [0.0] * len(self.children)
        self._pred_cost: dict[int, float] = {}
        self._listener: Any = None
        self._last_snapshot: Optional[GlobalClockSnapshot] = None
        for idx, child in enumerate(self.children):
            child.set_listener(_ReplicaChannel(self, idx))

    # --------------------------------------------------------- protocol

    @property
    def now(self) -> float:
        return max(c.now for c in self.children)

    @property
    def virtual_capacity(self) -> float:
        return float(sum(self.virtual_capacities))

    @property
    def n_replicas(self) -> int:
        return len(self.children)

    def set_listener(self, listener: Any) -> None:
        """Install the fleet listener.

        Callbacks arrive in workload seconds with a ``replica=k`` keyword
        identifying the serving child (the channels convert each child's
        native clock before forwarding), so ``to_workload_time`` is the
        identity here.
        """
        self._listener = listener

    def to_workload_time(self, t: float) -> float:
        return float(t)

    def submit(self, spec: AgentSpec, agent_id: int) -> float:
        pred, _ = spec.resolved_costs()
        replica = self.router.pick(spec, agent_id, pred)
        if not 0 <= replica < len(self.children):
            raise ValueError(
                f"router {self.router.name!r} picked replica {replica} "
                f"of {len(self.children)}"
            )
        arrival = self.children[replica].submit(spec, agent_id)
        self.assignment[agent_id] = replica
        self.live_agents[replica] += 1
        self.live_cost[replica] += pred
        self._pred_cost[agent_id] = pred
        self.global_clock.register(replica, agent_id, arrival, pred)
        return arrival

    def submit_stage(self, agent_id: int, specs, **kw) -> None:
        """Route a closed-loop follow-up stage to the agent's replica.

        ``**kw`` forwards the optional prefix-cache metadata
        (``prompt_ids``/``hints``) untouched — each child scales it to
        its own granularity.
        """
        try:
            replica = self.assignment[agent_id]
        except KeyError:
            raise ValueError(
                f"agent {agent_id} was never placed on this fleet"
            ) from None
        self.children[replica].submit_stage(agent_id, specs, **kw)

    def run(self, until: float) -> None:
        """Advance the whole fleet in lockstep to ``until`` (seconds)."""
        for child in self.children:
            child.run(until)

    def drain(self) -> BackendResult:
        finish: dict[int, float] = {}
        jct: dict[int, float] = {}
        per_replica: list[dict] = []
        swaps = decisions = 0
        sched_time = 0.0
        makespan = 0.0
        # fleet-level prefix-cache metrics, aggregated exactly like jct:
        # hit_fractions dict-merge (agent ids are fleet-unique — the
        # service assigns them before routing), prefill_tokens_saved
        # summed (children report backend-native token scales)
        hit_fractions: dict[int, float] = {}
        prefill_tokens_saved = 0
        for idx, child in enumerate(self.children):
            res = child.drain()
            finish.update(res.finish)
            jct.update(res.jct)
            swaps += res.swaps
            decisions += res.sched_decisions
            sched_time += res.sched_time
            makespan = max(makespan, res.makespan)
            hit_fractions.update(res.metrics.get("hit_fractions") or {})
            prefill_tokens_saved += res.metrics.get(
                "prefill_tokens_saved", 0
            ) or 0
            per_replica.append(
                {
                    "backend": child.name,
                    "agents": len(res.finish),
                    "makespan": res.makespan,
                    "swaps": res.swaps,
                    **{f"child_{k}": v for k, v in res.metrics.items()},
                }
            )
        # resume lockstep: drained children sit at their own makespans, so
        # re-anchor every child at the fleet makespan — later submissions
        # then clamp to a common clock and can never predate the reconciled
        # horizon (submit/drain rounds may interleave freely, per Backend)
        makespan = max(makespan, self.now)
        for child in self.children:
            child.run(makespan)
        snap = self.global_clock.reconcile(makespan)
        self._last_snapshot = snap
        return BackendResult(
            finish=finish,
            jct=jct,
            makespan=makespan,
            swaps=swaps,
            sched_decisions=decisions,
            sched_time=sched_time,
            metrics={
                "replicas": len(self.children),
                "router": self.router.name,
                "per_replica": per_replica,
                "global_virtual_time": snap.global_virtual_time,
                "virtual_lag": snap.lag,
                "virtual_times": list(snap.virtual_times),
                "hit_fractions": hit_fractions,
                "prefill_tokens_saved": prefill_tokens_saved,
            },
        )

    # ------------------------------------------------------- fleet state

    def _on_child_complete(self, replica: int, agent_id: int) -> None:
        self.live_agents[replica] -= 1
        self.live_cost[replica] -= self._pred_cost.pop(agent_id, 0.0)

    def pampering_order(self) -> list[int]:
        """Fleet-wide selective-pampering order (reconciled F_j ascending).

        Only agents whose arrivals have been reconciled (i.e. swept by
        ``drain`` or an explicit ``global_clock.reconcile``) appear.
        """
        return self.global_clock.pampering_order()
