"""Multi-replica serving: shard agents across N child backends.

:class:`ReplicatedBackend` implements the :class:`repro.api.Backend`
protocol over a fleet of children (any mix of ``SimBackend`` /
``EngineBackend`` — the children only need the protocol).  Incoming
``AgentSpec`` submissions are placed by a pluggable *router*, all children
advance in lockstep through ``run(until)``, and the per-replica GPS clocks
are reconciled into one global virtual time by a
:class:`repro.core.GlobalVirtualClock` — so Justitia's selective-pampering
order and the worst-case delay bound can be stated fleet-wide, not just per
replica (naive per-replica fair queuing loses global fairness exactly when
the replica clocks drift; the reconciled lag measures that drift).

Routers register with ``@register_router(name)`` the same way schedulers
register with ``@register_scheduler``:

  * ``round_robin`` — placement by submission order, oblivious to load;
  * ``least_loaded`` — fewest live (uncompleted) agents;
  * ``memory_cost_aware`` — smallest outstanding predicted KV token-time
    after adding this agent, normalized by replica capacity (greedy
    balancing on the predictor's memory-centric cost estimate).

Routers are deterministic given the submission sequence (ties break toward
the lowest replica index), which is what makes the engine-vs-sim replicated
equivalence testable: same routing seed => same per-replica assignment.
All routers place over the fleet's LIVE replicas only — after a failover
the registry policies see post-failure occupancy, and ``rebalance`` routes
a dead replica's backlog through the same placement path as fresh
submissions.

Fault tolerance (PR 8).  A :class:`repro.api.faults.FaultPlan` injects
deterministic crash / stall / slowdown windows at ``advance()`` boundaries:
``run(until)`` slices the fleet's advancement at the plan's window edges
(plus the watchdog's probe deadlines) and clamps each child's horizon per
:meth:`FaultPlan.horizon` — child state is never mutated, so the same plan
reproduces the same run bit for bit.  A progress watchdog
(``watchdog_timeout`` seconds, ``watchdog_retries`` backoff-growing
retries) marks a child SUSPECT when it lags a probe by one timeout,
RECOVERED (``ReplicaRecovered``) when it catches back up, and DEAD once it
makes no progress for the whole budget ``timeout * sum(backoff**i)`` —
at which point its uncompleted agents fail over: each is re-submitted to a
surviving replica (remaining stages only — completed stages are never
redone, in-progress stages restart), the global virtual clock carries the
agent's accrued virtual finish time across the migration, and the fleet
emits ``ReplicaFailed`` + per-agent ``AgentRequeued`` events.  With the
watchdog disabled, a crashed child with in-flight work raises
:class:`FleetStalledError` instead of leaving the fleet spinning.

Concurrent advancement + work stealing (PR 10).  ``fleet_workers > 1``
fans each ``_drive`` slice out on a bounded thread pool: engine children
release the GIL inside device compute, sim children are independent
pure-Python cores, and the only serialized sections are the slice barrier
(horizon clamping, watchdog probes, ``GlobalVirtualClock`` bookkeeping)
and the child-major buffer replay that re-emits every child's events in
child-index order — reproducing the sequential loop's global event order
bit for bit (see :class:`_ReplicaChannel`).  ``steal_threshold`` arms
load-triggered work stealing: at every ``steal_interval`` multiple, a
replica whose queued-and-never-admitted backlog (predicted cost normalized
by ``virtual_capacity``) exceeds the threshold times the live-fleet mean
migrates its newest queued agents to underloaded live replicas through the
failover requeue machinery, with accrued virtual time carried by
``GlobalVirtualClock.steal``.  ``retain_agents=False`` (with the
children's ``retain_results=False``) switches the fleet to streaming
emission: per-agent bookkeeping is dropped at completion and ``compact()``
trims the reconciled clock, bounding memory by the live-agent population
instead of the total workload.

Listener callbacks from child k are forwarded in *workload seconds* with a
``replica=k`` keyword, so the service's dispatcher (and the typed events in
``repro.api.events``) know which replica served each lifecycle step.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import weakref
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional, Sequence

import numpy as np

from repro.api.backend import AgentSpec, Backend, BackendResult
from repro.api.faults import FaultPlan
from repro.core.virtual_time import GlobalClockSnapshot, GlobalVirtualClock

_EPS = 1e-9

# ---------------------------------------------------------------- routers

_ROUTERS: dict[str, type] = {}
_ROUTER_ALIASES: dict[str, str] = {}


def register_router(name: str, *aliases: str):
    """Class decorator: register a :class:`Router` under ``name``.

    Name and aliases must not collide with any existing canonical name or
    alias (same shadowing protection as ``@register_scheduler``).
    """

    def deco(cls):
        for n in (name, *aliases):
            if n in _ROUTERS or n in _ROUTER_ALIASES:
                raise ValueError(f"router name {n!r} already registered")
        cls.name = name
        _ROUTERS[name] = cls
        for alias in aliases:
            _ROUTER_ALIASES[alias] = name
        return cls

    return deco


def router_names() -> list[str]:
    """Canonical router names (aliases excluded), registration order."""
    return list(_ROUTERS)


def resolve_router(name: str) -> type:
    canonical = _ROUTER_ALIASES.get(name, name)
    try:
        return _ROUTERS[canonical]
    except KeyError:
        raise ValueError(
            f"unknown router {name!r} (have: {', '.join(_ROUTERS)})"
        ) from None


class Router:
    """Placement policy: pick a replica for each submitted agent.

    Subclasses read fleet state off the bound backend (live agent counts,
    outstanding predicted cost, per-replica capacities) and must be
    deterministic given the submission sequence and ``seed``.  Placement
    is restricted to the fleet's live replicas (``candidates``); before a
    failure that is every index, so the restriction is invisible to
    healthy fleets.
    """

    name = "base"

    def __init__(self, n_replicas: int, seed: int = 0):
        self.n = int(n_replicas)
        self.rng = np.random.default_rng(seed)
        self._backend: Optional["ReplicatedBackend"] = None

    def bind(self, backend: "ReplicatedBackend") -> None:
        self._backend = backend

    def candidates(self) -> tuple[int, ...]:
        """Live replica indices (all of them when unbound)."""
        if self._backend is None:
            return tuple(range(self.n))
        return self._backend.live_replica_indices

    def pick(self, spec: AgentSpec, agent_id: int, pred_cost: float) -> int:
        raise NotImplementedError

    def rebalance(
        self, queued: Sequence[tuple[AgentSpec, int, float]]
    ) -> list[int]:
        """Place a dead replica's backlog onto survivors.

        Default: route each displaced agent through :meth:`pick`, in the
        order given (the fleet passes original-arrival order), so failover
        and fresh submission share one placement path and load-aware
        policies see the occupancy shift as each victim lands.  Override
        for policies that want to plan the whole batch at once.
        """
        return [
            self.pick(spec, agent_id, cost)
            for spec, agent_id, cost in queued
        ]


@register_router("round_robin", "rr")
class RoundRobinRouter(Router):
    def __init__(self, n_replicas: int, seed: int = 0):
        super().__init__(n_replicas, seed)
        self._next = 0

    def pick(self, spec: AgentSpec, agent_id: int, pred_cost: float) -> int:
        live = self.candidates()
        r = live[self._next % len(live)]
        self._next += 1
        return r


@register_router("least_loaded", "ll")
class LeastLoadedRouter(Router):
    """Fewest live agents *per unit of capacity*.

    A raw live-agent count systematically overloads the small replicas of
    a heterogeneous fleet (a child with half the decode rate drains its
    queue at half the speed, so equal counts are not equal load): the
    count is normalized by each replica's ``virtual_capacity``, with the
    deterministic lowest-index tie-break.  On a homogeneous fleet the
    normalization divides every candidate by the same constant, so
    placements are unchanged.
    """

    def pick(self, spec: AgentSpec, agent_id: int, pred_cost: float) -> int:
        loads = self._backend.live_agents
        caps = self._backend.virtual_capacities
        return min(self.candidates(), key=lambda k: (loads[k] / caps[k], k))


@register_router("memory_cost_aware", "cost_aware", "mca")
class MemoryCostAwareRouter(Router):
    """Greedy balancing of outstanding predicted KV token-time.

    Routes to the replica whose post-placement load-to-capacity ratio is
    smallest — the predictor's memory-centric cost estimate stands in for
    the agent's true KV footprint, exactly as it does for Justitia's
    virtual finish times.
    """

    def pick(self, spec: AgentSpec, agent_id: int, pred_cost: float) -> int:
        costs = self._backend.live_cost
        caps = self._backend.virtual_capacities
        return min(
            self.candidates(),
            key=lambda k: ((costs[k] + pred_cost) / caps[k], k),
        )


# -------------------------------------------------------------- failures


class FleetStalledError(RuntimeError):
    """A replica stopped progressing and no watchdog is armed to fail it.

    Raised by :meth:`ReplicatedBackend.run` instead of leaving the fleet
    spinning toward a horizon a crashed child can never reach.  Carries the
    diagnostic state the watchdog would have acted on: the stalled child's
    index, its last event time, its in-flight count, the drive target, and
    the fleet queue-depth snapshot — live replicas report their in-flight
    counts, already-dead replicas are labeled ``"dead"`` explicitly (their
    stranded queues are not depths the fleet can still drain, so counting
    them as numbers misdiagnosed the backlog).
    """

    def __init__(
        self,
        replica: int,
        last_time: float,
        in_flight: int,
        target: float,
        queue_depths: dict,
    ):
        self.replica = int(replica)
        self.last_time = float(last_time)
        self.in_flight = int(in_flight)
        self.target = float(target)
        self.queue_depths = dict(queue_depths)
        super().__init__(
            f"replica {replica} stalled at t={last_time:.6f} with "
            f"{in_flight} in-flight agent(s) while the fleet drives to "
            f"t={target:.6f} (live queue depths: {queue_depths}); arm "
            f"watchdog_timeout for automatic failover"
        )


# ------------------------------------------------------ replica channel


class _ReplicaChannel:
    """Child k's listener: tags callbacks with ``replica=k``, converts the
    child's native timestamps to workload seconds, and keeps the fleet's
    load accounting current (completions decrement the router's view,
    stage completions feed the failover respec bookkeeping).

    Concurrent advancement (PR 10) puts the channel in *buffering* mode
    for the span of one fleet slice: ``_buf`` is flipped from ``None`` to
    a list before the child is handed to a worker thread, every callback
    then records ``(method, args)`` and returns, and after the barrier the
    fleet replays the buffers **in child-index order** by re-invoking the
    same methods with ``_buf = None`` — which reproduces, event for event,
    the global order the sequential lockstep loop (child 0 fully, then
    child 1, ...) would have produced, so listener streams, fleet
    bookkeeping, and global-clock ``_seq`` assignment are bit-identical.
    Two side effects cannot wait for the replay because the child consults
    their results *before* its ``run()`` returns: closed-loop stage
    advancement (the session must append the next stage ahead of the
    child's stage-exhaustion check — see :meth:`on_stage_complete`) and
    the per-agent token counters that feed it.  Both are thread-confined:
    each agent lives on exactly one replica during a slice, so its counter
    keys are touched by one worker only, and the in-band session call is
    serialized under the fleet's ``_cl_lock``.
    """

    def __init__(self, fleet: "ReplicatedBackend", replica: int):
        self.fleet = fleet
        self.replica = replica
        self._buf: Optional[list] = None

    def _forward(self, event: str, agent_id: int, t: float, *args) -> None:
        listener = self.fleet._listener
        if listener is None:
            return
        fn = getattr(listener, event, None)
        if fn is None:
            return
        tw = self.fleet.children[self.replica].to_workload_time(t)
        fn(agent_id, *args, tw, replica=self.replica)

    def _replay(self) -> None:
        """Flush the slice buffer through the passthrough paths (barrier
        side, main thread): re-invoke each buffered method with ``_buf``
        cleared so fleet bookkeeping and listener forwards run exactly as
        they would have in the sequential loop."""
        buf, self._buf = self._buf, None
        for name, args in buf:
            getattr(self, name)(*args)

    def on_arrival(self, agent_id: int, t: float) -> None:
        if self._buf is not None:
            self._buf.append(("on_arrival", (agent_id, t)))
            return
        fleet = self.fleet
        fleet._arrived.add(agent_id)
        if agent_id in fleet._suppress_arrival:
            # failover re-submission: the agent already announced itself on
            # the dead replica — exactly one AgentArrived per agent
            fleet._suppress_arrival.discard(agent_id)
            return
        self._forward("on_arrival", agent_id, t)

    def on_admit(self, agent_id: int, rid: int, t: float) -> None:
        if self._buf is not None:
            self._buf.append(("on_admit", (agent_id, rid, t)))
            return
        self.fleet._ever_admitted.add(agent_id)
        self._forward("on_admit", agent_id, t, rid)

    def on_swap_out(self, agent_id: int, rid: int, t: float) -> None:
        if self._buf is not None:
            self._buf.append(("on_swap_out", (agent_id, rid, t)))
            return
        self._forward("on_swap_out", agent_id, t, rid)

    def on_swap_in(self, agent_id: int, rid: int, t: float) -> None:
        if self._buf is not None:
            self._buf.append(("on_swap_in", (agent_id, rid, t)))
            return
        self._forward("on_swap_in", agent_id, t, rid)

    def on_token(self, agent_id: int, rid: int, token: int, t: float) -> None:
        if self._buf is not None:
            # counted in-band: an in-band closed-loop stage boundary later
            # in this same slice needs the stage's token count before the
            # replay delivers the events (thread-confined per agent key)
            tok = self.fleet._cl_tokens
            tok[agent_id] = tok.get(agent_id, 0) + 1
            self._buf.append(("on_token", (agent_id, rid, token, t)))
            return
        self._forward("on_token", agent_id, t, rid, token)

    def on_prefix_hit(
        self, agent_id: int, rid: int, cached: int, prefill: int, t: float
    ) -> None:
        if self._buf is not None:
            self._buf.append(
                ("on_prefix_hit", (agent_id, rid, cached, prefill, t))
            )
            return
        self._forward("on_prefix_hit", agent_id, t, rid, cached, prefill)

    def on_admission_deferred(
        self, agent_id: int, rid: int, t: float
    ) -> None:
        if self._buf is not None:
            self._buf.append(("on_admission_deferred", (agent_id, rid, t)))
            return
        self._forward("on_admission_deferred", agent_id, t, rid)

    def on_stage_complete(self, agent_id: int, stage: int, t: float) -> None:
        fleet = self.fleet
        if self._buf is not None:
            spec = fleet._specs.get(agent_id)
            if spec is not None and spec.next_stage is not None:
                # in-band closed-loop advancement: the child checks stage
                # exhaustion the moment this emission returns, so the
                # session must run NOW, on this worker thread, and append
                # the next stage via submit_stage — buffering it to the
                # replay would complete the agent a whole slice early.
                # new_tokens comes from the fleet's in-band counters (the
                # dispatcher's handle counts are stale until the replay).
                tok = fleet._cl_tokens.get(agent_id, 0)
                new = tok - fleet._cl_marks.get(agent_id, 0)
                fleet._cl_marks[agent_id] = tok
                fleet._cl_inband(
                    agent_id, stage, new,
                    fleet.children[self.replica].to_workload_time(t),
                    self.replica,
                )
            self._buf.append(("on_stage_complete", (agent_id, stage, t)))
            return
        done = fleet._stages_done
        done[agent_id] = max(done.get(agent_id, 0), stage + 1)
        self._forward("on_stage_complete", agent_id, t, stage)

    def on_suspend(
        self, agent_id: int, stage: int, until: float, t: float
    ) -> None:
        if self._buf is not None:
            self._buf.append(("on_suspend", (agent_id, stage, until, t)))
            return
        fleet = self.fleet
        child = fleet.children[self.replica]
        until_w = child.to_workload_time(until)
        fleet._suspended[agent_id] = until_w
        if not fleet.think_time_accrual:
            fleet.global_clock.note_suspend(
                self.replica, agent_id, child.to_workload_time(t)
            )
        self._forward("on_suspend", agent_id, t, stage, until_w)

    def on_resume(self, agent_id: int, t: float) -> None:
        if self._buf is not None:
            self._buf.append(("on_resume", (agent_id, t)))
            return
        fleet = self.fleet
        fleet._suspended.pop(agent_id, None)
        if not fleet.think_time_accrual:
            fleet.global_clock.note_resume(
                self.replica, agent_id,
                fleet.children[self.replica].to_workload_time(t),
            )
        self._forward("on_resume", agent_id, t)

    def on_agent_complete(self, agent_id: int, t: float) -> None:
        if self._buf is not None:
            self._buf.append(("on_agent_complete", (agent_id, t)))
            return
        tw = self.fleet.children[self.replica].to_workload_time(t)
        self.fleet._on_child_complete(self.replica, agent_id, tw)
        self._forward("on_agent_complete", agent_id, t)


# ---------------------------------------------------- replicated backend


class ReplicatedBackend:
    """N child backends behind the single-backend protocol (see module doc).

    ``submit`` places each agent on one child via the router; ``run``
    advances every child to the same workload time (slicing at fault
    boundaries when a plan is armed); ``drain`` drains the live children,
    merges their results, and reconciles the per-replica virtual clocks
    (the snapshot lands in ``BackendResult.metrics`` as
    ``global_virtual_time`` / ``virtual_lag`` / ``virtual_times``).
    """

    name = "replicated"

    def __init__(
        self,
        children: Sequence[Backend],
        *,
        router: "str | Router" = "round_robin",
        seed: int = 0,
        fault_plan: Optional[FaultPlan] = None,
        watchdog_timeout: Optional[float] = None,
        watchdog_retries: int = 3,
        watchdog_backoff: float = 2.0,
        think_time_accrual: bool = True,
        fleet_workers: Optional[int] = None,
        steal_threshold: Optional[float] = None,
        steal_interval: float = 1.0,
        retain_agents: bool = True,
    ):
        self.children: list[Backend] = list(children)
        if not self.children:
            raise ValueError("need at least one child backend")
        if isinstance(router, str):
            router = resolve_router(router)(len(self.children), seed)
        elif router.n != len(self.children):
            raise ValueError(
                f"router sized for {router.n} replicas, have "
                f"{len(self.children)}"
            )
        self.router = router
        self.router.bind(self)
        self.virtual_capacities = [c.virtual_capacity for c in self.children]
        self.global_clock = GlobalVirtualClock(self.virtual_capacities)
        self.assignment: dict[int, int] = {}     # agent_id -> replica
        self.live_agents = [0] * len(self.children)
        self.live_cost = [0.0] * len(self.children)
        self._pred_cost: dict[int, float] = {}
        self._listener: Any = None
        self._last_snapshot: Optional[GlobalClockSnapshot] = None
        # --- fault injection + watchdog (see module doc) ----------------
        if fault_plan is not None:
            for f in fault_plan.faults:
                if f.replica >= len(self.children):
                    raise ValueError(
                        f"fault plan targets replica {f.replica} of "
                        f"{len(self.children)}"
                    )
        self._plan = fault_plan
        if watchdog_timeout is not None:
            if watchdog_timeout <= 0:
                raise ValueError("watchdog_timeout must be positive")
            if watchdog_retries < 0:
                raise ValueError("watchdog_retries must be >= 0")
            if watchdog_backoff < 1.0:
                raise ValueError("watchdog_backoff must be >= 1")
        self._wd_timeout = watchdog_timeout
        self._wd_retries = int(watchdog_retries)
        self._wd_backoff = float(watchdog_backoff)
        # probe offsets after a window edge: timeout, then retries
        # backoff-growing intervals; the last offset is the death budget
        if watchdog_timeout is not None:
            offs, acc = [], 0.0
            for i in range(self._wd_retries + 1):
                acc += watchdog_timeout * self._wd_backoff**i
                offs.append(acc)
            self._wd_offsets = tuple(offs)
            self._wd_budget = offs[-1]
        else:
            self._wd_offsets = ()
            self._wd_budget = 0.0
        self._dead: set[int] = set()
        self._suspect: set[int] = set()
        self._wd_last: dict[int, float] = {}
        self._failures: list[tuple[int, float]] = []   # (replica, t)
        # --- failover bookkeeping ---------------------------------------
        self._specs: dict[int, AgentSpec] = {}
        self._arrival0: dict[int, float] = {}          # first-submit arrival
        self._extras: dict[int, list] = {}             # appended stages
        self._stages_done: dict[int, int] = {}         # since last (re)submit
        self._stage_base: dict[int, int] = {}          # done before requeue
        self._completed: set[int] = set()
        self._fleet_finish: dict[int, tuple[float, int]] = {}
        self._arrived: set[int] = set()
        self._suppress_arrival: set[int] = set()
        self._requeued: set[int] = set()
        # --- suspension (PR 9) ------------------------------------------
        # ``think_time_accrual`` picks the fleet's GPS stance on tool-call
        # think time: True (Justitia) keeps a suspended agent in its
        # replica's GPS reference, so think time accrues virtual time and
        # its F_j ordering is untouched; False (the Equinox stance) pulls
        # it out via VirtualClock.deactivate — V speeds up for the agents
        # still decoding and the thinker accrues nothing while idle.
        self.think_time_accrual = bool(think_time_accrual)
        self._suspended: dict[int, float] = {}   # agent_id -> until (s)
        # --- concurrent advancement + work stealing (PR 10) -------------
        # fleet_workers > 1 turns each _drive slice into a bounded
        # thread-pool fan-out with child-major buffer replay (see
        # _ReplicaChannel); None/0/1 keeps the frozen sequential loop.
        if fleet_workers is not None and fleet_workers < 0:
            raise ValueError("fleet_workers must be >= 0")
        self._n_workers = min(
            int(fleet_workers or 1), len(self.children)
        ) or 1
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pool_finalizer = None
        # load-triggered work stealing: armed when steal_threshold is set
        # (> 1 — it multiplies the fleet-mean normalized backlog; the gap
        # between trigger and the stop-at-mean drain is the hysteresis
        # band that prevents migration thrash)
        if steal_threshold is not None and steal_threshold <= 1.0:
            raise ValueError("steal_threshold must be > 1")
        if steal_interval <= 0.0:
            raise ValueError("steal_interval must be positive")
        self.steal_threshold = (
            None if steal_threshold is None else float(steal_threshold)
        )
        self.steal_interval = float(steal_interval)
        self._ever_admitted: set[int] = set()
        self._stolen: set[int] = set()
        self._steals: list[tuple[int, int, int, float]] = []
        # in-band closed-loop plumbing (concurrent slices only)
        self._cl_lock = threading.Lock()
        self._cl_tokens: dict[int, int] = {}
        self._cl_marks: dict[int, int] = {}
        # streaming mode: retain_agents=False drops per-agent fleet
        # bookkeeping at completion and queues the finish times for
        # compact(), trading per-agent results for O(live) memory
        self.retain_agents = bool(retain_agents)
        self._compact_done: list[tuple[float, int]] = []
        self._channels: list[_ReplicaChannel] = [
            _ReplicaChannel(self, idx)
            for idx in range(len(self.children))
        ]
        for child, chan in zip(self.children, self._channels):
            child.set_listener(chan)

    # --------------------------------------------------------- protocol

    @property
    def now(self) -> float:
        return max(
            c.now
            for k, c in enumerate(self.children)
            if k not in self._dead
        )

    @property
    def virtual_capacity(self) -> float:
        return float(sum(self.virtual_capacities))

    @property
    def n_replicas(self) -> int:
        return len(self.children)

    @property
    def live_replica_indices(self) -> tuple[int, ...]:
        return tuple(
            k for k in range(len(self.children)) if k not in self._dead
        )

    @property
    def dead_replica_indices(self) -> tuple[int, ...]:
        return tuple(sorted(self._dead))

    def set_listener(self, listener: Any) -> None:
        """Install the fleet listener.

        Callbacks arrive in workload seconds with a ``replica=k`` keyword
        identifying the serving child (the channels convert each child's
        native clock before forwarding), so ``to_workload_time`` is the
        identity here.  Fleet-scoped events (``on_replica_failed`` /
        ``on_replica_recovered``) use ``agent_id=-1``.
        """
        self._listener = listener

    def to_workload_time(self, t: float) -> float:
        return float(t)

    def _notify(self, event: str, agent_id: int, *args,
                t: float, replica: int) -> None:
        """Fleet-originated listener callback (already workload seconds)."""
        listener = self._listener
        if listener is None:
            return
        fn = getattr(listener, event, None)
        if fn is not None:
            fn(agent_id, *args, float(t), replica=replica)

    def submit(self, spec: AgentSpec, agent_id: int) -> float:
        pred, _ = spec.resolved_costs()
        replica = self.router.pick(spec, agent_id, pred)
        if not 0 <= replica < len(self.children):
            raise ValueError(
                f"router {self.router.name!r} picked replica {replica} "
                f"of {len(self.children)}"
            )
        if replica in self._dead:
            raise ValueError(
                f"router {self.router.name!r} picked dead replica {replica}"
            )
        arrival = self.children[replica].submit(spec, agent_id)
        self.assignment[agent_id] = replica
        self.live_agents[replica] += 1
        self.live_cost[replica] += pred
        self._pred_cost[agent_id] = pred
        self._specs[agent_id] = spec
        self._arrival0[agent_id] = arrival
        self.global_clock.register(replica, agent_id, arrival, pred)
        return arrival

    def submit_stage(self, agent_id: int, specs, **kw) -> None:
        """Route a closed-loop follow-up stage to the agent's replica.

        ``**kw`` forwards the optional prefix-cache metadata
        (``prompt_ids``/``hints``) untouched — each child scales it to
        its own granularity.  The stage is also recorded fleet-side so a
        later failover can re-submit the agent's full remaining work.
        """
        try:
            replica = self.assignment[agent_id]
        except KeyError:
            raise ValueError(
                f"agent {agent_id} was never placed on this fleet"
            ) from None
        self._extras.setdefault(agent_id, []).append(
            (list(specs), kw.get("prompt_ids"), kw.get("hints"))
        )
        self.children[replica].submit_stage(agent_id, specs, **kw)

    def run(self, until: float) -> None:
        """Advance the whole fleet in lockstep to ``until`` (seconds).

        Without a fault plan, work stealing, or a worker pool this is the
        plain lockstep loop (bit-identical to the pre-fault-tolerance
        fleet).  Otherwise advancement goes through :meth:`_drive`, sliced
        at the plan's window edges, the watchdog's probe deadlines, and
        the steal-interval multiples — the slice targets depend only on
        the plan/steal configuration, never on ``fleet_workers``, which is
        what lets the concurrency property tests demand bit-identity
        between the sequential and the pooled stepper on the same plan.
        """
        if (
            self._plan is not None
            or self.steal_threshold is not None
            or self._n_workers > 1
        ):
            self._drive(float(until))
            return
        for k, child in enumerate(self.children):
            if k not in self._dead:
                child.run(until)

    # ------------------------------------------------------- sliced drive

    def _drive(self, until: float) -> None:
        start = self.now
        if until <= start + _EPS:
            return
        cand: set[float] = set()
        if self._plan is not None:
            for b in self._plan.boundaries():
                cand.add(b)
                for off in self._wd_offsets:
                    cand.add(b + off)
        if self.steal_threshold is not None:
            # integer multiples of the steal interval (no accumulating
            # float steps): the serialized points where backlog imbalance
            # is measured and queued agents may migrate
            step = self.steal_interval
            i = int(math.floor((start + _EPS) / step)) + 1
            while i * step < until - _EPS:
                if i * step > start + _EPS:
                    cand.add(i * step)
                i += 1
        targets = sorted(t for t in cand if start + _EPS < t < until - _EPS)
        targets.append(until)
        for s in targets:
            self._advance_slice(s)
            if self._plan is not None:
                self._watch(s)
            if self.steal_threshold is not None:
                self._steal(s)

    def _advance_slice(self, s: float) -> None:
        """Step every live child to its (fault-clamped) horizon for one
        slice ending at fleet time ``s``.

        Sequential mode steps children in index order on the caller's
        thread.  Concurrent mode flips every stepped child's channel into
        buffering, fans the ``run`` calls out on the worker pool (the only
        shared state a child touches mid-slice is thread-confined or
        ``_cl_lock``-serialized — see :class:`_ReplicaChannel`), joins
        them all (the reconcile barrier), then replays the buffers in
        child-index order, which reproduces the sequential loop's global
        event order exactly.  A child that raises still has its buffer
        replayed (its pre-fault events are real); the lowest-index error
        is then re-raised.
        """
        horizons: list[tuple[int, float]] = []
        for k in self.live_replica_indices:
            child = self.children[k]
            h = s if self._plan is None else min(s, self._plan.horizon(k, s))
            if h > child.now + _EPS:
                horizons.append((k, h))
        if not horizons:
            return
        if self._n_workers <= 1:
            for k, h in horizons:
                self.children[k].run(h)
            return
        for k, _ in horizons:
            self._channels[k]._buf = []
        pool = self._ensure_pool()
        futures = [
            (k, pool.submit(self.children[k].run, h)) for k, h in horizons
        ]
        errors: list[tuple[int, BaseException]] = []
        for k, fut in futures:
            try:
                fut.result()
            except BaseException as exc:  # noqa: BLE001 — rethrown below
                errors.append((k, exc))
        for k, _ in horizons:
            self._channels[k]._replay()
        if errors:
            raise errors[0][1]

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._n_workers,
                thread_name_prefix="fleet-child",
            )
            # bound method keeps the executor (not self) alive until the
            # fleet is collected without an explicit close()
            self._pool_finalizer = weakref.finalize(
                self, self._pool.shutdown, wait=False
            )
        return self._pool

    def close(self) -> None:
        """Shut the worker pool down (idempotent; the fleet stays usable —
        the next concurrent slice lazily recreates the pool)."""
        if self._pool is not None:
            if self._pool_finalizer is not None:
                self._pool_finalizer.detach()
                self._pool_finalizer = None
            self._pool.shutdown(wait=True)
            self._pool = None

    # ---------------------------------------------------- work stealing

    def _steal(self, s: float) -> None:
        """One load-triggered stealing pass at fleet time ``s`` (serialized,
        after the slice barrier and any watchdog verdicts).

        Backlog load of replica k = Σ predicted cost of its *eligible*
        agents / ``virtual_capacity[k]``; eligible means arrived, never
        admitted, not completed, not suspended — an admitted agent has KV
        state worth locality, a suspended one is mid-think with retained
        state, and a not-yet-arrived one is invisible backlog, so only
        cold queued work ever migrates.  A replica whose load exceeds
        ``steal_threshold`` x the live-fleet mean sheds its newest-arrived
        victims (LIFO keeps FIFO service order intact for the head of the
        queue) onto underloaded live, non-suspect replicas until it drains
        back to the mean — the threshold→mean gap is the hysteresis band.
        The mean is fixed for the pass; per-replica loads update as each
        victim lands so one pass cannot overshoot a target.  The child's
        ``cancel`` is the authoritative eligibility gate: anything it
        refuses (raced into admission inside the slice) is skipped.
        """
        thr = self.steal_threshold
        live = [k for k in self.live_replica_indices if k not in self._suspect]
        if len(live) < 2:
            return
        eligible: dict[int, list[int]] = {k: [] for k in live}
        for aid, k in self.assignment.items():
            if (
                k in eligible
                and aid in self._arrived
                and aid not in self._ever_admitted
                and aid not in self._completed
                and aid not in self._suspended
            ):
                eligible[k].append(aid)
        load = {
            k: sum(self._pred_cost.get(a, 0.0) for a in eligible[k])
            / self.virtual_capacities[k]
            for k in live
        }
        mean = sum(load.values()) / len(live)
        if mean <= _EPS:
            return
        for k in live:
            if load[k] <= thr * mean + _EPS:
                continue
            victims = sorted(
                eligible[k],
                key=lambda a: (-self._arrival0.get(a, 0.0), -a),
            )
            for aid in victims:
                if load[k] <= mean + _EPS:
                    break
                targets = [
                    j for j in live if j != k and load[j] < mean - _EPS
                ]
                if not targets:
                    break
                j = min(targets, key=lambda x: (load[x], x))
                old_cost = self._pred_cost.get(aid, 0.0)
                # anti-thrash guard: the move must strictly shrink the
                # pairwise max — when the tail holds too few queued agents
                # to balance, "drain to the mean" alone ping-pongs the
                # same victims between replicas every interval
                new_k = load[k] - old_cost / self.virtual_capacities[k]
                new_j = load[j] + old_cost / self.virtual_capacities[j]
                if max(new_k, new_j) >= load[k] - _EPS:
                    continue
                if not self.children[k].cancel(aid):
                    continue
                spec = self._respec(aid, s)
                if spec is None:  # pragma: no cover — never-admitted ⇒ work
                    continue
                cost = spec.resolved_costs()[0]
                self.live_agents[k] -= 1
                self.live_cost[k] -= old_cost
                self._stage_base[aid] = self._stage_base.get(
                    aid, 0
                ) + self._stages_done.pop(aid, 0)
                self._extras.pop(aid, None)
                self._specs[aid] = spec
                self._suppress_arrival.add(aid)
                arrival = self.children[j].submit(spec, aid)
                self.assignment[aid] = j
                self.live_agents[j] += 1
                self.live_cost[j] += cost
                self._pred_cost[aid] = cost
                self.global_clock.steal(aid, k, j, arrival, cost)
                self._requeued.add(aid)
                self._stolen.add(aid)
                self._steals.append((aid, k, j, float(max(arrival, s))))
                self._notify(
                    "on_requeued", aid, k, t=max(arrival, s), replica=j
                )
                load[k] -= old_cost / self.virtual_capacities[k]
                load[j] += cost / self.virtual_capacities[j]

    # ------------------------------------------------ closed-loop in-band

    def _cl_inband(
        self, agent_id: int, stage: int, new_tokens: int, t: float,
        replica: int,
    ) -> None:
        """Run a closed-loop agent's session in-band during a concurrent
        slice (called from the serving child's worker thread — see
        :meth:`_ReplicaChannel.on_stage_complete`).  Serialized under
        ``_cl_lock``; the listener's ``on_closed_loop_stage`` runs the
        session and appends the next stage, and later suppresses its own
        replayed ``on_stage_complete`` advancement so the session fires
        exactly once per logical stage."""
        listener = self._listener
        if listener is None:
            return
        fn = getattr(listener, "on_closed_loop_stage", None)
        if fn is None:
            raise RuntimeError(
                "concurrent fleet advancement requires the listener to "
                "implement on_closed_loop_stage for closed-loop agents: "
                "the session must run inside the serving child's emission "
                "(before its stage-exhaustion check), not at buffer "
                "replay — drive closed-loop work through AgentService, or "
                "add the hook to the listener"
            )
        with self._cl_lock:
            fn(agent_id, stage, new_tokens, t, replica=replica)

    def _watch(self, s: float) -> None:
        """One watchdog pass at fleet time ``s`` (after driving children).

        A live, busy child lagging the slice target by one timeout turns
        SUSPECT; a suspect that catches back up emits ``ReplicaRecovered``;
        a suspect that made no progress since the previous probe and lags
        by the full budget is declared DEAD and failed over.  With the
        watchdog disabled, a crashed-and-busy child raises
        :class:`FleetStalledError` instead (stall guard).
        """
        deaths: list[int] = []
        for k in self.live_replica_indices:
            child = self.children[k]
            now_k = child.now
            lag = s - now_k
            busy = getattr(child, "in_flight", 0) > 0
            if self._wd_timeout is None:
                if busy and lag > _EPS and self._plan.crash_time(k) <= s:
                    raise FleetStalledError(
                        k, now_k, child.in_flight, s, self._queue_depths()
                    )
                continue
            last = self._wd_last.get(k)
            progressed = last is None or now_k > last + _EPS
            self._wd_last[k] = now_k
            if busy and lag > _EPS:
                if (
                    k in self._suspect
                    and not progressed
                    and lag >= self._wd_budget - _EPS
                ):
                    deaths.append(k)
                elif lag >= self._wd_timeout - _EPS:
                    self._suspect.add(k)
            elif k in self._suspect and lag <= _EPS:
                self._suspect.discard(k)
                self._notify("on_replica_recovered", -1, t=s, replica=k)
        for k in deaths:
            self._fail_replica(k, s)

    def _queue_depths(self) -> dict:
        """Diagnostic fleet snapshot: live replicas map to their in-flight
        counts; dead replicas map to the literal ``"dead"`` so a stranded
        queue is never mistaken for drainable backlog."""
        depths: dict = {
            j: getattr(self.children[j], "in_flight", 0)
            for j in self.live_replica_indices
        }
        for j in self.dead_replica_indices:
            depths[j] = "dead"
        return depths

    # --------------------------------------------------------- failover

    def _respec(self, agent_id: int, t: float) -> Optional[AgentSpec]:
        """The agent's remaining work as a fresh :class:`AgentSpec`.

        Completed stages (original + closed-loop appendments) are dropped;
        the in-progress stage restarts from its beginning (stage-granularity
        retry — per-stage completion callbacks therefore still fire exactly
        once per logical stage).  Per-stage metadata rides along when it can
        be aligned with the surviving stages and is dropped otherwise
        (prompts are then re-synthesized by the target child).  Returns
        ``None`` when nothing remains.
        """
        spec = self._specs[agent_id]
        extras = self._extras.get(agent_id, [])
        stages = [list(st) for st in spec.stages]
        stages += [list(sp) for sp, _, _ in extras]
        done = self._stage_base.get(agent_id, 0) + self._stages_done.get(
            agent_id, 0
        )
        if done >= len(stages):
            return None
        remaining = stages[done:]

        def aligned(base, idx):
            if spec.stages and base is None:
                return None
            if any(e[idx] is None for e in extras):
                return None
            merged = list(base or []) + [list(e[idx]) for e in extras]
            return merged[done:]

        prompt_ids = aligned(spec.prompt_ids, 1)
        hints = aligned(spec.cached_hints, 2)
        prompts = None
        if spec.prompts is not None and not extras:
            prompts = [list(p) for p in spec.prompts][done:]
        return dataclasses.replace(
            spec,
            stages=remaining,
            arrival=max(float(t), self._arrival0.get(agent_id, 0.0)),
            prompts=prompts,
            prompt_ids=prompt_ids,
            cached_hints=hints,
        )

    def _fail_replica(self, k: int, t: float) -> None:
        """Declare child ``k`` DEAD at fleet time ``t`` and fail over.

        The dead child is excluded from every future advance/drain (its
        internal queue still holds the victims, but it is never driven
        again); each uncompleted agent assigned to it is re-specced to its
        remaining stages and re-submitted to a survivor chosen by
        ``router.rebalance``, carrying its accrued virtual time across the
        migration.  Emits one fleet-scoped ``ReplicaFailed`` plus one
        ``AgentRequeued`` per already-arrived victim (never-arrived agents
        are re-placed silently — their single ``AgentArrived`` fires on the
        survivor).
        """
        child = self.children[k]
        self._dead.add(k)
        self._suspect.discard(k)
        if len(self._dead) >= len(self.children):
            raise RuntimeError(
                f"replica {k} failed at t={t:.6f} and no live replica "
                f"remains to fail over to"
            )
        self._failures.append((k, float(t)))
        reason = (
            f"no progress past t={child.now:.6f} for the watchdog budget "
            f"({self._wd_budget:.4f}s)"
        )
        self._notify("on_replica_failed", -1, reason, t=t, replica=k)
        self.global_clock.fail_replica(k)
        victims = sorted(
            (
                aid
                for aid, r in self.assignment.items()
                if r == k and aid not in self._completed
            ),
            key=lambda aid: (self._arrival0.get(aid, 0.0), aid),
        )
        queued = []
        for aid in victims:
            spec = self._respec(aid, t)
            self.live_agents[k] -= 1
            self.live_cost[k] -= self._pred_cost.get(aid, 0.0)
            if spec is None:
                continue
            until_s = self._suspended.get(aid)
            if until_s is not None and until_s > spec.arrival:
                # a suspended victim keeps thinking through the failover:
                # its remaining work may not start before the think time
                # elapses, so the survivor sees a correspondingly later
                # arrival (the tool call itself survives the crash — only
                # the serving replica died)
                spec = dataclasses.replace(spec, arrival=float(until_s))
            queued.append((spec, aid, spec.resolved_costs()[0]))
        placements = self.router.rebalance(queued)
        for (spec, aid, cost), r in zip(queued, placements):
            if r in self._dead or not 0 <= r < len(self.children):
                raise ValueError(
                    f"router {self.router.name!r} rebalanced agent {aid} "
                    f"onto unusable replica {r}"
                )
            # reset the stage cursor: the survivor re-indexes the trimmed
            # spec's stages from 0
            self._stage_base[aid] = self._stage_base.get(
                aid, 0
            ) + self._stages_done.pop(aid, 0)
            self._extras.pop(aid, None)
            self._specs[aid] = spec
            if aid in self._arrived:
                self._suppress_arrival.add(aid)
            arrival = self.children[r].submit(spec, aid)
            self.assignment[aid] = r
            self.live_agents[r] += 1
            self.live_cost[r] += cost
            self._pred_cost[aid] = cost
            self.global_clock.migrate(aid, r, arrival, cost)
            if aid in self._arrived:
                # a suspended victim's open suspension closes HERE, on the
                # dead replica, exactly once — the survivor serves the
                # re-specced remainder as a fresh submission and will not
                # re-emit the resume
                if self._suspended.pop(aid, None) is not None:
                    self._notify(
                        "on_resume", aid, t=max(arrival, t), replica=k
                    )
                self._requeued.add(aid)
                self._notify(
                    "on_requeued", aid, k, t=max(arrival, t), replica=r
                )
            else:
                self._suspended.pop(aid, None)

    # ------------------------------------------------------------ drain

    def drain(self) -> BackendResult:
        # flush past every planned fault (plus the watchdog budget) first,
        # so failures scheduled after the last submission still trigger
        # detection and failover before results are collected; without a
        # watchdog the flush still overshoots the last edge so the stall
        # guard can observe a crashed-and-busy child (draining it blind
        # would serve agents the crash should have stranded)
        if self._plan is not None:
            margin = self._wd_budget if self._wd_timeout is not None else 1e-3
            flush = self._plan.max_boundary() + margin
            if flush > self.now + _EPS:
                self.run(flush)
        finish: dict[int, float] = {}
        jct: dict[int, float] = {}
        per_replica: list[dict] = []
        swaps = decisions = 0
        sched_time = 0.0
        makespan = 0.0
        # fleet-level prefix-cache metrics, aggregated exactly like jct:
        # hit_fractions dict-merge (agent ids are fleet-unique — the
        # service assigns them before routing), prefill_tokens_saved
        # summed (children report backend-native token scales)
        hit_fractions: dict[int, float] = {}
        prefill_tokens_saved = 0
        admission_deferrals = 0
        suspensions = resumes = suspend_spills = 0
        held_peak = 0.0
        for idx, child in enumerate(self.children):
            if idx in self._dead:
                # never driven again: harvest its pre-failure completions
                # from the fleet-side records instead of draining it (a
                # drain would re-serve the migrated victims it still holds)
                per_replica.append(
                    {
                        "backend": child.name,
                        "dead": True,
                        "agents": sum(
                            1
                            for _, r in self._fleet_finish.values()
                            if r == idx
                        ),
                        "makespan": child.now,
                        "swaps": 0,
                    }
                )
                continue
            res = child.drain()
            finish.update(res.finish)
            jct.update(res.jct)
            swaps += res.swaps
            decisions += res.sched_decisions
            sched_time += res.sched_time
            makespan = max(makespan, res.makespan)
            hit_fractions.update(res.metrics.get("hit_fractions") or {})
            prefill_tokens_saved += res.metrics.get(
                "prefill_tokens_saved", 0
            ) or 0
            admission_deferrals += res.metrics.get(
                "admission_deferrals", 0
            ) or 0
            suspensions += res.metrics.get("suspensions", 0) or 0
            resumes += res.metrics.get("resumes", 0) or 0
            suspend_spills += res.metrics.get("suspend_spills", 0) or 0
            held_peak = max(
                held_peak, res.metrics.get("held_peak", 0.0) or 0.0
            )
            per_replica.append(
                {
                    "backend": child.name,
                    "agents": len(res.finish),
                    "makespan": res.makespan,
                    "swaps": res.swaps,
                    **{f"child_{k}": v for k, v in res.metrics.items()},
                }
            )
        # completions that happened on a replica before it died
        for aid, (tw, _r) in self._fleet_finish.items():
            if aid not in finish:
                finish[aid] = tw
                jct[aid] = tw - self._arrival0.get(aid, tw)
        # a migrated agent's JCT spans from its ORIGINAL arrival — the
        # survivor only saw the re-submission
        for aid in self._requeued:
            if aid in finish:
                jct[aid] = finish[aid] - self._arrival0.get(aid, finish[aid])
        # resume lockstep: drained children sit at their own makespans, so
        # re-anchor every live child at the fleet makespan — later
        # submissions then clamp to a common clock and can never predate
        # the reconciled horizon (submit/drain may interleave freely)
        makespan = max(makespan, self.now)
        for k in self.live_replica_indices:
            self.children[k].run(makespan)
        snap = self.global_clock.reconcile(makespan)
        self._last_snapshot = snap
        return BackendResult(
            finish=finish,
            jct=jct,
            makespan=makespan,
            swaps=swaps,
            sched_decisions=decisions,
            sched_time=sched_time,
            metrics={
                "replicas": len(self.children),
                "live_replicas": len(self.live_replica_indices),
                "router": self.router.name,
                "per_replica": per_replica,
                "global_virtual_time": snap.global_virtual_time,
                "virtual_lag": snap.lag,
                "virtual_times": list(snap.virtual_times),
                "hit_fractions": hit_fractions,
                "prefill_tokens_saved": prefill_tokens_saved,
                "admission_deferrals": admission_deferrals,
                "replica_failures": len(self._failures),
                "failed_replicas": sorted(self._dead),
                "agents_requeued": len(self._requeued),
                "fleet_workers": self._n_workers,
                "agents_stolen": len(self._stolen),
                "steals": len(self._steals),
                "suspensions": suspensions,
                "resumes": resumes,
                "suspend_spills": suspend_spills,
                "held_peak": held_peak,
                "think_time_accrual": self.think_time_accrual,
            },
        )

    # ------------------------------------------------------- fleet state

    def _on_child_complete(
        self, replica: int, agent_id: int, t: Optional[float] = None
    ) -> None:
        self.live_agents[replica] -= 1
        self.live_cost[replica] -= self._pred_cost.pop(agent_id, 0.0)
        if self.retain_agents:
            self._completed.add(agent_id)
            if t is not None:
                self._fleet_finish[agent_id] = (float(t), replica)
            return
        # streaming mode: drop every per-agent map at completion — the
        # assignment pop is what keeps _steal/_fail_replica correct
        # without the O(agents) _completed set, and the finish time is
        # queued so compact() can forget the clock entry once the arrival
        # is safely reconciled (forgetting earlier would let the replayed
        # arrival resurrect the virtual finish)
        if t is not None and self._plan is not None:
            self._fleet_finish[agent_id] = (float(t), replica)
        self.assignment.pop(agent_id, None)
        self._specs.pop(agent_id, None)
        self._extras.pop(agent_id, None)
        self._stages_done.pop(agent_id, None)
        self._stage_base.pop(agent_id, None)
        self._arrival0.pop(agent_id, None)
        self._arrived.discard(agent_id)
        self._suppress_arrival.discard(agent_id)
        self._ever_admitted.discard(agent_id)
        self._stolen.discard(agent_id)
        self._requeued.discard(agent_id)
        self._suspended.pop(agent_id, None)
        self._cl_tokens.pop(agent_id, None)
        self._cl_marks.pop(agent_id, None)
        if t is not None:
            self._compact_done.append((float(t), agent_id))

    def compact(self, until: float) -> GlobalClockSnapshot:
        """Streaming-mode checkpoint: reconcile the global clock to
        ``until`` and forget clock bookkeeping for agents that completed
        at or before the reconciled horizon.

        Safe because reconcile replays every pending arrival up to
        ``until`` first — a forgotten agent's arrival can no longer be
        sitting in the pending heap waiting to re-create its virtual
        finish entry.  With ``retain_agents=True`` this is just an
        explicit reconcile."""
        snap = self.global_clock.reconcile(float(until))
        self._last_snapshot = snap
        if not self.retain_agents:
            keep: list[tuple[float, int]] = []
            for t, aid in self._compact_done:
                if t <= until + _EPS:
                    self.global_clock.forget(aid)
                else:
                    keep.append((t, aid))
            self._compact_done = keep
        return snap

    def pampering_order(self) -> list[int]:
        """Fleet-wide selective-pampering order (reconciled F_j ascending).

        Only agents whose arrivals have been reconciled (i.e. swept by
        ``drain`` or an explicit ``global_clock.reconcile``) appear.
        """
        return self.global_clock.pampering_order()

    def delay_bound(
        self, c_max: float, c_agent_max: float, service_rate: float = 1.0
    ) -> float:
        """Fleet-wide Theorem B.1 bound over the LIVE replicas.

        Delegates to :meth:`GlobalVirtualClock.delay_bound` — after a
        failover the bound is re-derived for the degraded fleet (dead
        capacities excluded), so it stays a valid worst-case statement for
        the replicas that are actually serving.
        """
        return self.global_clock.delay_bound(
            c_max, c_agent_max, service_rate
        )
