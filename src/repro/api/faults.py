"""Deterministic fault-injection plans for replicated fleets (PR 8).

A :class:`FaultPlan` is a seeded, immutable-once-built schedule of
replica-level faults — ``crash`` / ``stall`` / ``slowdown`` — that
:class:`repro.api.ReplicatedBackend` injects at ``advance()`` boundaries.
Faults are expressed purely in workload time and evaluated by clamping
each child's advancement horizon, never by mutating child state, so the
same plan on the same workload reproduces the same run bit for bit:

  * ``crash(replica, at)`` — the child stops advancing at ``at`` forever.
    Its queued/in-flight agents are failed over once the fleet watchdog
    declares it DEAD.
  * ``stall(replica, at, duration)`` — the child makes no progress inside
    ``[at, at + duration)`` and resumes afterwards.  Because both backends
    derive event timestamps from their own clocks (not from how often they
    are advanced), a stall shorter than the watchdog budget is invisible
    in the final results — it exercises the suspect/recover path only.
  * ``slowdown(replica, at, duration, factor)`` — inside the window the
    child advances at ``factor`` times real time (``0 < factor < 1``).

Windows on the same replica must not overlap, and nothing may be
scheduled after a crash on that replica.  ``FaultPlan.seeded`` builds a
reproducible random plan from an integer seed — the benchmark/chaos-demo
entry point.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

__all__ = ["Fault", "FaultPlan"]

_KINDS = ("crash", "stall", "slowdown")


@dataclass(frozen=True)
class Fault:
    """One scheduled fault window on one replica.

    ``start`` is inclusive; ``duration`` is ``inf`` for crashes.  For
    ``slowdown``, ``factor`` is the fraction of real-time progress the
    replica makes inside the window.
    """

    replica: int
    kind: str
    start: float
    duration: float = math.inf
    factor: float = 0.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.replica < 0:
            raise ValueError(f"negative replica index {self.replica}")
        if self.start < 0.0:
            raise ValueError(f"fault start {self.start} < 0")
        if self.duration <= 0.0:
            raise ValueError(f"fault duration {self.duration} <= 0")
        if self.kind == "crash" and not math.isinf(self.duration):
            raise ValueError("crash faults are permanent (duration=inf)")
        if self.kind == "stall" and math.isinf(self.duration):
            raise ValueError("stall needs a finite duration")
        if self.kind == "slowdown":
            if math.isinf(self.duration):
                raise ValueError("slowdown needs a finite duration")
            if not (0.0 < self.factor < 1.0):
                raise ValueError(
                    f"slowdown factor must be in (0, 1), got {self.factor}"
                )

    @property
    def end(self) -> float:
        return self.start + self.duration


@dataclass
class FaultPlan:
    """A deterministic per-replica fault schedule.

    Build with the ``crash`` / ``stall`` / ``slowdown`` methods (each
    returns ``self`` for chaining) or :meth:`seeded`; the plan validates
    itself on every addition.  Consumers only read — via
    :meth:`faults_for`, :meth:`crash_time`, :meth:`boundaries`, and
    :meth:`horizon` — so a plan can be reused across runs.
    """

    faults: list = field(default_factory=list)

    # ------------------------------------------------------------ builders
    def add(self, fault: Fault) -> "FaultPlan":
        for other in self.faults:
            if other.replica != fault.replica:
                continue
            if other.kind == "crash" and fault.start >= other.start:
                raise ValueError(
                    f"replica {fault.replica} crashes at {other.start}; "
                    f"cannot schedule {fault.kind} at {fault.start} after it"
                )
            if fault.kind == "crash" and other.start >= fault.start:
                raise ValueError(
                    f"crash at {fault.start} precedes existing "
                    f"{other.kind} at {other.start} on replica "
                    f"{fault.replica}"
                )
            if (fault.start < other.end and other.start < fault.end):
                raise ValueError(
                    f"overlapping fault windows on replica "
                    f"{fault.replica}: [{other.start}, {other.end}) and "
                    f"[{fault.start}, {fault.end})"
                )
        self.faults.append(fault)
        self.faults.sort(key=lambda f: (f.replica, f.start))
        return self

    def crash(self, replica: int, at: float) -> "FaultPlan":
        return self.add(Fault(replica, "crash", at))

    def stall(self, replica: int, at: float,
              duration: float) -> "FaultPlan":
        return self.add(Fault(replica, "stall", at, duration))

    def slowdown(self, replica: int, at: float, duration: float,
                 factor: float) -> "FaultPlan":
        return self.add(Fault(replica, "slowdown", at, duration, factor))

    @classmethod
    def seeded(cls, seed: int, n_replicas: int, *,
               n_crashes: int = 1, crash_window=(5.0, 20.0),
               n_stalls: int = 0, stall_duration=(1.0, 4.0)) -> "FaultPlan":
        """A reproducible random plan: ``n_crashes`` distinct replicas
        crash at times drawn from ``crash_window``; ``n_stalls`` distinct
        OTHER replicas stall once each."""
        import numpy as np

        if n_crashes + n_stalls > n_replicas:
            raise ValueError(
                f"{n_crashes} crashes + {n_stalls} stalls exceed "
                f"{n_replicas} replicas"
            )
        rng = np.random.default_rng(seed)
        victims = rng.permutation(n_replicas)
        plan = cls()
        lo, hi = crash_window
        for k in victims[:n_crashes]:
            plan.crash(int(k), float(rng.uniform(lo, hi)))
        dlo, dhi = stall_duration
        for k in victims[n_crashes:n_crashes + n_stalls]:
            plan.stall(int(k), float(rng.uniform(lo, hi)),
                       float(rng.uniform(dlo, dhi)))
        return plan

    # ------------------------------------------------------------- queries
    def faults_for(self, replica: int) -> list:
        return [f for f in self.faults if f.replica == replica]

    def crash_time(self, replica: int) -> float:
        """Crash time for ``replica``, or ``inf`` if it never crashes."""
        for f in self.faults:
            if f.replica == replica and f.kind == "crash":
                return f.start
        return math.inf

    def boundaries(self) -> list:
        """Every finite window edge, sorted — the fleet drive loop slices
        its advancement at these points so fault onsets/offsets land
        exactly where the plan says."""
        ts = set()
        for f in self.faults:
            ts.add(f.start)
            if not math.isinf(f.end):
                ts.add(f.end)
        return sorted(ts)

    def horizon(self, replica: int, target: float) -> float:
        """The furthest workload time ``replica`` may advance to when the
        fleet drives toward ``target``.

        Crash clamps at the crash time forever; a stall window clamps at
        its start until the window closes; a slowdown window maps fleet
        progress into the window at ``factor`` speed.  Outside any window
        the replica is unconstrained (returns ``target``).
        """
        h = target
        for f in self.faults_for(replica):
            if f.kind == "crash":
                h = min(h, f.start)
            elif f.kind == "stall":
                if target < f.end:
                    h = min(h, f.start)
            elif f.kind == "slowdown":
                if f.start < target < f.end:
                    h = min(h, f.start + f.factor * (target - f.start))
        return h

    def max_boundary(self) -> float:
        """Latest finite edge in the plan (0.0 for an empty plan) — the
        fleet drains past this plus the watchdog budget so every planned
        fault has been observed before results are collected."""
        finite = [t for t in self.boundaries() if not math.isinf(t)]
        return max(finite) if finite else 0.0
