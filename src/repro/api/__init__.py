"""Unified serving API: one facade over the simulator and the JAX engine.

    from repro.api import AgentService, AgentSpec

    service = AgentService.sim(scheduler="justitia")          # or .engine(...)
    handle = service.submit(AgentSpec(stages=[[InferenceSpec(300, 80)]]))
    result = service.drain()

See ``repro.api.service`` for the facade, ``repro.api.backend`` for the
``Backend`` protocol and how to add a backend, ``repro.api.events`` for the
streamed lifecycle events, and ``repro.core.registry`` for the scheduler
plugin registry the facade resolves policy names through.
"""

from repro.api.backend import (
    AgentSpec,
    Backend,
    BackendResult,
    EngineBackend,
    SimBackend,
)
from repro.api.events import (
    AdmissionDeferred,
    AgentArrived,
    AgentCompleted,
    AgentEvent,
    AgentHooks,
    AgentRequeued,
    AgentResumed,
    AgentSuspended,
    PrefixHit,
    ReplicaFailed,
    ReplicaRecovered,
    RequestAdmitted,
    RequestSwappedIn,
    RequestSwappedOut,
    StageCompleted,
    StageOutcome,
    TokenGenerated,
)
from repro.api.faults import Fault, FaultPlan
from repro.api.replicated import (
    FleetStalledError,
    ReplicatedBackend,
    Router,
    register_router,
    resolve_router,
    router_names,
)
from repro.api.service import (
    AgentHandle,
    AgentService,
    MetricsRecorder,
    ServiceResult,
)
from repro.api.workload import (
    service_for_backend,
    specs_from_classes,
    specs_from_closed_loop,
)

__all__ = [
    "AgentSpec",
    "Backend",
    "BackendResult",
    "EngineBackend",
    "SimBackend",
    "AdmissionDeferred",
    "AgentArrived",
    "AgentCompleted",
    "AgentEvent",
    "AgentHooks",
    "AgentRequeued",
    "AgentResumed",
    "AgentSuspended",
    "PrefixHit",
    "ReplicaFailed",
    "ReplicaRecovered",
    "RequestAdmitted",
    "RequestSwappedIn",
    "RequestSwappedOut",
    "StageCompleted",
    "StageOutcome",
    "TokenGenerated",
    "AgentHandle",
    "AgentService",
    "MetricsRecorder",
    "ServiceResult",
    "Fault",
    "FaultPlan",
    "FleetStalledError",
    "ReplicatedBackend",
    "Router",
    "register_router",
    "resolve_router",
    "router_names",
    "specs_from_classes",
    "specs_from_closed_loop",
    "service_for_backend",
]
