"""Typed lifecycle events streamed by :class:`repro.api.AgentService`.

Both backends (the discrete-event simulator and the real JAX engine) emit
the same duck-typed callbacks; the service's dispatcher normalizes them into
these frozen dataclasses with ``time`` in *workload seconds* regardless of
the backend's native clock (the engine counts iterations internally).

``TokenGenerated`` is engine-only: the simulator models decoding as a
continuous rate and has no per-token instants.

Every event carries a ``replica`` index when served through a
:class:`repro.api.ReplicatedBackend` (``None`` on single-backend services):
the fleet dispatcher tags each child backend's callbacks with the replica
that emitted them, so per-replica metrics fall out of the same stream.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class AgentEvent:
    agent_id: int
    time: float
    #: which replica of a ReplicatedBackend served this (None: unreplicated)
    replica: Optional[int] = dataclasses.field(default=None, kw_only=True)


@dataclasses.dataclass(frozen=True)
class AgentArrived(AgentEvent):
    pass


@dataclasses.dataclass(frozen=True)
class RequestAdmitted(AgentEvent):
    rid: int


@dataclasses.dataclass(frozen=True)
class RequestSwappedOut(AgentEvent):
    rid: int


@dataclasses.dataclass(frozen=True)
class RequestSwappedIn(AgentEvent):
    rid: int


@dataclasses.dataclass(frozen=True)
class TokenGenerated(AgentEvent):
    rid: int
    token: int


@dataclasses.dataclass(frozen=True)
class StageCompleted(AgentEvent):
    stage: int


@dataclasses.dataclass(frozen=True)
class AgentCompleted(AgentEvent):
    jct: float


Hook = Optional[Callable[[AgentEvent], None]]


@dataclasses.dataclass
class AgentHooks:
    """Per-agent lifecycle callbacks, each invoked with the typed event.

    Any subset may be set; ``on_swap`` fires for both swap-out and swap-in
    (inspect the event type to distinguish).  ``on_token`` only fires on the
    engine backend.
    """

    on_admit: Hook = None
    on_swap: Hook = None
    on_stage_complete: Hook = None
    on_complete: Hook = None
    on_token: Hook = None
