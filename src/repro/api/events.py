"""Typed lifecycle events streamed by :class:`repro.api.AgentService`.

Both backends (the discrete-event simulator and the real JAX engine) emit
the same duck-typed callbacks; the service's dispatcher normalizes them into
these frozen dataclasses with ``time`` in *workload seconds* regardless of
the backend's native clock (the engine counts iterations internally).

``TokenGenerated`` is backend-uniform: the engine streams its actually
sampled token ids, and the simulator (with ``token_events=True`` on
``SimBackend``/``ClusterSim``) streams the discretized token boundaries
its closed-form decode implies, stamped at the exact boundary-crossing
instants, with the 0-based token index as the ``token`` value.  The
per-agent event order and the per-request token *counts* are identical
across backends (pinned by ``tests/test_event_conformance.py``); only the
token values differ (the sim samples none).

Every event carries a ``replica`` index when served through a
:class:`repro.api.ReplicatedBackend` (``None`` on single-backend services):
the fleet dispatcher tags each child backend's callbacks with the replica
that emitted them, so per-replica metrics fall out of the same stream.

``StageOutcome`` is the view handed to a closed-loop
:class:`repro.api.AgentSpec`'s ``next_stage`` callback after each stage
completes — see ``repro.api.service``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class AgentEvent:
    agent_id: int
    time: float
    #: which replica of a ReplicatedBackend served this (None: unreplicated)
    replica: Optional[int] = dataclasses.field(default=None, kw_only=True)


@dataclasses.dataclass(frozen=True)
class AgentArrived(AgentEvent):
    pass


@dataclasses.dataclass(frozen=True)
class RequestAdmitted(AgentEvent):
    rid: int


@dataclasses.dataclass(frozen=True)
class RequestSwappedOut(AgentEvent):
    rid: int


@dataclasses.dataclass(frozen=True)
class RequestSwappedIn(AgentEvent):
    rid: int


@dataclasses.dataclass(frozen=True)
class TokenGenerated(AgentEvent):
    rid: int
    token: int


@dataclasses.dataclass(frozen=True)
class PrefixHit(AgentEvent):
    """One admission reused ``cached`` of its ``prefill`` prompt tokens
    from the prefix cache (backend-native token scale; emitted only when
    the backend was built with ``prefix_cache=True`` and the hit is
    non-zero).  The engine reports the exact full-block match its
    allocator found; the simulator reports its analytic model's hit —
    identical by construction when prompts are block-aligned (pinned by
    the sim-vs-engine hit-fraction equivalence test)."""

    rid: int
    cached: int
    prefill: int


@dataclasses.dataclass(frozen=True)
class StageCompleted(AgentEvent):
    stage: int


@dataclasses.dataclass(frozen=True)
class AgentCompleted(AgentEvent):
    jct: float


@dataclasses.dataclass(frozen=True)
class ReplicaFailed(AgentEvent):
    """A fleet child was declared DEAD (``replica`` names it).  Fleet-
    scoped: emitted with ``agent_id=-1`` — no per-agent handle records it,
    but the service recorder counts it and listeners see it in-stream.
    ``reason`` distinguishes a planned crash from a watchdog timeout."""

    reason: str = ""


@dataclasses.dataclass(frozen=True)
class ReplicaRecovered(AgentEvent):
    """A child previously suspected stalled resumed progress before its
    watchdog budget ran out (fleet-scoped, ``agent_id=-1``)."""


@dataclasses.dataclass(frozen=True)
class AgentRequeued(AgentEvent):
    """The agent's remaining stages were failed over from a dead replica
    (``from_replica``) to a surviving one (``replica``).  Resets the
    agent's per-replica admit/swap chain in the conformance grammar; its
    accrued global virtual time carries over unchanged."""

    from_replica: int = -1


@dataclasses.dataclass(frozen=True)
class AgentSuspended(AgentEvent):
    """The agent entered think time after completing ``stage`` (a closed-
    loop ``resume_delay``): it holds no decode slot until ``until``
    (workload seconds), and its KV sits under the backend's
    ``suspend_retention`` policy (``hold``/``spill``/``drop``).  Between
    this event and the matching :class:`AgentResumed`, the agent admits
    nothing; a fleet may close the suspension with an
    :class:`AgentRequeued` instead when the suspending replica dies."""

    stage: int = -1
    until: float = 0.0


@dataclasses.dataclass(frozen=True)
class AgentResumed(AgentEvent):
    """Think time ended: the agent's next stage was (re-)submitted.
    Exactly one per :class:`AgentSuspended`, on the same replica — or at
    requeue time (old replica) when the suspension is closed by a
    failover migration."""


@dataclasses.dataclass(frozen=True)
class AdmissionDeferred(AgentEvent):
    """Watermark admission control held request ``rid`` back because
    occupancy sat above the high watermark (emitted at most once per
    request; the eventual ``RequestAdmitted`` follows once occupancy
    drains below the low watermark)."""

    rid: int = -1


@dataclasses.dataclass
class StageOutcome:
    """What a closed-loop ``AgentSpec.next_stage`` callback is fed.

    ``stage`` is the 0-based index of the stage that just completed;
    ``time`` its completion in workload seconds; ``new_tokens`` the number
    of ``TokenGenerated`` events observed for the agent since the previous
    stage boundary (0 when the backend does not stream tokens — sim with
    ``token_events=False``); ``handle`` the live :class:`AgentHandle`
    (events/tokens are retained on it when the service records events).

    ``new_tokens`` is in the backend's NATIVE token scale: full workload
    tokens on the sim, engine tokens (demand / ``token_scale``) on the
    engine.  A session whose control flow branches on it will therefore
    unfold differently across backends — the stock closed-loop families
    deliberately key only on their own turn counters (see ROADMAP
    "closed-loop clients"), which is what the cross-backend turn-count
    conformance pin relies on.

    The callback returns the next stage's ``InferenceSpec`` list, or
    ``None``/empty to let the agent complete.  It runs synchronously
    inside the backend's event loop and MUST NOT call ``run``/``drain``
    on the service (enforced) or submit new agents.
    """

    agent_id: int
    stage: int
    time: float
    new_tokens: int
    handle: Any


Hook = Optional[Callable[[AgentEvent], None]]


@dataclasses.dataclass
class AgentHooks:
    """Per-agent lifecycle callbacks, each invoked with the typed event.

    Any subset may be set; ``on_swap`` fires for both swap-out and swap-in
    (inspect the event type to distinguish).  ``on_token`` fires on the
    engine backend always and on the sim backend when it was built with
    ``token_events=True``.
    """

    on_admit: Hook = None
    on_swap: Hook = None
    on_stage_complete: Hook = None
    on_complete: Hook = None
    on_token: Hook = None
    #: fires on prefix-cache hits (backends built with ``prefix_cache=True``)
    on_prefix_hit: Hook = None
    #: fires when the agent is failed over to a surviving replica
    on_requeued: Hook = None
    #: fires when the agent enters think time (closed-loop ``resume_delay``)
    on_suspend: Hook = None
    #: fires when think time ends and the next stage is submitted
    on_resume: Hook = None
    #: fires when watermark admission control defers one of the agent's
    #: requests (backends built with ``admission_watermark=...``)
    on_defer: Hook = None
