"""Shared workload/service builders for the CLI launchers and examples.

Both ``repro.launch.serve`` and ``examples/serve_agents.py`` stream the
paper's sampled agent classes into an :class:`AgentService` with bursty
(Mooncake-like) arrival times; the spec construction and the sim-vs-engine
service wiring live here so calibration constants exist in exactly one
place.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.api.backend import AgentSpec
from repro.api.service import AgentService
from repro.workloads import (
    CLOSED_LOOP_CLASSES,
    mooncake_like_arrivals,
    sample_agent,
    sample_closed_loop,
)

#: default small-agent mix used by the CLI drivers
DEFAULT_CLASSES = ("EV", "FV", "CC", "KBQAV")

#: default closed-loop session mix (multi-turn chat + react tool loops).
#: Think-time-heavy families are EXCLUDED from the default: they suspend
#: agents mid-run, which would silently change every CLI/benchmark run
#: that relies on the default mix — opt in with ``--closed-loop-classes``
#: or an explicit ``classes=`` list (e.g. ``("tooluse",)``)
DEFAULT_CLOSED_LOOP = tuple(
    name for name, c in CLOSED_LOOP_CLASSES.items() if c.think[1] <= 0.0
)

#: engine serves token demands divided by this (predicted costs by its
#: square, since KV token-time is ~quadratic in token counts)
DEFAULT_TOKEN_SCALE = 8


def specs_from_classes(
    rng: np.random.Generator,
    n_agents: int,
    window_s: float,
    *,
    classes: Sequence[str] = DEFAULT_CLASSES,
    predictor=None,
) -> list[AgentSpec]:
    """Sample one backend-agnostic AgentSpec list with online arrivals.

    ``predictor`` (an ``AgentCostPredictor``) supplies predicted costs from
    each agent's synthetic prompt; without one, ground-truth costs are used.
    """
    arrivals = mooncake_like_arrivals(rng, n_agents, window_s)
    specs = []
    for aid in range(n_agents):
        cls = classes[aid % len(classes)]
        a = sample_agent(rng, cls)
        pred = (
            float(predictor.predict(cls, a.prompt))
            if predictor is not None
            else a.true_cost
        )
        specs.append(
            AgentSpec(
                stages=[list(s) for s in a.stages],
                arrival=float(arrivals[aid]),
                predicted_cost=pred,
                true_cost=a.true_cost,
                name=cls,
            )
        )
    return specs


def specs_from_closed_loop(
    rng: np.random.Generator,
    n_agents: int,
    window_s: float,
    *,
    classes: Sequence[str] = DEFAULT_CLOSED_LOOP,
) -> list[AgentSpec]:
    """Sample a closed-loop AgentSpec list (multi-turn chat / react loops).

    Each spec carries only its opening turn in ``stages`` plus a stateful
    ``next_stage`` session callback that generates later turns as earlier
    ones complete.  Sessions hold mutable turn state, so the list is
    SINGLE-USE: rebuild (same seed) for every serving run rather than
    resubmitting — unlike the open-loop specs, these cannot be shared
    across runs.

    Specs carry the sessions' prefix-cache metadata: canonical prompt
    token streams (``prompt_ids``), per-inference expected cached-prefix
    hints (``cached_hints``), and the family's shared system prefix
    (``prefix_group``/``shared_prefix``) — inert on cache-oblivious
    backends, exploited by ones built with ``prefix_cache=True``.
    """
    arrivals = mooncake_like_arrivals(rng, n_agents, window_s)
    specs = []
    for aid in range(n_agents):
        cls = classes[aid % len(classes)]
        session = sample_closed_loop(rng, cls)
        specs.append(
            AgentSpec(
                stages=[list(session.first_stage)],
                arrival=float(arrivals[aid]),
                predicted_cost=session.expected_cost,
                true_cost=session.expected_cost,
                name=cls,
                next_stage=session,
                prompt_ids=(
                    None
                    if session.last_prompt_ids is None
                    else [list(session.last_prompt_ids)]
                ),
                cached_hints=[list(session.last_cached_hints)],
                prefix_group=cls,
                shared_prefix=float(session.cls.sys_prefix),
            )
        )
    return specs


def service_for_backend(
    backend: str,
    scheduler: str,
    *,
    arch: str = "granite-3-2b",
    vocab: int = 512,
    pool_tokens: int = 4096,
    max_batch: int = 4,
    cache_len: int = 512,
    token_scale: int = DEFAULT_TOKEN_SCALE,
    sim_kv_factor: float = 4.0,
    decode_rate: float = 30.0,
    seed: int = 0,
    replicas: int = 1,
    router: str = "round_robin",
    stream: bool = False,
    prefix_cache: bool = False,
    fused_prefill: bool = False,
    fault_plan=None,
    watchdog_timeout: Optional[float] = None,
    watchdog_retries: Optional[int] = None,
    watchdog_backoff: Optional[float] = None,
    admission_watermark: Optional[tuple] = None,
    suspend_retention: Optional[str] = None,
    think_time_accrual: bool = True,
    fleet_workers: Optional[int] = None,
    steal_threshold: Optional[float] = None,
    steal_interval: Optional[float] = None,
) -> AgentService:
    """Build an AgentService for ``backend`` in {"sim", "engine"}.

    The sim pool is ``pool_tokens * sim_kv_factor`` KV units: the simulator
    serves full-scale token demands while the engine serves them divided by
    ``token_scale``, so its pool is proportionally wider.

    ``replicas > 1`` shards the fleet behind a
    :class:`repro.api.ReplicatedBackend` using ``router`` (a name from
    ``repro.api.router_names()``); ``pool_tokens`` stays *per replica*, so
    raising ``replicas`` adds capacity rather than splitting it.

    ``stream=True`` asks for per-token events on every backend: the engine
    always streams its sampled tokens; the sim turns on its discretized
    ``token_events`` decode model (off by default — the emission sweep
    costs O(running) per event).

    ``prefix_cache=True`` turns on prefix-aware KV reuse on both
    backends (the engine's content-hash block index / the sim's analytic
    hit model) — per-agent hit fractions and ``prefill_tokens_saved``
    land in the drained result's ``metrics``.

    ``fused_prefill=True`` (engine only; ignored by the sim, whose
    analytic prefill never stalls decoders) streams each admitted
    prompt's uncached suffix into the fused decode windows one
    ``prefill_chunk`` slice per iteration instead of charging a blocking
    whole-prefill pass at admission — the interference-aware batch
    formation path.

    ``fault_plan`` (a :class:`repro.api.FaultPlan`) plus
    ``watchdog_timeout`` arm deterministic fault injection and failover
    on the fleet — both require ``replicas > 1``; ``watchdog_retries`` /
    ``watchdog_backoff`` tune the suspect-probe schedule (backend
    defaults apply when ``None``).
    ``admission_watermark=(low, high)`` (pool fractions) turns on
    watermark admission control on every child backend.

    ``suspend_retention`` in {"hold", "spill", "drop"} picks what happens
    to a suspended agent's KV during tool-call think time (``None`` keeps
    the backend default, "hold"); ``think_time_accrual=False`` removes
    thinking agents from the fleet's GPS reference so think time accrues
    no virtual time (the default True is the paper's stance).

    ``fleet_workers > 1`` advances the fleet's children concurrently on a
    bounded thread pool (bit-identical to the sequential lockstep loop —
    see :class:`repro.api.ReplicatedBackend`); ``steal_threshold`` arms
    load-triggered work stealing of queued, never-admitted agents at
    every ``steal_interval`` workload-seconds.  All three require
    ``replicas > 1``.
    """
    fleet_kw = {}
    if fault_plan is not None:
        fleet_kw["fault_plan"] = fault_plan
    if watchdog_timeout is not None:
        fleet_kw["watchdog_timeout"] = watchdog_timeout
    if watchdog_retries is not None:
        fleet_kw["watchdog_retries"] = int(watchdog_retries)
    if watchdog_backoff is not None:
        fleet_kw["watchdog_backoff"] = float(watchdog_backoff)
    if not think_time_accrual:
        fleet_kw["think_time_accrual"] = False
    if fleet_workers is not None:
        fleet_kw["fleet_workers"] = int(fleet_workers)
    if steal_threshold is not None:
        fleet_kw["steal_threshold"] = float(steal_threshold)
    if steal_interval is not None:
        fleet_kw["steal_interval"] = float(steal_interval)
    child_kw = {}
    if suspend_retention is not None:
        child_kw["suspend_retention"] = suspend_retention
    if backend == "sim":
        return AgentService.sim(
            scheduler,
            total_kv=float(pool_tokens) * sim_kv_factor,
            decode_rate=decode_rate,
            replicas=replicas, router=router, seed=seed,
            token_events=stream,
            prefix_cache=prefix_cache,
            admission_watermark=admission_watermark,
            **child_kw,
            **fleet_kw,
        )
    if backend != "engine":
        raise ValueError(f"unknown backend {backend!r} (sim|engine)")
    import jax

    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config(arch).reduced(vocab=vocab)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return AgentService.engine(
        model, params, scheduler,
        pool_tokens=pool_tokens, max_batch=max_batch, cache_len=cache_len,
        token_scale=token_scale, time_scale=1.0,
        replicas=replicas, router=router, seed=seed,
        prefix_cache=prefix_cache, fused_prefill=fused_prefill,
        admission_watermark=admission_watermark,
        **child_kw,
        **fleet_kw,
    )
