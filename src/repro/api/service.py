"""``AgentService`` — the single serving facade over every backend.

This is how launchers, examples, benchmarks, and tests drive serving::

    service = AgentService.sim(scheduler="justitia", total_kv=16384.0)
    # or: AgentService.engine(model, params, scheduler="justitia", ...)
    for spec in workload:                      # AgentSpec, arrival in seconds
        handle = service.submit(spec)          # online: at any time
    service.run(until=30.0)                    # interleave with more submits
    result = service.drain()                   # ServiceResult

Each submission returns an :class:`AgentHandle` that streams the agent's
lifecycle (admission, swaps, per-stage completions, per-token events on the
engine backend) and accepts :class:`repro.api.events.AgentHooks` callbacks.
A :class:`MetricsRecorder` built on ``repro.sim.metrics`` aggregates JCT
statistics and event counts uniformly across backends.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

from repro.api.backend import AgentSpec, Backend, BackendResult
from repro.api.events import (
    AdmissionDeferred,
    AgentArrived,
    AgentCompleted,
    AgentEvent,
    AgentHooks,
    AgentRequeued,
    AgentResumed,
    AgentSuspended,
    PrefixHit,
    ReplicaFailed,
    ReplicaRecovered,
    RequestAdmitted,
    RequestSwappedIn,
    RequestSwappedOut,
    StageCompleted,
    StageOutcome,
    TokenGenerated,
)
from repro.sim.metrics import (
    JctStats,
    LatencyStats,
    SloStats,
    SloTier,
    fair_ratios,
    fairness_stats,
    jct_stats,
    latency_stats,
    slo_attainment,
)


@dataclasses.dataclass
class AgentHandle:
    """Live view of one submitted agent's session."""

    agent_id: int
    spec: AgentSpec
    arrival: float                      # effective arrival, workload seconds
    hooks: AgentHooks
    status: str = "pending"             # pending -> active -> done
    record_events: bool = True          # retain events/tokens on the handle
    replica: Optional[int] = None       # serving replica (replicated fleets)
    finish: Optional[float] = None
    jct: Optional[float] = None
    stage_finish: dict[int, float] = dataclasses.field(default_factory=dict)
    tokens: list[int] = dataclasses.field(default_factory=list)
    events: list[AgentEvent] = dataclasses.field(default_factory=list)
    #: tokens observed in total / at the last stage boundary — maintained
    #: even with ``record_events=False`` (closed-loop callbacks read the
    #: per-stage difference via ``StageOutcome.new_tokens``)
    token_count: int = 0
    _stage_token_mark: int = 0

    @property
    def done(self) -> bool:
        return self.status == "done"

    def _record(self, ev: AgentEvent) -> None:
        if self.record_events:
            self.events.append(ev)
        if ev.replica is not None:
            self.replica = ev.replica
        if isinstance(ev, AgentArrived):
            self.status = "active"
            self.arrival = ev.time
        elif isinstance(ev, RequestAdmitted):
            if self.hooks.on_admit:
                self.hooks.on_admit(ev)
        elif isinstance(ev, (RequestSwappedOut, RequestSwappedIn)):
            if self.hooks.on_swap:
                self.hooks.on_swap(ev)
        elif isinstance(ev, PrefixHit):
            if self.hooks.on_prefix_hit:
                self.hooks.on_prefix_hit(ev)
        elif isinstance(ev, AgentRequeued):
            if self.hooks.on_requeued:
                self.hooks.on_requeued(ev)
        elif isinstance(ev, AgentSuspended):
            if self.hooks.on_suspend:
                self.hooks.on_suspend(ev)
        elif isinstance(ev, AgentResumed):
            if self.hooks.on_resume:
                self.hooks.on_resume(ev)
        elif isinstance(ev, AdmissionDeferred):
            if self.hooks.on_defer:
                self.hooks.on_defer(ev)
        elif isinstance(ev, TokenGenerated):
            self.token_count += 1
            if self.record_events:
                self.tokens.append(ev.token)
            if self.hooks.on_token:
                self.hooks.on_token(ev)
        elif isinstance(ev, StageCompleted):
            self.stage_finish[ev.stage] = ev.time
            if self.hooks.on_stage_complete:
                self.hooks.on_stage_complete(ev)
        elif isinstance(ev, AgentCompleted):
            self.status = "done"
            self.finish = ev.time
            self.jct = ev.jct
            if self.hooks.on_complete:
                self.hooks.on_complete(ev)


class MetricsRecorder:
    """Uniform serving metrics across backends (on ``repro.sim.metrics``).

    Events served through a replicated fleet carry a ``replica`` index;
    the recorder aggregates both fleet-level JCTs (``jct``/``jct_stats``)
    and per-replica JCTs (``replica_jct``/``per_replica_jct_stats``) from
    the same stream.
    """

    def __init__(self) -> None:
        self.jct: dict[int, float] = {}
        self.finish: dict[int, float] = {}
        self.event_counts: dict[str, int] = {}
        self.replica_jct: dict[int, dict[int, float]] = {}
        # latency accounting (PR 7), fed by the streamed token events —
        # both backends stamp them in workload seconds, so TTFT/TBT fall
        # out of the same stream on either
        self.arrival: dict[int, float] = {}
        self.first_token: dict[int, float] = {}       # agent -> time
        self.last_token: dict[int, float] = {}
        #: per-request token spans, keyed (replica, rid) — rids are only
        #: unique per child backend in a replicated fleet
        self._req_first: dict = {}
        self._req_last: dict = {}
        self._req_count: dict = {}
        self._req_agent: dict = {}

    def record(self, ev: AgentEvent) -> None:
        kind = type(ev).__name__
        self.event_counts[kind] = self.event_counts.get(kind, 0) + 1
        if isinstance(ev, AgentArrived):
            self.arrival[ev.agent_id] = ev.time
        elif isinstance(ev, TokenGenerated):
            aid = ev.agent_id
            self.first_token.setdefault(aid, ev.time)
            self.last_token[aid] = ev.time
            key = (ev.replica, ev.rid)
            self._req_first.setdefault(key, ev.time)
            self._req_last[key] = ev.time
            self._req_count[key] = self._req_count.get(key, 0) + 1
            self._req_agent[key] = aid
        elif isinstance(ev, AgentCompleted):
            self.jct[ev.agent_id] = ev.jct
            self.finish[ev.agent_id] = ev.time
            if ev.replica is not None:
                self.replica_jct.setdefault(ev.replica, {})[
                    ev.agent_id
                ] = ev.jct

    def ttfts(self) -> dict[int, float]:
        """Per-agent TTFT: arrival -> first streamed token (any request).

        Queueing-inclusive — the latency the agent's user experiences,
        which is where admission-stall interference shows up.  Empty
        without token streaming.
        """
        return {
            aid: t - self.arrival.get(aid, 0.0)
            for aid, t in self.first_token.items()
        }

    def tbts(self) -> dict[int, float]:
        """Per-agent mean time-between-tokens, pooled over the agent's
        requests (``sum(span) / sum(tokens - 1)``): cross-stage queueing
        and prefill gaps are excluded, so this is pure decode cadence.
        Agents whose requests all decoded a single token have no sample.
        """
        span: dict[int, float] = {}
        gaps: dict[int, int] = {}
        for key, n in self._req_count.items():
            if n < 2:
                continue
            aid = self._req_agent[key]
            span[aid] = span.get(aid, 0.0) + (
                self._req_last[key] - self._req_first[key]
            )
            gaps[aid] = gaps.get(aid, 0) + (n - 1)
        return {aid: span[aid] / gaps[aid] for aid in span}

    def latency_stats(self) -> LatencyStats:
        return latency_stats(self.ttfts(), self.tbts())

    def slo_stats(self, tiers: "dict[int, SloTier]") -> SloStats:
        """SLO attainment for the given agent -> tier assignment."""
        return slo_attainment(self.ttfts(), self.tbts(), tiers)

    def jct_stats(self) -> JctStats:
        return jct_stats(self.jct)

    def per_replica_jct_stats(self) -> dict[int, JctStats]:
        """Per-replica JCT aggregates (empty for unreplicated backends)."""
        return {
            r: jct_stats(jcts)
            for r, jcts in sorted(self.replica_jct.items())
        }

    def fairness_vs(self, reference_jct: dict[int, float]):
        """Finish-time fair ratios against a reference run (paper §5.1)."""
        return fairness_stats(fair_ratios(self.jct, reference_jct))


@dataclasses.dataclass
class ServiceResult:
    """What ``drain`` returns: per-agent outcomes + aggregate stats."""

    finish: dict[int, float]
    jct: dict[int, float]
    stats: JctStats
    makespan: float
    swaps: int
    sched_decisions: int
    sched_time: float
    backend: str
    metrics: dict
    event_counts: dict
    #: replica -> JctStats when served by a replicated fleet (else empty)
    per_replica: dict = dataclasses.field(default_factory=dict)
    #: TTFT/TBT percentiles from the streamed token events (all-zero
    #: unless the service streamed tokens — engine default, sim
    #: ``token_events=True``)
    latency: Optional[LatencyStats] = None


class _Dispatcher:
    """Translates backend-native callbacks into typed workload-time events.

    A :class:`repro.api.ReplicatedBackend` forwards its children's callbacks
    with a ``replica=k`` keyword (and pre-converted workload timestamps, so
    its ``to_workload_time`` is the identity); unreplicated backends omit it.
    """

    def __init__(self, service: "AgentService") -> None:
        self.svc = service

    def _push(self, agent_id: int, ev: AgentEvent) -> None:
        self.svc.recorder.record(ev)
        handle = self.svc.handles.get(agent_id)
        if handle is not None:
            handle._record(ev)

    def _t(self, t: float) -> float:
        return self.svc.backend.to_workload_time(t)

    def on_arrival(
        self, agent_id: int, t: float, *, replica: Optional[int] = None
    ) -> None:
        self._push(agent_id, AgentArrived(agent_id, self._t(t),
                                          replica=replica))

    def on_admit(
        self, agent_id: int, rid: int, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        self._push(agent_id, RequestAdmitted(agent_id, self._t(t), rid,
                                             replica=replica))

    def on_swap_out(
        self, agent_id: int, rid: int, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        self._push(agent_id, RequestSwappedOut(agent_id, self._t(t), rid,
                                               replica=replica))

    def on_swap_in(
        self, agent_id: int, rid: int, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        self._push(agent_id, RequestSwappedIn(agent_id, self._t(t), rid,
                                              replica=replica))

    def on_token(
        self, agent_id: int, rid: int, token: int, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        self._push(agent_id, TokenGenerated(agent_id, self._t(t), rid, token,
                                            replica=replica))

    def on_prefix_hit(
        self, agent_id: int, rid: int, cached: int, prefill: int, t: float,
        *, replica: Optional[int] = None,
    ) -> None:
        self._push(
            agent_id,
            PrefixHit(agent_id, self._t(t), rid, cached, prefill,
                      replica=replica),
        )

    def on_stage_complete(
        self, agent_id: int, stage: int, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        ev = StageCompleted(agent_id, self._t(t), stage, replica=replica)
        self._push(agent_id, ev)
        # closed-loop continuation: runs INSIDE the backend's emit, which
        # precedes its stage-exhaustion check — an appended stage keeps
        # the agent alive in the same event/iteration
        self.svc._advance_closed_loop(ev)

    def on_closed_loop_stage(
        self, agent_id: int, stage: int, new_tokens: int, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        """In-band closed-loop advancement during a concurrent fleet slice.

        The fleet calls this from the serving child's worker thread
        (serialized under its ``_cl_lock``) so the session can append the
        next stage before the child's stage-exhaustion check; the
        corresponding ``on_stage_complete`` arrives later, at buffer
        replay, and must NOT re-run the session — the service records the
        (agent, stage) pair to suppress it.  No event is pushed here: the
        replayed ``StageCompleted`` is the one canonical record, keeping
        the event stream bit-identical to sequential advancement.
        """
        self.svc._advance_closed_loop_inband(
            agent_id, stage, new_tokens, self._t(t)
        )

    def on_agent_complete(
        self, agent_id: int, t: float, *, replica: Optional[int] = None
    ) -> None:
        tw = self._t(t)
        handle = self.svc.handles.get(agent_id)
        arrival = handle.arrival if handle is not None else 0.0
        self._push(agent_id, AgentCompleted(agent_id, tw, tw - arrival,
                                            replica=replica))

    # fault-tolerance events (PR 8).  Replica-scoped events arrive with
    # agent_id=-1: no handle records them, but the recorder's per-type
    # counts and any raw-listener consumer still see them in-stream.

    def on_replica_failed(
        self, agent_id: int, reason: str, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        self._push(agent_id, ReplicaFailed(agent_id, self._t(t),
                                           reason, replica=replica))

    def on_replica_recovered(
        self, agent_id: int, t: float, *, replica: Optional[int] = None
    ) -> None:
        self._push(agent_id, ReplicaRecovered(agent_id, self._t(t),
                                              replica=replica))

    def on_requeued(
        self, agent_id: int, from_replica: int, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        self._push(agent_id, AgentRequeued(agent_id, self._t(t),
                                           from_replica, replica=replica))

    # suspension events (PR 9): closed-loop think time between stages.
    # ``until`` is a timestamp too — the fleet channel pre-converts it
    # alongside ``t``, so ``self._t`` is the identity there and the real
    # conversion on unreplicated backends.

    def on_suspend(
        self, agent_id: int, stage: int, until: float, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        self._push(
            agent_id,
            AgentSuspended(agent_id, self._t(t), stage, self._t(until),
                           replica=replica),
        )

    def on_resume(
        self, agent_id: int, t: float, *, replica: Optional[int] = None
    ) -> None:
        self._push(agent_id, AgentResumed(agent_id, self._t(t),
                                          replica=replica))

    def on_admission_deferred(
        self, agent_id: int, rid: int, t: float, *,
        replica: Optional[int] = None,
    ) -> None:
        self._push(agent_id, AdmissionDeferred(agent_id, self._t(t), rid,
                                               replica=replica))


class AgentService:
    """Backend-agnostic serving facade (see module docstring)."""

    def __init__(self, backend: Backend, *, record_events: bool = True):
        """``record_events=False`` keeps only aggregate counts and JCTs —
        per-event objects are not retained on the handles, which matters
        for paper-scale benchmark sweeps (thousands of admissions/tokens).
        Hooks and status/stage bookkeeping still work either way."""
        self.backend = backend
        self.handles: dict[int, AgentHandle] = {}
        self.recorder = MetricsRecorder()
        self.record_events = record_events
        self._next_id = 0
        self._in_callback = False    # closed-loop re-entrancy guard
        # (agent_id, stage) pairs whose session already ran in-band
        # during a concurrent fleet slice; the replayed StageCompleted
        # consumes its pair instead of re-running the session
        self._cl_done: set = set()
        backend.set_listener(_Dispatcher(self))

    # ------------------------------------------------------- constructors

    #: ReplicatedBackend-level kwargs peeled off ``**kw`` by the ``sim`` /
    #: ``engine`` constructors (everything else goes to the child backends)
    _FLEET_KW = (
        "fault_plan", "watchdog_timeout", "watchdog_retries",
        "watchdog_backoff", "think_time_accrual", "fleet_workers",
        "steal_threshold", "steal_interval", "retain_agents",
    )

    @classmethod
    def sim(
        cls, scheduler: str = "justitia", *, record_events: bool = True,
        replicas: int = 1, router: str = "round_robin", seed: int = 0, **kw
    ) -> "AgentService":
        """Service over the discrete-event simulator (paper-scale runs).

        ``replicas > 1`` builds a fleet of identical ``SimBackend`` children
        behind a :class:`ReplicatedBackend`, sharding agents via ``router``
        (each replica gets its own scheduler instance and the full ``**kw``
        pool — pass per-replica capacity, not fleet capacity).  Fleet-level
        fault-tolerance kwargs (``fault_plan`` / ``watchdog_*``) go to the
        :class:`ReplicatedBackend`, the rest to the children.
        """
        from repro.api.backend import SimBackend

        fleet_kw = {k: kw.pop(k) for k in cls._FLEET_KW if k in kw}

        def make():
            return SimBackend(scheduler, **kw)

        return cls._maybe_replicated(
            make, replicas, router, seed, record_events, fleet_kw
        )

    @classmethod
    def engine(
        cls, model, params, scheduler: str = "justitia", *,
        record_events: bool = True, replicas: int = 1,
        router: str = "round_robin", seed: int = 0, **kw
    ) -> "AgentService":
        """Service over the real JAX continuous-batching engine.

        ``replicas > 1`` builds N engines (sharing ``model``/``params`` but
        each with its own KV pool, batch slots, and scheduler) behind a
        :class:`ReplicatedBackend`; replica k synthesizes prompts from
        ``seed + k`` so fleets are deterministic but decorrelated.
        Fleet-level fault-tolerance kwargs (``fault_plan`` / ``watchdog_*``)
        go to the :class:`ReplicatedBackend`, the rest to the children.
        """
        from repro.api.backend import EngineBackend

        fleet_kw = {k: kw.pop(k) for k in cls._FLEET_KW if k in kw}
        counter = iter(range(replicas if replicas > 1 else 1))

        def make():
            return EngineBackend(
                model, params, scheduler, seed=seed + next(counter), **kw
            )

        return cls._maybe_replicated(
            make, replicas, router, seed, record_events, fleet_kw
        )

    @classmethod
    def replicated(
        cls, children, *, router: str = "round_robin", seed: int = 0,
        record_events: bool = True, **fleet_kw
    ) -> "AgentService":
        """Service over an explicit fleet (any mix of backend types).

        ``**fleet_kw`` forwards fault-tolerance knobs (``fault_plan``,
        ``watchdog_timeout``/``watchdog_retries``/``watchdog_backoff``) to
        the :class:`ReplicatedBackend`.
        """
        from repro.api.replicated import ReplicatedBackend

        return cls(
            ReplicatedBackend(children, router=router, seed=seed,
                              **fleet_kw),
            record_events=record_events,
        )

    @classmethod
    def _maybe_replicated(
        cls, make_child, replicas: int, router: str, seed: int,
        record_events: bool, fleet_kw: Optional[dict] = None,
    ) -> "AgentService":
        if replicas <= 1:
            if fleet_kw:
                raise ValueError(
                    f"{sorted(fleet_kw)} require a replicated fleet — "
                    f"pass replicas > 1"
                )
            return cls(make_child(), record_events=record_events)
        from repro.api.replicated import ReplicatedBackend

        children = [make_child() for _ in range(replicas)]
        return cls(
            ReplicatedBackend(children, router=router, seed=seed,
                              **(fleet_kw or {})),
            record_events=record_events,
        )

    # --------------------------------------------------------- lifecycle

    @property
    def now(self) -> float:
        return self.backend.now

    def submit(
        self, spec: AgentSpec, *, hooks: Optional[AgentHooks] = None
    ) -> AgentHandle:
        """Submit one agent; arrival is ``max(spec.arrival, now)``.

        May be called at any point — before, between, or after ``run``
        calls — on both backends (online arrivals).
        """
        if self._in_callback:
            raise RuntimeError(
                "closed-loop stage callbacks must not submit new agents — "
                "see ROADMAP 'closed-loop clients'"
            )
        agent_id = self._next_id
        self._next_id += 1
        # register the handle BEFORE the backend sees the spec: an agent
        # arriving at or before `now` is released inside submit() and its
        # AgentArrived event must find the handle
        handle = AgentHandle(
            agent_id=agent_id,
            spec=spec,
            arrival=float(spec.arrival),
            hooks=hooks or AgentHooks(),
            record_events=self.record_events,
        )
        self.handles[agent_id] = handle
        try:
            arrival = self.backend.submit(spec, agent_id)
        except Exception:
            del self.handles[agent_id]
            raise
        if handle.status == "pending":   # arrival lies in the future
            handle.arrival = arrival
        return handle

    def submit_many(
        self, specs: Iterable[AgentSpec]
    ) -> list[AgentHandle]:
        return [self.submit(s) for s in specs]

    def _advance_closed_loop(self, ev: StageCompleted) -> None:
        """Feed a completed stage to the agent's ``next_stage`` callback
        and submit whatever it returns as the agent's next stage."""
        handle = self.handles.get(ev.agent_id)
        if handle is None or handle.spec.next_stage is None:
            return
        if (ev.agent_id, ev.stage) in self._cl_done:
            # the session already ran in-band during the concurrent slice;
            # re-sync the token mark now that the replayed token events
            # have landed on the handle, exactly where the sequential path
            # would have set it
            self._cl_done.discard((ev.agent_id, ev.stage))
            handle._stage_token_mark = handle.token_count
            return
        outcome = StageOutcome(
            agent_id=ev.agent_id,
            stage=ev.stage,
            time=ev.time,
            new_tokens=handle.token_count - handle._stage_token_mark,
            handle=handle,
        )
        handle._stage_token_mark = handle.token_count
        self._in_callback = True
        try:
            specs = handle.spec.next_stage(outcome)
        finally:
            self._in_callback = False
        if specs:
            # sessions that pin canonical prompt streams / cached-prefix
            # hints for the stage they just returned expose them as
            # ``last_prompt_ids`` / ``last_cached_hints`` (the stock
            # closed-loop families do; plain callables simply don't)
            session = handle.spec.next_stage
            self.backend.submit_stage(
                ev.agent_id,
                list(specs),
                prompt_ids=getattr(session, "last_prompt_ids", None),
                hints=getattr(session, "last_cached_hints", None),
                resume_delay=getattr(session, "last_resume_delay", None),
            )

    def _advance_closed_loop_inband(
        self, agent_id: int, stage: int, new_tokens: int, t: float
    ) -> None:
        """Concurrent-slice twin of :meth:`_advance_closed_loop` (see
        :meth:`_Dispatcher.on_closed_loop_stage`): runs the session with
        the fleet-counted token delta (the handle's counts lag until the
        buffer replay) and records the pair for replay suppression."""
        handle = self.handles.get(agent_id)
        if handle is None or handle.spec.next_stage is None:
            return
        self._cl_done.add((agent_id, stage))
        outcome = StageOutcome(
            agent_id=agent_id,
            stage=stage,
            time=t,
            new_tokens=int(new_tokens),
            handle=handle,
        )
        self._in_callback = True
        try:
            specs = handle.spec.next_stage(outcome)
        finally:
            self._in_callback = False
        if specs:
            session = handle.spec.next_stage
            self.backend.submit_stage(
                agent_id,
                list(specs),
                prompt_ids=getattr(session, "last_prompt_ids", None),
                hints=getattr(session, "last_cached_hints", None),
                resume_delay=getattr(session, "last_resume_delay", None),
            )

    def run(self, until: float) -> None:
        """Advance serving time to ``until`` (workload seconds)."""
        if self._in_callback:
            raise RuntimeError(
                "closed-loop stage callbacks must not call run() — see "
                "ROADMAP 'closed-loop clients'"
            )
        self.backend.run(until)

    def drain(self) -> ServiceResult:
        """Serve everything submitted so far to completion."""
        if self._in_callback:
            raise RuntimeError(
                "closed-loop stage callbacks must not call drain() — see "
                "ROADMAP 'closed-loop clients'"
            )
        res: BackendResult = self.backend.drain()
        # the recorder's jct view is authoritative (it uses true arrival
        # stamps); fall back to the backend's numbers for any agent whose
        # events were not observed (e.g. a listener installed late)
        jct = dict(res.jct)
        jct.update(self.recorder.jct)
        finish = dict(res.finish)
        finish.update(self.recorder.finish)
        return ServiceResult(
            finish=finish,
            jct=jct,
            stats=jct_stats(jct),
            makespan=res.makespan,
            swaps=res.swaps,
            sched_decisions=res.sched_decisions,
            sched_time=res.sched_time,
            backend=self.backend.name,
            metrics=res.metrics,
            event_counts=dict(self.recorder.event_counts),
            per_replica=self.recorder.per_replica_jct_stats(),
            latency=self.recorder.latency_stats(),
        )
