"""Discrete-event simulator of a vLLM-style continuous-batching backend.

Models the serving semantics the paper builds on (vLLM + App. C):

  * a shared KV pool of ``total_kv`` token units (paper's M);
  * continuous batching: every running sequence decodes at ``decode_rate``
    tokens/s (per-iteration latency statistically stable — paper fn. 2);
  * prefill occupies the prompt's KV immediately at admission and takes
    ``p / prefill_rate`` seconds before decoding starts;
  * non-preemptive admission: waiting requests never preempt running ones;
  * on memory exhaustion, the running inference with the *worst* scheduler
    key is swapped out (KV to host), keeping its progress; the swapped queue
    has absolute priority for re-admission and blocks new admissions
    (exactly vLLM's recompute/swap policy, per the paper's footnote 3).

The scheduler policy objects from ``repro.core.schedulers`` are used
unmodified — the same classes drive the real JAX engine.  Time unit:
seconds; service unit: KV token-time (token·seconds scaled by decode_rate
to match the cost model's token·iterations — see ``kv_unit_scale``).

The simulator emits the same duck-typed lifecycle callbacks as the engine
(``on_arrival``, ``on_admit``, ``on_swap_out``, ``on_swap_in``,
``on_stage_complete``, ``on_agent_complete``) to an optional ``listener`` —
``repro.api`` builds its backend-agnostic event stream on these.  Per-token
events are not emitted: decoding is continuous here, not discrete.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Optional, Sequence

from repro.core.cost import InferenceSpec, MemoryFamily, inference_cost
from repro.core.schedulers import AgentScheduler, Request


@dataclasses.dataclass
class SimAgent:
    """An agent submitted to the cluster."""

    agent_id: int
    arrival: float
    stages: list[list[InferenceSpec]]           # stage -> parallel inferences
    predicted_cost: float                        # fed to the scheduler
    true_cost: float = 0.0                       # for metrics
    family: MemoryFamily = MemoryFamily.DENSE
    name: str = "agent"

    # runtime
    finish: float = float("inf")
    next_stage: int = 0
    live_inferences: int = 0


@dataclasses.dataclass
class _Running:
    req: Request
    admit_time: float
    prefill_done: float          # absolute time decoding starts
    decoded_at_last: float       # decoded tokens at last account time
    last_account: float          # time of last service accounting
    swapped: bool = False

    def occupancy(self, t: float, decode_rate: float) -> float:
        return self.req.spec.prefill + self.decoded(t, decode_rate)

    def decoded(self, t: float, decode_rate: float) -> float:
        if t <= self.prefill_done:
            return self.decoded_at_last
        return min(
            self.req.spec.decode,
            self.decoded_at_last
            + max(0.0, t - max(self.last_account, self.prefill_done)) * decode_rate,
        )

    def finish_time(self, decode_rate: float) -> float:
        rem = self.req.spec.decode - self.decoded_at_last
        return max(self.prefill_done, self.last_account) + rem / decode_rate


@dataclasses.dataclass
class SimResult:
    jct: dict[int, float]                  # agent_id -> completion - arrival
    finish: dict[int, float]               # agent_id -> absolute completion
    sched_decisions: int = 0
    sched_time: float = 0.0                # wall-clock spent in scheduler code
    swaps: int = 0
    makespan: float = 0.0


class ClusterSim:
    def __init__(
        self,
        scheduler: AgentScheduler,
        total_kv: float,
        decode_rate: float = 30.0,       # tokens/s per running sequence
        prefill_rate: float = 4000.0,    # prompt tokens/s
        swap_penalty: float = 0.2,       # seconds added on re-admission
        listener: Any = None,
    ):
        self.sched = scheduler
        self.m = float(total_kv)
        self.decode_rate = float(decode_rate)
        self.prefill_rate = float(prefill_rate)
        self.swap_penalty = float(swap_penalty)
        self.listener = listener

    def _emit(self, event: str, *args) -> None:
        if self.listener is not None:
            fn = getattr(self.listener, event, None)
            if fn is not None:
                fn(*args)

    # ------------------------------------------------------------------ run

    def run(self, agents: Sequence[SimAgent]) -> SimResult:
        import time as _time

        agents = sorted(agents, key=lambda a: (a.arrival, a.agent_id))
        by_id = {a.agent_id: a for a in agents}
        arrivals = list(agents)
        ai = 0
        waiting: list[Request] = []
        swapped: list[_Running] = []
        running: list[_Running] = []
        rid_counter = 0
        t = 0.0
        result = SimResult(jct={}, finish={})
        _sched_clock = 0.0
        _decisions = 0

        def submit_stage(agent: SimAgent, now: float) -> None:
            nonlocal rid_counter
            specs = agent.stages[agent.next_stage]
            agent.next_stage += 1
            agent.live_inferences += len(specs)
            for spec in specs:
                waiting.append(
                    Request(
                        agent_id=agent.agent_id,
                        rid=rid_counter,
                        spec=spec,
                        submit_time=now,
                        pred_cost=inference_cost(spec, agent.family),
                    )
                )
                rid_counter += 1

        def occupancy(now: float) -> float:
            return sum(r.occupancy(now, self.decode_rate) for r in running)

        def account(now: float) -> None:
            """Credit service between last accounting point and ``now``."""
            for r in running:
                dt_total = now - r.last_account
                if dt_total <= 0:
                    continue
                # decode progress only after prefill completes
                dec_start = max(r.last_account, r.prefill_done)
                dt_dec = max(0.0, now - dec_start)
                new_decoded = min(
                    r.req.spec.decode,
                    r.decoded_at_last + dt_dec * self.decode_rate,
                )
                if r.req.spec.decode - new_decoded < 1e-6:
                    new_decoded = float(r.req.spec.decode)  # snap (float Zeno)
                d_tokens = new_decoded - r.decoded_at_last
                # KV token-time integral: occupancy dt, converted to
                # token-iterations via decode_rate (1 iteration == 1/rate s)
                occ0 = r.req.spec.prefill + r.decoded_at_last
                kv_tt = (occ0 * dt_total + 0.5 * d_tokens * dt_dec) * self.decode_rate
                self.sched.on_service(
                    r.req.agent_id,
                    kv_token_time=kv_tt,
                    decode_tokens=d_tokens,
                )
                r.decoded_at_last = new_decoded
                r.last_account = now

        def admit(now: float) -> None:
            """Admission pass: swapped queue first, then waiting (vLLM)."""
            nonlocal _sched_clock, _decisions
            # listener emits are deferred past the timed window so the
            # reported scheduler overhead measures policy code only
            deferred: list[tuple] = []
            t0 = _time.perf_counter()
            free = self.m - occupancy(now)
            # swapped queue has absolute priority and blocks new admissions
            swapped.sort(key=lambda r: self.sched.request_key(r.req, now))
            while swapped:
                r = swapped[0]
                need = r.req.spec.prefill + r.decoded_at_last
                if need <= free:
                    swapped.pop(0)
                    r.swapped = False
                    r.last_account = now
                    r.prefill_done = max(r.prefill_done, now + self.swap_penalty)
                    running.append(r)
                    free -= need
                    deferred.append(
                        ("on_swap_in", r.req.agent_id, r.req.rid, now)
                    )
                else:
                    break
            if not swapped:
                waiting.sort(key=lambda r: self.sched.request_key(r, now))
                while waiting and (
                    waiting[0].spec.prefill <= free
                    # a request larger than the whole pool would deadlock the
                    # backend; vLLM admits it alone and lets it thrash — we
                    # admit it when the pool is otherwise idle
                    or (not running and waiting[0].spec.prefill >= self.m)
                ):
                    req = waiting.pop(0)
                    pf = now + req.spec.prefill / self.prefill_rate
                    self.sched.on_service(
                        req.agent_id, prefill_tokens=req.spec.prefill
                    )
                    deferred.append(("on_admit", req.agent_id, req.rid, now))
                    running.append(
                        _Running(
                            req=req,
                            admit_time=now,
                            prefill_done=pf,
                            decoded_at_last=0.0,
                            last_account=now,
                        )
                    )
                    free -= req.spec.prefill
                    if free < 0:
                        break
            elif not running:
                # swapped head cannot fit but nothing is running: re-admit it
                # anyway (its KV footprint is what it is — vLLM would page)
                r = swapped.pop(0)
                r.swapped = False
                r.last_account = now
                r.prefill_done = max(r.prefill_done, now + self.swap_penalty)
                running.append(r)
                deferred.append(("on_swap_in", r.req.agent_id, r.req.rid, now))
            _decisions += 1
            _sched_clock += _time.perf_counter() - t0
            for ev in deferred:
                self._emit(*ev)

        def saturation_time(now: float) -> float:
            """When does pool occupancy hit M at current decode rates?

            Only sequences whose prefill has completed are growing; a
            prefill completion is itself an event (see the main loop), after
            which this is recomputed with the new rate.
            """
            occ = occupancy(now)
            free = self.m - occ
            growing = sum(
                1
                for r in running
                if r.prefill_done <= now + 1e-12
                and r.decoded(now, self.decode_rate) < r.req.spec.decode
            )
            if growing == 0:
                return float("inf")
            rate = growing * self.decode_rate
            return now + max(0.0, free) / rate

        # main event loop
        while ai < len(arrivals) or waiting or running or swapped:
            t_arr = arrivals[ai].arrival if ai < len(arrivals) else float("inf")
            t_fin = min(
                (r.finish_time(self.decode_rate) for r in running),
                default=float("inf"),
            )
            t_pref = min(
                (r.prefill_done for r in running if r.prefill_done > t + 1e-12),
                default=float("inf"),
            )
            t_sat = saturation_time(t) if running else float("inf")
            t_next = min(t_arr, t_fin, t_sat, t_pref)
            if t_next == float("inf"):
                # nothing running/finishing: only waiting items blocked by
                # swapped priority or memory — should not happen if pool can
                # fit smallest request; guard against deadlock
                if waiting or swapped:
                    raise RuntimeError(
                        "simulator deadlock: pool cannot fit pending work"
                    )
                break
            t_next = max(t_next, t)
            account(t_next)
            t = t_next

            if t_arr <= t + 1e-12 and ai < len(arrivals):
                agent = arrivals[ai]
                ai += 1
                _t0 = _time.perf_counter()
                self.sched.on_agent_arrival(
                    agent.agent_id, agent.arrival, agent.predicted_cost
                )
                _sched_clock += _time.perf_counter() - _t0
                _decisions += 1
                self._emit("on_arrival", agent.agent_id, t)
                submit_stage(agent, t)
                admit(t)
                continue

            # completions
            done = [
                r
                for r in running
                if r.decoded_at_last >= r.req.spec.decode - 1e-9
                and t >= r.prefill_done - 1e-9
            ]
            if done:
                for r in done:
                    running.remove(r)
                    agent = by_id[r.req.agent_id]
                    agent.live_inferences -= 1
                    if agent.live_inferences == 0:
                        self._emit(
                            "on_stage_complete", agent.agent_id,
                            agent.next_stage - 1, t,
                        )
                        if agent.next_stage < len(agent.stages):
                            submit_stage(agent, t)
                        else:
                            agent.finish = t
                            result.finish[agent.agent_id] = t
                            result.jct[agent.agent_id] = t - agent.arrival
                            _t0 = _time.perf_counter()
                            self.sched.on_agent_complete(agent.agent_id, t)
                            _sched_clock += _time.perf_counter() - _t0
                            self._emit(
                                "on_agent_complete", agent.agent_id, t
                            )
                admit(t)
                continue

            # saturation: swap out the worst-priority running inference
            if occupancy(t) >= self.m - 1e-6 and len(running) > 1:
                victim = max(
                    running, key=lambda r: self.sched.request_key(r.req, t)
                )
                running.remove(victim)
                victim.swapped = True
                swapped.append(victim)
                result.swaps += 1
                self._emit(
                    "on_swap_out", victim.req.agent_id, victim.req.rid, t
                )
                continue
            if occupancy(t) >= self.m - 1e-6 and len(running) <= 1:
                # single sequence saturating the pool: let it finish
                # (assume p + d < M for all workloads; see App. B assumption)
                r = running[0]
                fin = r.finish_time(self.decode_rate)
                account(fin)
                t = fin
                continue

        result.sched_decisions = _decisions
        result.sched_time = _sched_clock
        result.makespan = t
        return result
