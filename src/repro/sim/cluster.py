"""Discrete-event simulator of a vLLM-style continuous-batching backend.

Models the serving semantics the paper builds on (vLLM + App. C):

  * a shared KV pool of ``total_kv`` token units (paper's M);
  * continuous batching: every running sequence decodes at ``decode_rate``
    tokens/s (per-iteration latency statistically stable — paper fn. 2);
  * prefill occupies the prompt's KV immediately at admission and takes
    ``p / prefill_rate`` seconds before decoding starts;
  * non-preemptive admission: waiting requests never preempt running ones;
  * on memory exhaustion, the running inference with the *worst* scheduler
    key is swapped out (KV to host), keeping its progress; the swapped queue
    has absolute priority for re-admission and blocks new admissions
    (exactly vLLM's recompute/swap policy, per the paper's footnote 3).

The scheduler policy objects from ``repro.core.schedulers`` are used
unmodified — the same classes drive the real JAX engine.  Time unit:
seconds; service unit: KV token-time (token·seconds scaled by decode_rate
to match the cost model's token·iterations — see ``kv_unit_scale``).

Event-indexed core
------------------
The scheduling loop does no per-event rescans of queues or probes over the
whole running set:

  * **Calendar heaps** carry each running sequence's finish time and
    prefill boundary as ``(time, rid, version)`` entries; a state change
    (admit, swap, resume) bumps the sequence's ``version`` so stale
    entries are discarded lazily on pop — no ``min()`` probe over the
    running set ever happens.  Finish times are *cached at (re-)admission*
    and exact by construction: decode progress is the stable closed form
    ``d_base + (t - prefill_done) * decode_rate`` anchored only at
    (re-)admission, never at accounting points.
  * **Service accounting is lazy.**  A sequence is credited
    (``sched.on_service``) only when its *own* state changes — admission,
    swap out/in, finish — because the KV token-time integral over
    piecewise-linear occupancy telescopes exactly across any partition of
    the interval.  Dynamic policies (``sched.dynamic``), whose keys read
    the service counters at decision time, instead get a full refresh at
    every event — which reproduces the reference core's eager sweep
    bit-for-bit (see below).
  * **Queues are ``repro.core.OrderedQueue``** — static-key policies keep
    the waiting/swapped queues sorted by construction (one key evaluation
    per request, ever); agent-keyed dynamic policies (VTC, SRJF) use
    grouped invalidation, repositioning only the freshly-serviced agents'
    requests per admission pass.

Pool occupancy and the saturation probe remain O(running) sweeps — but
``running`` is bounded by the pool size M, not by the number of agents, so
the loop stays O(events · log n) in workload size.  The sweeps reproduce
the *exact float arithmetic* of the retained pre-rewrite core
(``repro.sim.reference.ReferenceClusterSim``, same ordered sums over the
same stable decode form): saturation and finish events frequently land
within 1e-10 of each other under contention, and both cores must order
them identically or swap decisions diverge.  The equivalence property
tests and the ``benchmarks/perf.py`` oracle pin the two cores to
identical completion orders and JCTs.

The core is *incremental*: ``submit`` registers agents online at any time,
``advance(until)`` processes events up to a horizon (so completions are
observable mid-run — the replicated fleet's load-aware routers depend on
this), and ``drain`` runs to empty.  ``run(agents)`` is the legacy one-shot
wrapper.

The simulator emits the same duck-typed lifecycle callbacks as the engine
(``on_arrival``, ``on_admit``, ``on_swap_out``, ``on_swap_in``,
``on_stage_complete``, ``on_agent_complete``) to an optional ``listener`` —
``repro.api`` builds its backend-agnostic event stream on these.

Discretized token streaming (off by default)
--------------------------------------------
Decoding is a continuous fluid rate here, but with ``token_events=True``
the simulator ALSO emits ``on_token(agent_id, rid, token, t)`` at the
instants the closed-form decode crosses integer token boundaries:
token ``k`` of a sequence is stamped ``prefill_done + (k - d_base) /
decode_rate`` from the same anchored closed form that drives every event
time, so the stream is exact and bit-identical between this core and the
frozen reference.  The emission is a pure OVERLAY: a sweep at the top of
every event trip reads the closed form and a per-sequence emitted counter
— it never touches the accounting anchors, the calendars, or the
scheduler, so completions/JCTs/swap decisions are bit-identical with the
flag on or off (``tests/test_sim_equivalence.py`` pins this).  Token
"values" are the 0-based index within the request (the sim samples no
real tokens).  Tokens are emitted at event times — between events the
stream is quiet and catches up at the next trip; each trip's batch is
emitted time-sorted and the sweep runs before any of the trip's own
emits, so the stream is timestamp-monotone per agent and globally.  The
sweep is O(running + tokens) per event, which is why it is gated off by
default.

Closed-loop clients
-------------------
``append_stage`` extends a live agent's stage list at any time — including
from inside an ``on_stage_complete`` listener callback, which both cores
deliberately emit BEFORE checking whether the agent has stages left, so a
callback-appended stage seamlessly continues the agent (this is what
``repro.api``'s closed-loop ``AgentSpec.next_stage`` builds on).  Listener
callbacks must NOT re-enter ``advance``/``drain`` (guarded).

Suspended agents (PR 9)
-----------------------
A closed-loop stage appended with ``resume_delay > 0`` does not submit at
the stage boundary: the agent SUSPENDS for the delay (tool-call / user
think time), holding no decode slot, and its conversation-tail KV sits
under the ``suspend_retention`` policy — ``hold`` keeps it resident and
charged against the pool, ``spill`` parks it host-side for a
``swap_penalty`` restore surcharge at resume, ``drop`` releases it
outright.  Memory pressure victimizes suspended agents BEFORE running
ones: admission fit-failures and the saturation trip escalate held KV
hold→spill one agent at a time (oldest first) and only swap a running
sequence when nothing is held.  Strictly flag-gated: with no suspensions
``_held_total`` stays 0.0 and every adjusted expression reduces to the
pre-PR-9 arithmetic bit-for-bit.  LOCKSTEP: the frozen reference core
carries the identical model.
"""

from __future__ import annotations

import dataclasses
import heapq
import time as _time
from typing import Any, Sequence

from repro.core.cost import InferenceSpec, MemoryFamily, inference_cost
from repro.core.queueing import OrderedQueue
from repro.core.schedulers import AgentScheduler, Request


@dataclasses.dataclass
class SimAgent:
    """An agent submitted to the cluster."""

    agent_id: int
    arrival: float
    stages: list[list[InferenceSpec]]           # stage -> parallel inferences
    predicted_cost: float                        # fed to the scheduler
    true_cost: float = 0.0                       # for metrics
    family: MemoryFamily = MemoryFamily.DENSE
    name: str = "agent"
    #: prefix-cache metadata (PR 6; read only with ``prefix_cache=True``):
    #: agents sharing a ``prefix_group`` share a ``shared_prefix``-token
    #: system prompt, and ``cached_hints`` (per stage, per spec) carries
    #: the expected cached conversation-history prefix of each prompt
    prefix_group: str = ""
    shared_prefix: float = 0.0
    cached_hints: Any = None
    #: per-stage think-time delays (PR 9): ``resume_delays[j]`` seconds of
    #: suspension inserted before stage ``j`` submits (``None``: never)
    resume_delays: Any = None

    # runtime
    finish: float = float("inf")
    next_stage: int = 0
    live_inferences: int = 0


@dataclasses.dataclass
class _Running:
    req: Request
    admit_time: float
    prefill_done: float          # absolute time decoding starts
    d_base: float                # decoded tokens at (re-)admission anchor
    decoded_at_last: float       # decoded tokens at last service credit
    last_account: float          # time of last service credit
    fin: float = float("inf")    # finish time, cached at (re-)admission
    swapped: bool = False
    version: int = 0             # invalidates stale calendar-heap entries
    order: int = 0               # (re-)admission sequence number
    key: Any = None              # cached static scheduler key
    tokens_emitted: int = 0      # token boundaries streamed (token_events)

    def decoded(self, t: float, decode_rate: float) -> float:
        """Stable closed form, anchored at (re-)admission only.

        Identical (bit-for-bit) to the reference core's; the snap window
        mirrors the historical accounting's float-Zeno guard.
        """
        if t <= self.prefill_done:
            d = self.d_base
        else:
            d = self.d_base + (t - self.prefill_done) * decode_rate
        cap = self.req.spec.decode
        if cap - d < 1e-6:
            return float(cap)
        return d

    def finish_time(self, decode_rate: float) -> float:
        rem = self.req.spec.decode - self.decoded_at_last
        return max(self.prefill_done, self.last_account) + rem / decode_rate


@dataclasses.dataclass
class SimResult:
    jct: dict[int, float]                  # agent_id -> completion - arrival
    finish: dict[int, float]               # agent_id -> absolute completion
    sched_decisions: int = 0
    sched_time: float = 0.0                # wall-clock spent in scheduler code
    swaps: int = 0
    makespan: float = 0.0
    events: int = 0                        # discrete events processed
    key_evals: int = 0                     # scheduler request_key invocations
    sorts: int = 0                         # queue re-sorts (dynamic policies)
    peak_occupancy: float = 0.0            # max pool occupancy observed
    # watermark admission control (populated only with
    # ``admission_watermark=(low, high)``; see ClusterSim.__init__)
    admission_deferrals: int = 0           # distinct requests ever deferred
    wm_admit_peak: float = 0.0             # max occ-after-admit (new admits)
    wm_bypass_admits: int = 0              # above-high admits on an idle pool
    # prefix-cache accounting (populated only with ``prefix_cache=True``)
    prefill_tokens_saved: float = 0.0
    agent_prefill_tokens: dict[int, float] = dataclasses.field(
        default_factory=dict
    )
    agent_hit_tokens: dict[int, float] = dataclasses.field(
        default_factory=dict
    )
    # suspension accounting (PR 9; populated only when closed-loop stages
    # carry a ``resume_delay``)
    suspensions: int = 0
    resumes: int = 0
    suspend_spills: int = 0                # hold→spill escalations + spills
    held_peak: float = 0.0                 # max KV held by suspended agents


class ClusterSim:
    def __init__(
        self,
        scheduler: AgentScheduler,
        total_kv: float,
        decode_rate: float = 30.0,       # tokens/s per running sequence
        prefill_rate: float = 4000.0,    # prompt tokens/s
        swap_penalty: float = 0.2,       # seconds added on re-admission
        listener: Any = None,
        token_events: bool = False,
        prefix_cache: bool = False,
        admission_watermark: Any = None,
        suspend_retention: str = "hold",
        retain_results: bool = True,
    ):
        self.sched = scheduler
        #: streaming mode (PR 10): with ``retain_results=False`` a
        #: completed agent's record is evicted immediately — no
        #: ``result.finish``/``result.jct`` entry, no ``_by_id`` object —
        #: so a fleet can stream millions of agents through under
        #: constant memory, consuming completions via listener events
        #: only.  Strictly flag-gated: True (the default) keeps every
        #: result dict and the drained snapshot exactly as before.
        self.retain_results = bool(retain_results)
        self.m = float(total_kv)
        self.decode_rate = float(decode_rate)
        self.prefill_rate = float(prefill_rate)
        self.swap_penalty = float(swap_penalty)
        self.listener = listener
        self.token_events = bool(token_events)
        #: analytic prefix-cache model (PR 6): an admission's prefill
        #: event is shortened by the request's modeled cache hit and only
        #: the uncached suffix is charged as prefill service.  Pool
        #: occupancy stays the full logical prompt (the engine's shared
        #: blocks dedup physically, not logically).  Strictly flag-gated:
        #: off, every expression reduces to the pre-cache arithmetic
        #: bit-for-bit.  LOCKSTEP: the frozen reference core carries the
        #: identical model (frozen-oracle invariant, like token_events).
        self.prefix_cache = bool(prefix_cache)
        self._seeded_groups: set[str] = set()
        #: watermark admission control (PR 8): ``(low_frac, high_frac)``
        #: of the pool.  While anything is running, a NEW admission that
        #: would lift occupancy above the high watermark is deferred, and
        #: once gated the gate stays shut until occupancy drains to the
        #: low watermark (hysteresis) — trading queueing delay for the
        #: swap-thrash regime.  Swapped re-admissions are never gated
        #: (they hold pool-priority state), and an idle pool bypasses the
        #: gate entirely (progress guarantee).  Strictly flag-gated: with
        #: ``None`` the admission pass is untouched bit-for-bit.
        #: LOCKSTEP: the frozen reference core carries the identical gate.
        if admission_watermark is not None:
            low, high = admission_watermark
            if not (0.0 < low <= high <= 1.0):
                raise ValueError(
                    f"admission_watermark must satisfy 0 < low <= high <= 1,"
                    f" got {admission_watermark!r}"
                )
            self._wm = (low * self.m, high * self.m)
        else:
            self._wm = None
        self._wm_gated = False
        self._wm_emitted: set[int] = set()
        #: suspended-agent KV retention (PR 9): an agent in think time
        #: (closed-loop ``resume_delay``) holds no decode slot; ``hold``
        #: keeps its conversation tail resident (charged to the pool via
        #: ``_held_total``), ``spill`` parks it host-side for a
        #: ``swap_penalty`` restore surcharge at resume, ``drop`` releases
        #: it outright.  Under pressure held KV escalates hold→spill
        #: BEFORE any running sequence is swapped.  Strictly flag-gated:
        #: with no suspensions ``_held_total`` stays 0.0 and every
        #: adjusted expression is bit-identical (``x - 0.0 == x``).
        #: LOCKSTEP: the frozen reference carries the identical model.
        if suspend_retention not in ("hold", "spill", "drop"):
            raise ValueError(
                f"suspend_retention must be 'hold', 'spill' or 'drop',"
                f" got {suspend_retention!r}"
            )
        self.suspend_retention = suspend_retention
        # pending resumes: (resume_time, seq, agent_id) min-heap
        self._resume_heap: list[tuple[float, int, int]] = []
        self._rseq = 0
        self._held: dict[int, float] = {}  # suspended aid -> resident KV
        self._held_total = 0.0
        self._spilled: set[int] = set()    # suspended aids parked host-side
        self._penalized: set[int] = set()  # spilled aids past their restore
        self._in_run = False             # re-entrancy guard (listener rule)

        # clock + result (cumulative across submit/advance/drain rounds)
        self.t = 0.0
        self.result = SimResult(jct={}, finish={})
        self._last_event_t = 0.0

        # pending arrivals: (arrival, agent_id, SimAgent) min-heap
        self._arrivals: list[tuple[float, int, SimAgent]] = []
        self._by_id: dict[int, SimAgent] = {}
        self._live_agents = 0            # submitted, not yet completed

        # queues (see repro.core.queueing); key evals are counted by the
        # key functions themselves so static caching shows up in the metric.
        # Agent-keyed dynamic policies (VTC, SRJF) use grouped invalidation:
        # only the serviced agents' requests are repositioned per pass.
        dyn = self.sched.dynamic
        self._grouped = dyn and getattr(self.sched, "agent_keyed", False)
        # agents serviced since the last admission pass (grouped mode):
        # flushed into the queues' dirty-group sets at each pass
        self._dirty_agents: set[int] = set()
        self._waiting: OrderedQueue = OrderedQueue(
            self._req_key,
            dynamic=dyn,
            group_fn=(lambda req: req.agent_id) if self._grouped else None,
        )
        self._swapped: OrderedQueue = OrderedQueue(
            self._run_key,
            dynamic=dyn,
            group_fn=(lambda r: r.req.agent_id) if self._grouped else None,
        )

        # running set (insertion == admission order, like the reference's
        # list) + calendar heaps ((time, rid, version), lazily purged)
        self._running: dict[int, _Running] = {}
        self._fin_heap: list[tuple[float, int, int]] = []
        self._pref_heap: list[tuple[float, int, int]] = []
        # completion-batch tolerance: the stable decode form snaps to the
        # cap within 1e-6 tokens (float Zeno guard) — the same window in
        # seconds bounds how far a finish entry can trail its snap
        self._fin_eps = 1e-6 / self.decode_rate

        self._rid = 0
        self._order = 0
        self._sched_clock = 0.0
        self._decisions = 0

    # ---------------------------------------------------------------- emits

    def _emit(self, event: str, *args) -> None:
        if self.listener is not None:
            fn = getattr(self.listener, event, None)
            if fn is not None:
                fn(*args)

    def _sweep_tokens(self, t: float) -> None:
        """Emit every token boundary the closed-form decode crossed by ``t``.

        Pure overlay (see module doc): reads only the anchored closed form
        and advances the per-sequence ``tokens_emitted`` counter — the
        accounting anchors, the calendars, and the scheduler are untouched,
        so dynamics with the flag on are bit-identical to the flag off.
        Runs at the top of every event trip, before any of the trip's own
        emits.  Every boundary crossed since the previous sweep lies in
        ``(prev_event, t]``, so sorting each sweep's batch by (time,
        running-set position, token index) keeps the whole stream — per
        agent and globally — timestamp-monotone even when parallel
        requests' backlogs are flushed together.  LOCKSTEP: the reference
        core carries the identical sweep (same float expressions, same
        running-set iteration order, same sort key).
        """
        rate = self.decode_rate
        batch: list[tuple[float, int, int, int, int]] = []
        for idx, r in enumerate(self._running.values()):
            d = r.decoded(t, rate)
            n = int(d + 1e-9)
            cap = int(r.req.spec.decode)
            if n > cap:
                n = cap
            k = r.tokens_emitted
            if n <= k:
                continue
            pf = r.prefill_done
            base = r.d_base
            aid, rid = r.req.agent_id, r.req.rid
            while k < n:
                k += 1
                tk = pf + (k - base) / rate
                if tk > t:          # cap-snap window: never post-date the
                    tk = t          # event that observed the boundary
                batch.append((tk, idx, k, aid, rid))
            r.tokens_emitted = n
        batch.sort(key=lambda e: e[:3])
        for tk, _, k, aid, rid in batch:
            self._emit("on_token", aid, rid, k - 1, tk)

    # ----------------------------------------------------------------- keys

    def _req_key(self, req: Request):
        self.result.key_evals += 1
        return self.sched.request_key(req, self.t)

    def _run_key(self, r: _Running):
        return self._req_key(r.req)

    # ------------------------------------------------------------ occupancy

    def _occupancy(self, t: float) -> float:
        """Pool occupancy at ``t``: the reference's ordered sum, exactly.

        O(running) — bounded by the pool size M, not by workload size.
        Saturation and finish events frequently coincide to within 1e-10
        under contention, so this must be the reference core's float
        arithmetic to the bit or the two cores order them differently.

        Internal use only: ``t`` must be the current event time (for
        dynamic policies the accounting anchors must be at ``t``, which
        every internal call site guarantees); ``occupancy_now`` is the
        anytime-safe public probe.
        """
        occ = 0.0
        if self.sched.dynamic:
            # the per-event accounting sweep keeps every anchor at the
            # current event time, so decoded_at_last IS decoded(t) —
            # bit-for-bit (refresh writes the stable form into it)
            for r in self._running.values():
                occ += r.req.spec.prefill + r.decoded_at_last
            return occ
        # inlined _Running.decoded (hot: ~2 sweeps per event)
        rate = self.decode_rate
        for r in self._running.values():
            pf = r.prefill_done
            d = r.d_base if t <= pf else r.d_base + (t - pf) * rate
            cap = r.req.spec.decode
            if cap - d < 1e-6:
                d = cap
            occ += r.req.spec.prefill + d
        return occ

    def _saturation_time(self, t: float) -> float:
        """When does pool occupancy hit M at current decode rates?

        Only sequences whose prefill has completed are growing; a prefill
        completion is itself an event (see the calendar), after which this
        is recomputed with the new rate.  Bit-exact mirror of the
        reference's probe (one sweep yields both sums).
        """
        rate = self.decode_rate
        eps = t + 1e-12
        occ = 0.0
        growing = 0
        if self.sched.dynamic:
            # anchors are at t (see _occupancy): decoded_at_last is exact
            for r in self._running.values():
                d = r.decoded_at_last
                occ += r.req.spec.prefill + d
                if r.prefill_done <= eps and d < r.req.spec.decode:
                    growing += 1
        else:
            for r in self._running.values():
                pf = r.prefill_done
                d = r.d_base if t <= pf else r.d_base + (t - pf) * rate
                cap = r.req.spec.decode
                if cap - d < 1e-6:
                    d = cap
                occ += r.req.spec.prefill + d
                if pf <= eps and d < cap:
                    growing += 1
        if growing == 0:
            return float("inf")
        return t + max(0.0, self.m - occ - self._held_total) / (
            growing * rate
        )

    # ----------------------------------------------------------- accounting

    def _credit(self, r: _Running, now: float) -> None:
        """Credit service dealt to ``r`` since its own last accounting.

        Decode totals are differences of the stable closed form, so they
        telescope exactly over any partition; the KV token-time integral
        telescopes in exact arithmetic (float association differs across
        partitions, which only dynamic policies observe — and they refresh
        on the reference's schedule, see :meth:`_refresh_all`).
        """
        dt_total = now - r.last_account
        if dt_total <= 0:
            return
        dec_start = max(r.last_account, r.prefill_done)
        dt_dec = max(0.0, now - dec_start)
        new_decoded = r.decoded(now, self.decode_rate)
        d_tokens = new_decoded - r.decoded_at_last
        occ0 = r.req.spec.prefill + r.decoded_at_last
        kv_tt = (occ0 * dt_total + 0.5 * d_tokens * dt_dec) * self.decode_rate
        self.sched.on_service(
            r.req.agent_id, kv_token_time=kv_tt, decode_tokens=d_tokens
        )
        if self._grouped:
            self._dirty_agents.add(r.req.agent_id)
        r.decoded_at_last = new_decoded
        r.last_account = now

    def _refresh_all(self, now: float) -> None:
        """Bring every running sequence's service counters current.

        Needed only for dynamic policies, whose admission keys read the
        scheduler's per-agent service counters at decision time.  This is
        the hot per-event O(running) sweep for VTC/SRJF, so the credit
        arithmetic of :meth:`_credit` is inlined — the two must stay in
        lockstep (the equivalence property tests pin both to the
        reference core).
        """
        rate = self.decode_rate
        on_service = self.sched.on_service
        dirty = self._dirty_agents
        for r in self._running.values():
            la = r.last_account
            dt_total = now - la
            if dt_total <= 0.0:
                continue
            pf = r.prefill_done
            d0 = r.decoded_at_last
            if now <= pf:
                new_decoded = r.d_base
            else:
                new_decoded = r.d_base + (now - pf) * rate
            cap = r.req.spec.decode
            if cap - new_decoded < 1e-6:
                new_decoded = float(cap)        # snap (float Zeno)
            dt_dec = now - pf if la <= pf else dt_total
            if dt_dec < 0.0:
                dt_dec = 0.0
            d_tokens = new_decoded - d0
            kv_tt = (
                (r.req.spec.prefill + d0) * dt_total
                + 0.5 * d_tokens * dt_dec
            ) * rate
            on_service(
                r.req.agent_id, kv_token_time=kv_tt, decode_tokens=d_tokens
            )
            dirty.add(r.req.agent_id)
            r.decoded_at_last = new_decoded
            r.last_account = now

    # ----------------------------------------------------- running-set ops

    def _add_running(self, r: _Running, now: float) -> None:
        r.order = self._order
        self._order += 1
        r.fin = r.finish_time(self.decode_rate)
        self._running[r.req.rid] = r
        if r.prefill_done > now + 1e-12:
            heapq.heappush(
                self._pref_heap, (r.prefill_done, r.req.rid, r.version)
            )
        heapq.heappush(self._fin_heap, (r.fin, r.req.rid, r.version))

    def _remove_running(self, r: _Running) -> None:
        del self._running[r.req.rid]
        r.version += 1

    # ------------------------------------------------------------ admission

    def _resume(self, r: _Running, now: float, deferred: list) -> None:
        r.swapped = False
        r.last_account = now
        r.prefill_done = max(r.prefill_done, now + self.swap_penalty)
        r.d_base = r.decoded_at_last
        self._add_running(r, now)
        deferred.append(("on_swap_in", r.req.agent_id, r.req.rid, now))

    # ------------------------------------------------------------ suspension

    def _suspend(self, agent: SimAgent, delay: float, now: float) -> None:
        """Park a closed-loop agent for ``delay`` seconds of think time.

        The agent holds no decode slot; under ``hold`` retention its
        conversation tail (the completed stage's last inference) stays
        resident and charged against the pool via ``_held_total``; under
        ``spill``/``drop`` nothing stays resident (spill pays the
        ``swap_penalty`` restore surcharge at resume, drop re-prefills —
        cheap when the prefix-cache model still matches the history).
        """
        aid = agent.agent_id
        stage = agent.next_stage - 1
        until = now + float(delay)
        held = 0.0
        if self.suspend_retention == "hold":
            spec = agent.stages[stage][-1]
            held = float(spec.prefill + spec.decode)
        self._held[aid] = held
        self._held_total += held
        if self.suspend_retention == "spill":
            self._spilled.add(aid)
        self._rseq += 1
        heapq.heappush(self._resume_heap, (until, self._rseq, aid))
        self.result.suspensions += 1
        if self._held_total > self.result.held_peak:
            self.result.held_peak = self._held_total
        _t0 = _time.perf_counter()
        self.sched.on_agent_suspend(aid, now)
        self._sched_clock += _time.perf_counter() - _t0
        self._emit("on_suspend", aid, stage, until, now)

    def _spill_oldest_held(self) -> float:
        """Escalate hold→spill on the oldest held agent; returns freed KV.

        Memory pressure victimizes suspended agents BEFORE running ones:
        admission fit-failures and the saturation trip call this first,
        and only when nothing is held does a running sequence get
        swapped.  The spilled agent pays the ``swap_penalty`` restore
        surcharge at resume, exactly like a swapped sequence.
        """
        for aid, held in self._held.items():
            if held > 0.0:
                self._held[aid] = 0.0
                self._held_total -= held
                self._spilled.add(aid)
                self.result.suspend_spills += 1
                return held
        return 0.0

    def _admit(self, now: float) -> None:
        """Admission pass: swapped queue first, then waiting (vLLM)."""
        # listener emits are deferred past the timed window so the
        # reported scheduler overhead measures policy code only
        deferred: list[tuple] = []
        t0 = _time.perf_counter()
        free = self.m - self._occupancy(now) - self._held_total
        # None (a policy without the version counter) => refresh falls back
        # to sorting whenever the queue is dirty-or-dynamic, always safe
        version = getattr(self.sched, "version", None)
        if self._grouped and self._dirty_agents:
            self._waiting.mark_dirty_many(self._dirty_agents)
            self._swapped.mark_dirty_many(self._dirty_agents)
            self._dirty_agents.clear()
        # swapped queue has absolute priority and blocks new admissions
        if self._swapped:
            self._swapped.refresh(version)
            while self._swapped:
                r = self._swapped.peek()
                need = r.req.spec.prefill + r.decoded_at_last
                if need > free:
                    spilled = self._spill_oldest_held()
                    if spilled > 0.0:
                        free += spilled
                        continue
                    break
                self._swapped.popleft()
                self._resume(r, now, deferred)
                free -= need
        if not self._swapped:
            self._waiting.refresh(version)
            while self._waiting:
                req = self._waiting.peek()
                # the fit check precedes admission so a pass can never push
                # occupancy past M — except for a request larger than the
                # whole pool, which would deadlock the backend; vLLM admits
                # it alone and lets it thrash, so we admit it when the pool
                # is otherwise idle
                fits = req.spec.prefill <= free
                solo_oversized = (
                    not self._running and req.spec.prefill >= self.m
                )
                if not (fits or solo_oversized):
                    spilled = self._spill_oldest_held()
                    if spilled > 0.0:
                        free += spilled
                        continue
                    break
                if self._wm is not None:
                    low, high = self._wm
                    occ_now = self.m - free
                    if self._running:
                        if self._wm_gated and occ_now <= low:
                            self._wm_gated = False
                        if (self._wm_gated
                                or occ_now + req.spec.prefill > high):
                            self._wm_gated = True
                            if req.rid not in self._wm_emitted:
                                self._wm_emitted.add(req.rid)
                                self.result.admission_deferrals += 1
                                deferred.append((
                                    "on_admission_deferred",
                                    req.agent_id, req.rid, now,
                                ))
                            break
                    elif occ_now + req.spec.prefill > high:
                        # idle-pool bypass: admit for progress even above
                        # the high watermark, but record the violation
                        self.result.wm_bypass_admits += 1
                    peak = occ_now + req.spec.prefill
                    if peak > self.result.wm_admit_peak:
                        self.result.wm_admit_peak = peak
                static_key = (
                    None if self.sched.dynamic else self._waiting.head_key()
                )
                self._waiting.popleft()
                # analytic prefix-cache hit shortens the prefill event and
                # the charged prefill service; 0.0 with the cache off, and
                # `x - 0.0 == x` bitwise for positive prefills, so the off
                # path is unchanged
                hit = self._prefix_hit(req, now, deferred)
                pf = now + (req.spec.prefill - hit) / self.prefill_rate
                self.sched.on_service(
                    req.agent_id, prefill_tokens=req.spec.prefill - hit
                )
                if self._grouped:
                    self._dirty_agents.add(req.agent_id)
                deferred.append(("on_admit", req.agent_id, req.rid, now))
                self._add_running(
                    _Running(
                        req=req,
                        admit_time=now,
                        prefill_done=pf,
                        d_base=0.0,
                        decoded_at_last=0.0,
                        last_account=now,
                        key=static_key,
                    ),
                    now,
                )
                free -= req.spec.prefill
                if free < 0:          # only reachable via solo_oversized
                    break
        elif not self._running:
            # swapped head cannot fit but nothing is running: re-admit it
            # anyway (its KV footprint is what it is — vLLM would page)
            r = self._swapped.popleft()
            self._resume(r, now, deferred)
            free -= r.req.spec.prefill + r.decoded_at_last
        self._decisions += 1
        self._sched_clock += _time.perf_counter() - t0
        # occupancy after the pass == M - remaining free (O(1) metric; the
        # tracked ``free`` already absorbed every admission's footprint)
        occ = self.m - free
        if occ > self.result.peak_occupancy:
            self.result.peak_occupancy = occ
        for ev in deferred:
            self._emit(*ev)

    def _prefix_hit(self, req: Request, now: float,
                    deferred: list) -> float:
        """Modeled cache hit for an admission (0.0 with the cache off).

        The hit is the larger of the request's conversation-history hint
        (``Request.cached_prefix``: a later turn re-sends everything the
        previous turn cached) and the agent's family-shared system prefix
        — the latter only once some agent of the group has admitted and
        seeded the cache.  Admission itself seeds the group.  The model
        is optimistic about eviction (the engine may report less under
        pool pressure) and block-oblivious (the engine rounds hits down
        to full blocks); the equivalence test sizes prompts so both
        effects vanish.
        """
        if not self.prefix_cache:
            return 0.0
        agent = self._by_id[req.agent_id]
        base = 0.0
        if agent.prefix_group and agent.prefix_group in self._seeded_groups:
            base = float(agent.shared_prefix)
        hit = max(base, float(req.cached_prefix))
        if hit > req.spec.prefill:
            hit = float(req.spec.prefill)
        if agent.prefix_group:
            self._seeded_groups.add(agent.prefix_group)
        res = self.result
        aid = req.agent_id
        res.agent_prefill_tokens[aid] = (
            res.agent_prefill_tokens.get(aid, 0.0) + req.spec.prefill
        )
        if hit > 0.0:
            res.agent_hit_tokens[aid] = (
                res.agent_hit_tokens.get(aid, 0.0) + hit
            )
            res.prefill_tokens_saved += hit
            deferred.append(
                ("on_prefix_hit", aid, req.rid, hit, float(req.spec.prefill),
                 now)
            )
        return hit

    def hit_fractions(self) -> dict[int, float]:
        """Per-agent modeled hit fraction: cached / total prefill tokens."""
        return {
            aid: self.result.agent_hit_tokens.get(aid, 0.0) / tot
            for aid, tot in self.result.agent_prefill_tokens.items()
            if tot > 0
        }

    # ------------------------------------------------------ calendar peeks

    def _peek_fin(self) -> float:
        heap = self._fin_heap
        while heap:
            t, rid, ver = heap[0]
            r = self._running.get(rid)
            if r is None or r.version != ver:
                heapq.heappop(heap)
                continue
            return t
        return float("inf")

    def _peek_pref(self) -> float:
        # mirrors the reference probe min(pf for pf > t + 1e-12): an entry
        # at or before the current instant is no longer a boundary (its
        # sequence already counts as growing in the sweeps) and is purged
        heap = self._pref_heap
        eps = self.t + 1e-12
        while heap:
            t, rid, ver = heap[0]
            r = self._running.get(rid)
            if r is None or r.version != ver or t <= eps:
                heapq.heappop(heap)
                continue
            return t
        return float("inf")

    # ------------------------------------------------------------ submission

    def submit(self, agent: SimAgent) -> float:
        """Register one agent online; arrival clamps to ``max(arrival, t)``."""
        agent.arrival = max(float(agent.arrival), self.t)
        self._by_id[agent.agent_id] = agent
        heapq.heappush(self._arrivals, (agent.arrival, agent.agent_id, agent))
        self._live_agents += 1
        return agent.arrival

    def append_stage(
        self, agent_id: int, stages: list[list[InferenceSpec]],
        hints: Any = None,
        resume_delay: float = 0.0,
    ) -> None:
        """Append follow-up stages to a live agent (closed-loop clients).

        Legal at any point before the agent completes — including from
        inside an ``on_stage_complete`` listener callback, which fires
        BEFORE the core checks for remaining stages, so an appended stage
        seamlessly continues the agent in the same event.  The callback
        must not re-enter ``advance``/``drain``.

        ``hints`` (optional, aligned with ``stages``) carries per-spec
        expected cached-prefix lengths for the prefix-cache model.
        ``resume_delay > 0`` (seconds of think time, PR 9) suspends the
        agent for that long before the FIRST appended stage submits.
        """
        agent = self._by_id.get(agent_id)
        if agent is None or agent.finish != float("inf"):
            raise ValueError(f"agent {agent_id} is not live")
        if resume_delay and resume_delay > 0.0 and stages:
            if agent.resume_delays is None:
                agent.resume_delays = [0.0] * len(agent.stages)
            while len(agent.resume_delays) < len(agent.stages):
                agent.resume_delays.append(0.0)
            agent.resume_delays.append(float(resume_delay))
            agent.resume_delays.extend([0.0] * (len(stages) - 1))
        if hints is not None:
            if agent.cached_hints is None:
                agent.cached_hints = [None] * len(agent.stages)
            while len(agent.cached_hints) < len(agent.stages):
                agent.cached_hints.append(None)
            agent.cached_hints.extend([list(h) for h in hints])
        agent.stages.extend([list(s) for s in stages])

    def _submit_stage(self, agent: SimAgent, now: float) -> None:
        specs = agent.stages[agent.next_stage]
        hints = None
        if (agent.cached_hints is not None
                and agent.next_stage < len(agent.cached_hints)):
            hints = agent.cached_hints[agent.next_stage]
        agent.next_stage += 1
        agent.live_inferences += len(specs)
        for i, spec in enumerate(specs):
            self._waiting.push(
                Request(
                    agent_id=agent.agent_id,
                    rid=self._rid,
                    spec=spec,
                    submit_time=now,
                    pred_cost=inference_cost(spec, agent.family),
                    cached_prefix=(
                        float(hints[i])
                        if hints is not None and i < len(hints) else 0.0
                    ),
                )
            )
            self._rid += 1

    def cancel(self, agent_id: int) -> bool:
        """Withdraw a never-admitted agent (fleet work stealing, PR 10).

        Legal only while NONE of the agent's requests has ever been
        admitted: either its arrival is still pending, or its whole
        opening stage sits in the waiting queue.  The withdrawal is
        silent — no completion event, no result entry — because the
        caller (the fleet) re-submits the agent elsewhere and emits the
        migration event itself.  The scheduler sees ``on_agent_cancel``
        so arrival-time registrations (records, Justitia's GPS share)
        are released.  Returns False — leaving the sim untouched — when
        the agent is unknown, completed, suspended, past its opening
        stage, or was ever admitted.
        """
        agent = self._by_id.get(agent_id)
        if agent is None or agent.finish != float("inf"):
            return False
        if agent.next_stage == 0:
            # submitted, not yet arrived: unwind silently — the scheduler
            # and listener never saw it
            for i, (_, aid, _a) in enumerate(self._arrivals):
                if aid == agent_id:
                    self._arrivals.pop(i)
                    heapq.heapify(self._arrivals)
                    del self._by_id[agent_id]
                    self._live_agents -= 1
                    return True
            return False
        if agent.next_stage != 1:
            return False         # a later stage implies admitted service
        if (
            agent_id in self._held
            or agent_id in self._spilled
            or any(aid == agent_id for _, _, aid in self._resume_heap)
        ):
            return False         # suspended (implies admitted anyway)
        if any(
            r.req.agent_id == agent_id for r in self._running.values()
        ) or any(r.req.agent_id == agent_id for r in self._swapped):
            return False
        if agent.live_inferences != len(agent.stages[0]):
            return False         # some opening request already ran
        reqs = [req for req in self._waiting if req.agent_id == agent_id]
        if len(reqs) != agent.live_inferences:
            return False
        for req in reqs:
            self._waiting.remove(req)
        del self._by_id[agent_id]
        self._live_agents -= 1
        _t0 = _time.perf_counter()
        self.sched.on_agent_cancel(agent_id, self.t)
        self._sched_clock += _time.perf_counter() - _t0
        return True

    # ------------------------------------------------------------ inspection

    @property
    def live_agents(self) -> int:
        """Agents submitted but not yet completed (in-flight load)."""
        return self._live_agents

    @property
    def busy(self) -> bool:
        return bool(
            self._arrivals or self._waiting or self._running
            or self._swapped or self._resume_heap
        )

    def occupancy_now(self) -> float:
        """Current pool occupancy in KV-token units (anytime-safe)."""
        t = self.t
        rate = self.decode_rate
        return sum(
            r.req.spec.prefill + r.decoded(t, rate)
            for r in self._running.values()
        ) + self._held_total

    # ------------------------------------------------------------- stepping

    def _step(self, until: float) -> bool:
        """Process the next event at or before ``until``; False when none.

        Event cascade mirrors the reference core: arrival > completion >
        (prefill boundary, then the saturation condition).  Within one
        event time multiple trips may fire — each processes exactly one
        arrival or one completion batch or one swap, exactly like one trip
        through the reference loop.
        """
        t_arr = self._arrivals[0][0] if self._arrivals else float("inf")
        t_res = (
            self._resume_heap[0][0] if self._resume_heap else float("inf")
        )
        t_fin = self._peek_fin()
        t_pref = self._peek_pref()
        # the saturation probe is evaluated at the LAST EVENT time, not at
        # self.t: after advance() raised the clock floor past the last
        # event the two differ, and (a) for dynamic policies the anchors
        # (valid only at the last refresh == last event) would read stale,
        # (b) re-basing the linear extrapolation at the horizon would
        # shift the probe in the last float bits.  Occupancy grows
        # linearly, so the absolute saturation time is the same from any
        # base point — and probing from the last event time keeps
        # incremental runs bit-identical to one-shot drains, regardless
        # of how often the driver polls advance().  Crediting the
        # scheduler at horizon times is never allowed for the same
        # reason: on_service partitions must depend only on true events.
        t_sat = (
            self._saturation_time(self._last_event_t)
            if self._running
            else float("inf")
        )
        t_next = min(t_arr, t_res, t_fin, t_sat, t_pref)
        if t_next == float("inf"):
            if self._waiting or self._swapped:
                raise RuntimeError(
                    "simulator deadlock: pool cannot fit pending work"
                )
            return False
        if t_next > until:
            return False
        if (
            len(self._running) == 1
            and t_arr > until
            and t_res > until
            and t_fin > until
            and t_pref > until
        ):
            # single-sequence saturation stall: the only due candidate is
            # the saturation probe, and its jump target (min(fin, next
            # arrival) — both beyond the horizon here) is unreachable this
            # advance().  Bail BEFORE mutating anything so repeated polls
            # leave the event counter, the anchors, and the dynamic
            # policies' service-credit partitions untouched.
            return False
        # clamp to the last EVENT time, not the raised clock floor: after
        # advance() lifted self.t past the last event, processing a stalled
        # event at the horizon would credit dynamic schedulers at
        # poll-dependent times; _last_event_t is exactly where a one-shot
        # drain's clock would stand (in batch runs self.t equals it here)
        t = max(t_next, self._last_event_t)
        self.t = max(self.t, t)
        self._last_event_t = t
        self.result.events += 1
        if self.token_events:
            self._sweep_tokens(t)
        if self.sched.dynamic:
            # dynamic keys (and VTC's counter lift) read the service
            # counters at decision time: replicate the reference's eager
            # per-event accounting sweep at EVERY event, so the counters
            # dynamic policies compare (often to exact ties) match the
            # reference bit-for-bit
            self._refresh_all(t)

        # -- arrivals (one per trip, like the reference loop)
        if t_arr <= t + 1e-12:
            _, _, agent = heapq.heappop(self._arrivals)
            _t0 = _time.perf_counter()
            self.sched.on_agent_arrival(
                agent.agent_id, agent.arrival, agent.predicted_cost
            )
            self._sched_clock += _time.perf_counter() - _t0
            self._decisions += 1
            self._emit("on_arrival", agent.agent_id, t)
            self._submit_stage(agent, t)
            self._admit(t)
            return True

        # -- resumes: think time ended (one per trip, like arrivals)
        if t_res <= t + 1e-12:
            _, _, aid = heapq.heappop(self._resume_heap)
            if aid in self._spilled and aid not in self._penalized:
                # spilled KV pays the swap-in restore surcharge before
                # the next stage submits — one deterministic penalty trip
                self._penalized.add(aid)
                self._rseq += 1
                heapq.heappush(
                    self._resume_heap,
                    (t + self.swap_penalty, self._rseq, aid),
                )
                return True
            held = self._held.pop(aid, 0.0)
            self._held_total -= held
            self._spilled.discard(aid)
            self._penalized.discard(aid)
            self.result.resumes += 1
            agent = self._by_id[aid]
            _t0 = _time.perf_counter()
            self.sched.on_agent_resume(aid, t)
            self._sched_clock += _time.perf_counter() - _t0
            self._emit("on_resume", aid, t)
            self._submit_stage(agent, t)
            self._admit(t)
            return True

        # -- completions: drain the finish calendar within the snap window
        if t_fin <= t + self._fin_eps:
            batch: list[_Running] = []
            while True:
                f = self._peek_fin()
                if f > t + self._fin_eps:
                    break
                _, rid, _ = heapq.heappop(self._fin_heap)
                batch.append(self._running[rid])
            batch.sort(key=lambda r: r.order)   # reference processing order
            for r in batch:
                self._credit(r, t)               # snaps decoded to the cap
                self._remove_running(r)
                agent = self._by_id[r.req.agent_id]
                agent.live_inferences -= 1
                if agent.live_inferences == 0:
                    self._emit(
                        "on_stage_complete", agent.agent_id,
                        agent.next_stage - 1, t,
                    )
                    if agent.next_stage < len(agent.stages):
                        delays = agent.resume_delays
                        delay = (
                            float(delays[agent.next_stage])
                            if delays is not None
                            and agent.next_stage < len(delays)
                            else 0.0
                        )
                        if delay > 0.0:
                            self._suspend(agent, delay, t)
                        else:
                            self._submit_stage(agent, t)
                    else:
                        agent.finish = t
                        if self.retain_results:
                            self.result.finish[agent.agent_id] = t
                            self.result.jct[agent.agent_id] = (
                                t - agent.arrival
                            )
                        self._live_agents -= 1
                        _t0 = _time.perf_counter()
                        self.sched.on_agent_complete(agent.agent_id, t)
                        self._sched_clock += _time.perf_counter() - _t0
                        self._emit("on_agent_complete", agent.agent_id, t)
                        if not self.retain_results:
                            # streaming mode: evict the completed agent
                            # (its live_inferences hit 0, so no other
                            # request in this batch can re-read it)
                            del self._by_id[agent.agent_id]
            self._admit(t)
            return True

        # (prefill boundaries are pure time barriers: the decode closed
        # form needs no transition — the event only exists so the
        # saturation probe is recomputed with the new growth rate.  The
        # entry that triggered this trip is purged by the next _peek_pref.)

        # -- saturation: swap out the worst-priority running inference
        occ_sat = (
            self._occupancy(t) + self._held_total if self._running else 0.0
        )
        if occ_sat >= self.m - 1e-6 and self._running:
            if self._held_total > 0.0:
                # memory pressure victimizes suspended agents first:
                # escalate one hold→spill instead of swapping a runner
                self._spill_oldest_held()
                return True
            if len(self._running) > 1:
                t0 = _time.perf_counter()
                if self.sched.dynamic:
                    self.result.key_evals += len(self._running)
                    victim = max(
                        self._running.values(),
                        key=lambda r: self.sched.request_key(r.req, t),
                    )
                else:
                    # static policies: keys were cached at admission
                    victim = max(self._running.values(), key=lambda r: r.key)
                self._sched_clock += _time.perf_counter() - t0
                self._credit(victim, t)
                self._remove_running(victim)
                victim.swapped = True
                self._swapped.push(victim)
                self.result.swaps += 1
                # the pre-swap occupancy (~M) is the true local maximum
                if occ_sat > self.result.peak_occupancy:
                    self.result.peak_occupancy = occ_sat
                self._emit(
                    "on_swap_out", victim.req.agent_id, victim.req.rid, t
                )
            else:
                # single sequence saturating the pool: let it finish — but
                # never past the next arrival, which must be processed on
                # time (assume p + d < M for all workloads; App. B)
                r = next(iter(self._running.values()))
                fin = r.fin
                if self._arrivals and self._arrivals[0][0] < fin:
                    fin = self._arrivals[0][0]
                if self._resume_heap and self._resume_heap[0][0] < fin:
                    fin = self._resume_heap[0][0]
                if fin > until:
                    # don't overshoot an advance() horizon: a later submit
                    # would clamp its arrival to the overshot clock.  The
                    # jump resumes in a later advance/drain; one-shot
                    # drains (until=inf) never take this path.  Un-count
                    # this trip: a one-shot drain performs the prefill
                    # pops above AND the jump as ONE event, and the
                    # resuming trip will re-count it.
                    self.result.events -= 1
                    return False
                self._credit(r, fin)
                self.t = fin
                self._last_event_t = fin
        return True

    # ------------------------------------------------------------ advancing

    def advance(self, until: float) -> None:
        """Process all events at or before ``until``; raise the clock floor."""
        if self._in_run:
            raise RuntimeError(
                "re-entrant advance() from a listener callback"
            )
        until = float(until)
        self._in_run = True
        try:
            while self._step(until):
                pass
        finally:
            self._in_run = False
        self.t = max(self.t, until)

    def drain(self) -> SimResult:
        """Serve everything submitted so far; cumulative results snapshot."""
        if self._in_run:
            raise RuntimeError("re-entrant drain() from a listener callback")
        self._in_run = True
        try:
            while self._step(float("inf")):
                pass
        finally:
            self._in_run = False
        self.result.sched_decisions = self._decisions
        self.result.sched_time = self._sched_clock
        self.result.sorts = self._waiting.sorts + self._swapped.sorts
        self.result.makespan = self._last_event_t
        return dataclasses.replace(
            self.result,
            jct=dict(self.result.jct),
            finish=dict(self.result.finish),
        )

    def run(self, agents: Sequence[SimAgent]) -> SimResult:
        """One-shot wrapper: submit ``agents`` and drain (legacy surface)."""
        for a in sorted(agents, key=lambda a: (a.arrival, a.agent_id)):
            self.submit(a)
        return self.drain()
