"""Discrete-event cluster simulator + metrics (paper-scale experiments).

``ClusterSim`` is the event-indexed production core;
``reference.ReferenceClusterSim`` is the retained pre-rewrite oracle the
equivalence tests and ``benchmarks/perf.py`` pin it against.
"""

from repro.sim.cluster import ClusterSim, SimAgent, SimResult
from repro.sim.metrics import (
    FairnessStats,
    JctStats,
    fair_ratios,
    fairness_stats,
    jct_stats,
)
from repro.sim.reference import ReferenceClusterSim

__all__ = [
    "ClusterSim",
    "ReferenceClusterSim",
    "SimAgent",
    "SimResult",
    "FairnessStats",
    "JctStats",
    "fair_ratios",
    "fairness_stats",
    "jct_stats",
]
