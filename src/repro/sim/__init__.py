"""Discrete-event cluster simulator + metrics (paper-scale experiments)."""

from repro.sim.cluster import ClusterSim, SimAgent, SimResult
from repro.sim.metrics import (
    FairnessStats,
    JctStats,
    fair_ratios,
    fairness_stats,
    jct_stats,
)

__all__ = [
    "ClusterSim",
    "SimAgent",
    "SimResult",
    "FairnessStats",
    "JctStats",
    "fair_ratios",
    "fairness_stats",
    "jct_stats",
]
