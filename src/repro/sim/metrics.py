"""Metrics for the paper's evaluation (§5.1 Metrics).

Efficiency: average / P90 job completion time (JCT).
Fairness: finish-time fair ratio — a job's completion time under a reference
fair scheduler (VTC in the paper's Fig. 8; GPS for the theorem check)
divided by its realistic completion time.  Ratio >= 1 means the job was not
delayed relative to the fair reference.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class JctStats:
    mean: float
    p50: float
    p90: float
    p99: float
    n: int

    def row(self) -> str:
        return (
            f"mean={self.mean:.1f}s p50={self.p50:.1f}s "
            f"p90={self.p90:.1f}s p99={self.p99:.1f}s n={self.n}"
        )


def jct_stats(jct: Mapping[int, float]) -> JctStats:
    v = np.asarray(sorted(jct.values()), dtype=np.float64)
    if v.size == 0:
        return JctStats(0.0, 0.0, 0.0, 0.0, 0)
    return JctStats(
        mean=float(v.mean()),
        p50=float(np.percentile(v, 50)),
        p90=float(np.percentile(v, 90)),
        p99=float(np.percentile(v, 99)),
        n=int(v.size),
    )


def fair_ratios(
    realistic_jct: Mapping[int, float], reference_jct: Mapping[int, float]
) -> dict[int, float]:
    """finish-time fair ratio per agent: reference / realistic (higher=better)."""
    out = {}
    for k, real in realistic_jct.items():
        ref = reference_jct.get(k)
        if ref is None or real <= 0:
            continue
        out[k] = ref / real
    return out


@dataclasses.dataclass(frozen=True)
class FairnessStats:
    frac_not_delayed: float      # ratio >= 1 (within tolerance)
    worst_delay_pct: float       # max relative delay among delayed agents
    mean_delay_pct_of_delayed: float
    n: int


def fairness_stats(ratios: Mapping[int, float], tol: float = 1e-6) -> FairnessStats:
    r = np.asarray(list(ratios.values()), dtype=np.float64)
    if r.size == 0:
        return FairnessStats(1.0, 0.0, 0.0, 0)
    delayed = r[r < 1.0 - tol]
    delay_pct = (1.0 / np.maximum(delayed, 1e-12) - 1.0) * 100.0
    return FairnessStats(
        frac_not_delayed=float((r >= 1.0 - tol).mean()),
        worst_delay_pct=float(delay_pct.max()) if delayed.size else 0.0,
        mean_delay_pct_of_delayed=float(delay_pct.mean()) if delayed.size else 0.0,
        n=int(r.size),
    )
