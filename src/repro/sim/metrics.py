"""Metrics for the paper's evaluation (§5.1 Metrics).

Efficiency: average / P90 job completion time (JCT).
Fairness: finish-time fair ratio — a job's completion time under a reference
fair scheduler (VTC in the paper's Fig. 8; GPS for the theorem check)
divided by its realistic completion time.  Ratio >= 1 means the job was not
delayed relative to the fair reference.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class JctStats:
    mean: float
    p50: float
    p90: float
    p99: float
    n: int

    def row(self) -> str:
        return (
            f"mean={self.mean:.1f}s p50={self.p50:.1f}s "
            f"p90={self.p90:.1f}s p99={self.p99:.1f}s n={self.n}"
        )


def jct_stats(jct: Mapping[int, float]) -> JctStats:
    v = np.asarray(sorted(jct.values()), dtype=np.float64)
    if v.size == 0:
        return JctStats(0.0, 0.0, 0.0, 0.0, 0)
    return JctStats(
        mean=float(v.mean()),
        p50=float(np.percentile(v, 50)),
        p90=float(np.percentile(v, 90)),
        p99=float(np.percentile(v, 99)),
        n=int(v.size),
    )


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """Per-request latency percentiles: TTFT (arrival -> first streamed
    token, queueing-inclusive) and TBT (mean inter-token gap within a
    request's decode, excluding cross-stage idle/queueing gaps)."""

    ttft_mean: float
    ttft_p50: float
    ttft_p90: float
    ttft_p99: float
    tbt_mean: float
    tbt_p50: float
    tbt_p90: float
    tbt_p99: float
    n_ttft: int
    n_tbt: int

    def row(self) -> str:
        return (
            f"ttft mean={self.ttft_mean:.2f}s p50={self.ttft_p50:.2f}s "
            f"p99={self.ttft_p99:.2f}s (n={self.n_ttft}) | "
            f"tbt mean={self.tbt_mean:.3f}s p99={self.tbt_p99:.3f}s "
            f"(n={self.n_tbt})"
        )


def _pcts(values) -> tuple[float, float, float, float, int]:
    v = np.asarray(sorted(values), dtype=np.float64)
    if v.size == 0:
        return 0.0, 0.0, 0.0, 0.0, 0
    return (
        float(v.mean()),
        float(np.percentile(v, 50)),
        float(np.percentile(v, 90)),
        float(np.percentile(v, 99)),
        int(v.size),
    )


def latency_stats(ttfts, tbts) -> LatencyStats:
    """Percentile summary over TTFT / TBT samples (mappings or sequences)."""
    if isinstance(ttfts, Mapping):
        ttfts = ttfts.values()
    if isinstance(tbts, Mapping):
        tbts = tbts.values()
    tf = _pcts(ttfts)
    tb = _pcts(tbts)
    return LatencyStats(
        ttft_mean=tf[0], ttft_p50=tf[1], ttft_p90=tf[2], ttft_p99=tf[3],
        tbt_mean=tb[0], tbt_p50=tb[1], tbt_p90=tb[2], tbt_p99=tb[3],
        n_ttft=tf[4], n_tbt=tb[4],
    )


@dataclasses.dataclass(frozen=True)
class SloTier:
    """One latency tier's targets, in workload seconds (Equinox-style
    per-class SLOs: an agent attains its tier iff BOTH hold)."""

    name: str
    ttft: float       # max time-to-first-token
    tbt: float        # max mean time-between-tokens


@dataclasses.dataclass(frozen=True)
class SloStats:
    attainment: float                 # frac of agents meeting BOTH targets
    ttft_attainment: float
    tbt_attainment: float
    per_tier: dict[str, float]        # tier name -> joint attainment
    n: int

    def row(self) -> str:
        tiers = " ".join(
            f"{name}={frac:.2f}" for name, frac in sorted(self.per_tier.items())
        )
        return (
            f"slo={self.attainment:.2f} (ttft {self.ttft_attainment:.2f}, "
            f"tbt {self.tbt_attainment:.2f}) [{tiers}] n={self.n}"
        )


def slo_attainment(
    ttfts: Mapping[int, float],
    tbts: Mapping[int, float],
    tiers: Mapping[int, SloTier],
) -> SloStats:
    """SLO attainment over the agents that have a tier assignment.

    An agent without a TTFT sample (never streamed a token) misses its
    tier; an agent without a TBT sample (single-token decodes) vacuously
    attains the TBT half.
    """
    n = ok = ok_ttft = ok_tbt = 0
    per_tier_n: dict[str, int] = {}
    per_tier_ok: dict[str, int] = {}
    for aid, tier in tiers.items():
        n += 1
        per_tier_n[tier.name] = per_tier_n.get(tier.name, 0) + 1
        ttft = ttfts.get(aid)
        a_ttft = ttft is not None and ttft <= tier.ttft
        tbt = tbts.get(aid)
        a_tbt = tbt is None or tbt <= tier.tbt
        ok_ttft += a_ttft
        ok_tbt += a_tbt
        if a_ttft and a_tbt:
            ok += 1
            per_tier_ok[tier.name] = per_tier_ok.get(tier.name, 0) + 1
    if n == 0:
        return SloStats(1.0, 1.0, 1.0, {}, 0)
    return SloStats(
        attainment=ok / n,
        ttft_attainment=ok_ttft / n,
        tbt_attainment=ok_tbt / n,
        per_tier={
            name: per_tier_ok.get(name, 0) / cnt
            for name, cnt in per_tier_n.items()
        },
        n=n,
    )


def fair_ratios(
    realistic_jct: Mapping[int, float], reference_jct: Mapping[int, float]
) -> dict[int, float]:
    """finish-time fair ratio per agent: reference / realistic (higher=better)."""
    out = {}
    for k, real in realistic_jct.items():
        ref = reference_jct.get(k)
        if ref is None or real <= 0:
            continue
        out[k] = ref / real
    return out


@dataclasses.dataclass(frozen=True)
class FairnessStats:
    frac_not_delayed: float      # ratio >= 1 (within tolerance)
    worst_delay_pct: float       # max relative delay among delayed agents
    mean_delay_pct_of_delayed: float
    n: int


def fairness_stats(ratios: Mapping[int, float], tol: float = 1e-6) -> FairnessStats:
    r = np.asarray(list(ratios.values()), dtype=np.float64)
    if r.size == 0:
        return FairnessStats(1.0, 0.0, 0.0, 0)
    delayed = r[r < 1.0 - tol]
    delay_pct = (1.0 / np.maximum(delayed, 1e-12) - 1.0) * 100.0
    return FairnessStats(
        frac_not_delayed=float((r >= 1.0 - tol).mean()),
        worst_delay_pct=float(delay_pct.max()) if delayed.size else 0.0,
        mean_delay_pct_of_delayed=float(delay_pct.mean()) if delayed.size else 0.0,
        n=int(r.size),
    )
