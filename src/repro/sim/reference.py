"""Retained pre-rewrite simulator core: the behavioural oracle.

This is the O(events × running) discrete-event loop the event-indexed
``repro.sim.ClusterSim`` replaced: every event re-accounts service for all
running sequences (``account``), re-sums pool occupancy, probes the next
finish/prefill boundary with ``min()`` over the running set, and fully
re-sorts the waiting/swapped queues on every admission pass.  It is kept —
deliberately slow and simple — as the ground truth the optimized core is
pinned to:

* ``tests/test_sim_equivalence.py`` property-checks that both cores produce
  identical completion orders and JCTs across mixed arrival patterns;
* ``benchmarks/perf.py`` asserts identical JCT/finish dicts on a seeded
  1k-agent workload before recording the optimized core's throughput, and
  reports the measured speedup against this implementation.

Semantics are identical to the optimized core by construction (one
admission-pass structure, same event-ordering cascade arrival >
completion > saturation, same vLLM swap policy); the only intentional
change from the historical seed code is shared with the optimized core:
the admission fit check happens *before* a request joins ``running``, so a
pass can no longer push occupancy past M (except for the documented
oversized-request-on-idle-pool escape hatch).

Do not grow features here — this file only changes when the *semantics*
of the simulator change, in lockstep with ``cluster.py``.  Two
post-rewrite lockstep additions exist, both off by default and provably
inert to the dynamics when off:

* ``token_events`` — the discretized token-boundary emission overlay
  (see the cluster.py module doc): a pure emission sweep at the top of
  every event trip, identical float-for-float in both cores.
* ``prefix_cache`` (PR 6) — the analytic prefix-cache model: an
  admission's prefill event is shortened by the modeled hit and only the
  uncached suffix is charged as prefill service, with the identical
  float expressions as the optimized core.  Off, every expression
  reduces to the pre-cache arithmetic bit-for-bit (``hit == 0.0`` and
  ``x - 0.0 == x`` for positive prefills).
* ``admission_watermark`` (PR 8) — the hysteresis admission gate: a NEW
  admission that would lift occupancy above the high watermark is
  deferred while anything is running, until occupancy drains to the low
  watermark.  Off (``None``), the admission pass is untouched — the gate
  branch is never entered.
* ``suspend_retention`` (PR 9) — suspended agents: a stage whose
  ``SimAgent.resume_delays`` entry is positive suspends the agent (no
  decode slot) until its resume time, with its conversation-tail KV
  ``hold``-resident (charged via a held total), ``spill``-parked (a
  ``swap_penalty`` restore trip at resume) or ``drop``-released; memory
  pressure escalates held KV hold→spill BEFORE swapping any running
  sequence.  With no suspensions the held total stays 0.0 and every
  adjusted expression reduces to the prior arithmetic bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import heapq  # noqa: F401  (parity of imports with the historical core)
from typing import Any, Sequence

from repro.core.cost import inference_cost
from repro.core.schedulers import AgentScheduler, Request
from repro.sim.cluster import SimAgent, SimResult


@dataclasses.dataclass
class _Running:
    req: Request
    admit_time: float
    prefill_done: float          # absolute time decoding starts
    d_base: float                # decoded tokens at (re-)admission anchor
    decoded_at_last: float       # decoded tokens at last account time
    last_account: float          # time of last service accounting
    swapped: bool = False
    # finish time, computed ONCE at (re-)admission with the exact same
    # float expression the event-indexed core pushes into its finish
    # calendar — recomputing it per event from the updated accounting
    # anchors shifts the result in the last bits, and a 1e-12 jitter in
    # event times is enough to flip exact-tie VTC counter comparisons
    # between the two cores
    fin: float = float("inf")
    tokens_emitted: int = 0      # token boundaries streamed (token_events)

    def occupancy(self, t: float, decode_rate: float) -> float:
        return self.req.spec.prefill + self.decoded(t, decode_rate)

    def decoded(self, t: float, decode_rate: float) -> float:
        """Stable closed form, anchored at (re-)admission only.

        Accumulating decode progress across per-event accounting anchors
        (the historical formulation) yields bit-different values depending
        on how the interval was partitioned; both cores use this anchored
        form so decode state — and every event time derived from it — is
        identical float-for-float between them.  The snap window mirrors
        the historical accounting's float-Zeno guard.
        """
        if t <= self.prefill_done:
            d = self.d_base
        else:
            d = self.d_base + (t - self.prefill_done) * decode_rate
        cap = self.req.spec.decode
        if cap - d < 1e-6:
            return float(cap)
        return d

    def finish_time(self, decode_rate: float) -> float:
        rem = self.req.spec.decode - self.decoded_at_last
        return max(self.prefill_done, self.last_account) + rem / decode_rate


class ReferenceClusterSim:
    """Pre-rewrite ``ClusterSim``: per-event rescans, per-pass re-sorts."""

    def __init__(
        self,
        scheduler: AgentScheduler,
        total_kv: float,
        decode_rate: float = 30.0,       # tokens/s per running sequence
        prefill_rate: float = 4000.0,    # prompt tokens/s
        swap_penalty: float = 0.2,       # seconds added on re-admission
        listener: Any = None,
        token_events: bool = False,
        prefix_cache: bool = False,
        admission_watermark: Any = None,
        suspend_retention: str = "hold",
    ):
        self.sched = scheduler
        self.m = float(total_kv)
        self.decode_rate = float(decode_rate)
        self.prefill_rate = float(prefill_rate)
        self.swap_penalty = float(swap_penalty)
        self.listener = listener
        self.token_events = bool(token_events)
        self.prefix_cache = bool(prefix_cache)
        if admission_watermark is not None:
            low, high = admission_watermark
            if not (0.0 < low <= high <= 1.0):
                raise ValueError(
                    f"admission_watermark must satisfy 0 < low <= high <= 1,"
                    f" got {admission_watermark!r}"
                )
            self._wm = (low * self.m, high * self.m)
        else:
            self._wm = None
        if suspend_retention not in ("hold", "spill", "drop"):
            raise ValueError(
                f"suspend_retention must be 'hold', 'spill' or 'drop',"
                f" got {suspend_retention!r}"
            )
        self.suspend_retention = suspend_retention

    def _emit(self, event: str, *args) -> None:
        if self.listener is not None:
            fn = getattr(self.listener, event, None)
            if fn is not None:
                fn(*args)

    # ------------------------------------------------------------------ run

    def run(self, agents: Sequence[SimAgent]) -> SimResult:
        import time as _time

        agents = sorted(agents, key=lambda a: (a.arrival, a.agent_id))
        by_id = {a.agent_id: a for a in agents}
        arrivals = list(agents)
        ai = 0
        waiting: list[Request] = []
        swapped: list[_Running] = []
        running: list[_Running] = []
        rid_counter = 0
        t = 0.0
        result = SimResult(jct={}, finish={})
        seeded_groups: set[str] = set()
        wm_state = {"gated": False}
        wm_emitted: set[int] = set()
        # suspension state (PR 9) — LOCKSTEP with the optimized core
        resume_heap: list[tuple[float, int, int]] = []
        held: dict[int, float] = {}
        spilled: set[int] = set()
        penalized: set[int] = set()
        held_total = 0.0
        rseq = 0
        _sched_clock = 0.0
        _decisions = 0
        _key_evals = 0

        def key(req: Request, now: float):
            nonlocal _key_evals
            _key_evals += 1
            return self.sched.request_key(req, now)

        def submit_stage(agent: SimAgent, now: float) -> None:
            nonlocal rid_counter
            specs = agent.stages[agent.next_stage]
            hints = None
            if (agent.cached_hints is not None
                    and agent.next_stage < len(agent.cached_hints)):
                hints = agent.cached_hints[agent.next_stage]
            agent.next_stage += 1
            agent.live_inferences += len(specs)
            for i, spec in enumerate(specs):
                waiting.append(
                    Request(
                        agent_id=agent.agent_id,
                        rid=rid_counter,
                        spec=spec,
                        submit_time=now,
                        pred_cost=inference_cost(spec, agent.family),
                        cached_prefix=(
                            float(hints[i])
                            if hints is not None and i < len(hints) else 0.0
                        ),
                    )
                )
                rid_counter += 1

        def prefix_hit(req: Request, now: float, deferred: list) -> float:
            """Analytic prefix-cache hit — LOCKSTEP with the optimized
            core's ``_prefix_hit`` (same expressions, same seeded-group
            rule, same accounting); 0.0 with the cache off."""
            if not self.prefix_cache:
                return 0.0
            agent = by_id[req.agent_id]
            base = 0.0
            if agent.prefix_group and agent.prefix_group in seeded_groups:
                base = float(agent.shared_prefix)
            hit = max(base, float(req.cached_prefix))
            if hit > req.spec.prefill:
                hit = float(req.spec.prefill)
            if agent.prefix_group:
                seeded_groups.add(agent.prefix_group)
            aid = req.agent_id
            result.agent_prefill_tokens[aid] = (
                result.agent_prefill_tokens.get(aid, 0.0)
                + req.spec.prefill
            )
            if hit > 0.0:
                result.agent_hit_tokens[aid] = (
                    result.agent_hit_tokens.get(aid, 0.0) + hit
                )
                result.prefill_tokens_saved += hit
                deferred.append(
                    ("on_prefix_hit", aid, req.rid, hit,
                     float(req.spec.prefill), now)
                )
            return hit

        def occupancy(now: float) -> float:
            return sum(r.occupancy(now, self.decode_rate) for r in running)

        def account(now: float) -> None:
            """Credit service between last accounting point and ``now``."""
            for r in running:
                dt_total = now - r.last_account
                if dt_total <= 0:
                    continue
                # decode progress only after prefill completes
                dec_start = max(r.last_account, r.prefill_done)
                dt_dec = max(0.0, now - dec_start)
                new_decoded = r.decoded(now, self.decode_rate)
                d_tokens = new_decoded - r.decoded_at_last
                # KV token-time integral: occupancy dt, converted to
                # token-iterations via decode_rate (1 iteration == 1/rate s)
                occ0 = r.req.spec.prefill + r.decoded_at_last
                kv_tt = (occ0 * dt_total + 0.5 * d_tokens * dt_dec) * self.decode_rate
                self.sched.on_service(
                    r.req.agent_id,
                    kv_token_time=kv_tt,
                    decode_tokens=d_tokens,
                )
                r.decoded_at_last = new_decoded
                r.last_account = now

        def resume(r: _Running, now: float, deferred: list) -> None:
            r.swapped = False
            r.last_account = now
            r.prefill_done = max(r.prefill_done, now + self.swap_penalty)
            r.d_base = r.decoded_at_last
            r.fin = r.finish_time(self.decode_rate)
            running.append(r)
            deferred.append(("on_swap_in", r.req.agent_id, r.req.rid, now))

        def suspend(agent: SimAgent, delay: float, now: float) -> None:
            """Park a closed-loop agent for ``delay`` seconds of think
            time — LOCKSTEP with the optimized core's ``_suspend``."""
            nonlocal held_total, rseq
            aid = agent.agent_id
            stage = agent.next_stage - 1
            until = now + float(delay)
            h = 0.0
            if self.suspend_retention == "hold":
                spec = agent.stages[stage][-1]
                h = float(spec.prefill + spec.decode)
            held[aid] = h
            held_total += h
            if self.suspend_retention == "spill":
                spilled.add(aid)
            rseq += 1
            heapq.heappush(resume_heap, (until, rseq, aid))
            result.suspensions += 1
            if held_total > result.held_peak:
                result.held_peak = held_total
            self.sched.on_agent_suspend(aid, now)
            self._emit("on_suspend", aid, stage, until, now)

        def spill_oldest_held() -> float:
            """Escalate hold→spill on the oldest held agent (freed KV) —
            memory pressure victimizes suspended agents before running
            ones.  LOCKSTEP with ``_spill_oldest_held``."""
            nonlocal held_total
            for aid, h in held.items():
                if h > 0.0:
                    held[aid] = 0.0
                    held_total -= h
                    spilled.add(aid)
                    result.suspend_spills += 1
                    return h
            return 0.0

        def admit(now: float) -> None:
            """Admission pass: swapped queue first, then waiting (vLLM)."""
            nonlocal _sched_clock, _decisions, _key_evals
            # listener emits are deferred past the timed window so the
            # reported scheduler overhead measures policy code only
            deferred: list[tuple] = []
            t0 = _time.perf_counter()
            free = self.m - occupancy(now) - held_total
            # swapped queue has absolute priority and blocks new admissions
            _key_evals += len(swapped)
            swapped.sort(key=lambda r: self.sched.request_key(r.req, now))
            while swapped:
                r = swapped[0]
                need = r.req.spec.prefill + r.decoded_at_last
                if need > free:
                    sp = spill_oldest_held()
                    if sp > 0.0:
                        free += sp
                        continue
                    break
                swapped.pop(0)
                resume(r, now, deferred)
                free -= need
            if not swapped:
                _key_evals += len(waiting)
                waiting.sort(key=lambda r: self.sched.request_key(r, now))
                while waiting:
                    req = waiting[0]
                    # the fit check precedes admission so a pass can never
                    # push occupancy past M — except for a request larger
                    # than the whole pool, which would deadlock the backend;
                    # vLLM admits it alone and lets it thrash, so we admit
                    # it when the pool is otherwise idle
                    fits = req.spec.prefill <= free
                    solo_oversized = (
                        not running and req.spec.prefill >= self.m
                    )
                    if not (fits or solo_oversized):
                        sp = spill_oldest_held()
                        if sp > 0.0:
                            free += sp
                            continue
                        break
                    # watermark admission gate — LOCKSTEP with the
                    # optimized core's ``_admit`` (same expressions, same
                    # hysteresis rule, same idle-pool bypass)
                    if self._wm is not None:
                        low, high = self._wm
                        occ_now = self.m - free
                        if running:
                            if wm_state["gated"] and occ_now <= low:
                                wm_state["gated"] = False
                            if (wm_state["gated"]
                                    or occ_now + req.spec.prefill > high):
                                wm_state["gated"] = True
                                if req.rid not in wm_emitted:
                                    wm_emitted.add(req.rid)
                                    result.admission_deferrals += 1
                                    deferred.append((
                                        "on_admission_deferred",
                                        req.agent_id, req.rid, now,
                                    ))
                                break
                        elif occ_now + req.spec.prefill > high:
                            result.wm_bypass_admits += 1
                        peak = occ_now + req.spec.prefill
                        if peak > result.wm_admit_peak:
                            result.wm_admit_peak = peak
                    waiting.pop(0)
                    hit = prefix_hit(req, now, deferred)
                    pf = now + (req.spec.prefill - hit) / self.prefill_rate
                    self.sched.on_service(
                        req.agent_id, prefill_tokens=req.spec.prefill - hit
                    )
                    deferred.append(("on_admit", req.agent_id, req.rid, now))
                    r_new = _Running(
                        req=req,
                        admit_time=now,
                        prefill_done=pf,
                        d_base=0.0,
                        decoded_at_last=0.0,
                        last_account=now,
                    )
                    r_new.fin = r_new.finish_time(self.decode_rate)
                    running.append(r_new)
                    free -= req.spec.prefill
                    if free < 0:      # only reachable via solo_oversized
                        break
            elif not running:
                # swapped head cannot fit but nothing is running: re-admit it
                # anyway (its KV footprint is what it is — vLLM would page)
                resume(swapped.pop(0), now, deferred)
            _decisions += 1
            _sched_clock += _time.perf_counter() - t0
            result.peak_occupancy = max(
                result.peak_occupancy, occupancy(now) + held_total
            )
            for ev in deferred:
                self._emit(*ev)

        def sweep_tokens(now: float) -> None:
            """Token-boundary emission overlay — LOCKSTEP with the
            optimized core's ``_sweep_tokens`` (same float expressions,
            same running-list iteration order, same sort key); see the
            cluster.py module doc.
            """
            rate = self.decode_rate
            batch = []
            for idx, r in enumerate(running):
                d = r.decoded(now, rate)
                n = int(d + 1e-9)
                cap = int(r.req.spec.decode)
                if n > cap:
                    n = cap
                k = r.tokens_emitted
                if n <= k:
                    continue
                pf = r.prefill_done
                base = r.d_base
                aid, rid = r.req.agent_id, r.req.rid
                while k < n:
                    k += 1
                    tk = pf + (k - base) / rate
                    if tk > now:
                        tk = now
                    batch.append((tk, idx, k, aid, rid))
                r.tokens_emitted = n
            batch.sort(key=lambda e: e[:3])
            for tk, _, k, aid, rid in batch:
                self._emit("on_token", aid, rid, k - 1, tk)

        def saturation_time(now: float) -> float:
            """When does pool occupancy hit M at current decode rates?

            Only sequences whose prefill has completed are growing; a
            prefill completion is itself an event (see the main loop), after
            which this is recomputed with the new rate.
            """
            occ = occupancy(now)
            free = self.m - occ
            growing = sum(
                1
                for r in running
                if r.prefill_done <= now + 1e-12
                and r.decoded(now, self.decode_rate) < r.req.spec.decode
            )
            if growing == 0:
                return float("inf")
            rate = growing * self.decode_rate
            return now + max(0.0, free - held_total) / rate

        # main event loop
        while (ai < len(arrivals) or waiting or running or swapped
               or resume_heap):
            t_arr = arrivals[ai].arrival if ai < len(arrivals) else float("inf")
            t_res = resume_heap[0][0] if resume_heap else float("inf")
            t_fin = min(
                (r.fin for r in running),
                default=float("inf"),
            )
            t_pref = min(
                (r.prefill_done for r in running if r.prefill_done > t + 1e-12),
                default=float("inf"),
            )
            t_sat = saturation_time(t) if running else float("inf")
            t_next = min(t_arr, t_res, t_fin, t_sat, t_pref)
            if t_next == float("inf"):
                # nothing running/finishing: only waiting items blocked by
                # swapped priority or memory — should not happen if pool can
                # fit smallest request; guard against deadlock
                if waiting or swapped:
                    raise RuntimeError(
                        "simulator deadlock: pool cannot fit pending work"
                    )
                break
            t_next = max(t_next, t)
            account(t_next)
            t = t_next
            result.events += 1
            if self.token_events:
                sweep_tokens(t)

            if t_arr <= t + 1e-12 and ai < len(arrivals):
                agent = arrivals[ai]
                ai += 1
                _t0 = _time.perf_counter()
                self.sched.on_agent_arrival(
                    agent.agent_id, agent.arrival, agent.predicted_cost
                )
                _sched_clock += _time.perf_counter() - _t0
                _decisions += 1
                self._emit("on_arrival", agent.agent_id, t)
                submit_stage(agent, t)
                admit(t)
                continue

            # resumes: think time ended (one per trip, like arrivals)
            if t_res <= t + 1e-12:
                _, _, aid = heapq.heappop(resume_heap)
                if aid in spilled and aid not in penalized:
                    # spilled KV pays the swap-in restore surcharge before
                    # the next stage submits — one deterministic penalty
                    # trip (LOCKSTEP with the optimized core)
                    penalized.add(aid)
                    rseq += 1
                    heapq.heappush(
                        resume_heap, (t + self.swap_penalty, rseq, aid)
                    )
                    continue
                h = held.pop(aid, 0.0)
                held_total -= h
                spilled.discard(aid)
                penalized.discard(aid)
                result.resumes += 1
                agent = by_id[aid]
                _t0 = _time.perf_counter()
                self.sched.on_agent_resume(aid, t)
                _sched_clock += _time.perf_counter() - _t0
                self._emit("on_resume", aid, t)
                submit_stage(agent, t)
                admit(t)
                continue

            # completions
            done = [
                r
                for r in running
                if r.decoded_at_last >= r.req.spec.decode - 1e-9
                and t >= r.prefill_done - 1e-9
            ]
            if done:
                for r in done:
                    running.remove(r)
                    agent = by_id[r.req.agent_id]
                    agent.live_inferences -= 1
                    if agent.live_inferences == 0:
                        self._emit(
                            "on_stage_complete", agent.agent_id,
                            agent.next_stage - 1, t,
                        )
                        if agent.next_stage < len(agent.stages):
                            delays = agent.resume_delays
                            delay = (
                                float(delays[agent.next_stage])
                                if delays is not None
                                and agent.next_stage < len(delays)
                                else 0.0
                            )
                            if delay > 0.0:
                                suspend(agent, delay, t)
                            else:
                                submit_stage(agent, t)
                        else:
                            agent.finish = t
                            result.finish[agent.agent_id] = t
                            result.jct[agent.agent_id] = t - agent.arrival
                            _t0 = _time.perf_counter()
                            self.sched.on_agent_complete(agent.agent_id, t)
                            _sched_clock += _time.perf_counter() - _t0
                            self._emit(
                                "on_agent_complete", agent.agent_id, t
                            )
                admit(t)
                continue

            # saturation: swap out the worst-priority running inference —
            # but memory pressure victimizes suspended agents first
            occ_sat = occupancy(t) + held_total if running else 0.0
            if occ_sat >= self.m - 1e-6 and running:
                if held_total > 0.0:
                    spill_oldest_held()
                    continue
                if len(running) > 1:
                    _key_evals += len(running)
                    victim = max(
                        running,
                        key=lambda r: self.sched.request_key(r.req, t),
                    )
                    running.remove(victim)
                    victim.swapped = True
                    swapped.append(victim)
                    result.swaps += 1
                    self._emit(
                        "on_swap_out", victim.req.agent_id, victim.req.rid, t
                    )
                    continue
                # single sequence saturating the pool: let it finish —
                # but never past the next arrival or resume, which must be
                # processed on time (assume p + d < M for all workloads;
                # see App. B assumption)
                r = running[0]
                fin = r.fin
                if ai < len(arrivals):
                    fin = min(fin, arrivals[ai].arrival)
                if resume_heap:
                    fin = min(fin, resume_heap[0][0])
                account(fin)
                t = fin
                continue

        result.sched_decisions = _decisions
        result.sched_time = _sched_clock
        result.key_evals = _key_evals
        result.makespan = t
        return result
