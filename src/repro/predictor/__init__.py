"""MLP-based agent demand prediction (paper §4.2) + heavy baseline."""

from repro.predictor.heavy import HeavyPredictor
from repro.predictor.mlp import MlpCostModel, init_mlp_params, mlp_apply
from repro.predictor.service import (
    AgentCostPredictor,
    TrainedClassModel,
    relative_error,
)
from repro.predictor.tfidf import TfidfVectorizer, tokenize

__all__ = [
    "HeavyPredictor",
    "MlpCostModel",
    "init_mlp_params",
    "mlp_apply",
    "AgentCostPredictor",
    "TrainedClassModel",
    "relative_error",
    "TfidfVectorizer",
    "tokenize",
]
