"""TF-IDF vectorizer (Sparck Jones, 1972) — from scratch, scipy/sklearn-free.

The paper (§4.2) vectorizes the runtime input prompt with TF-IDF before the
per-agent-type MLP: "lightweight and efficient ... focusing on word
importance rather than deep semantic analysis".
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Sequence

import numpy as np

_TOKEN_RE = re.compile(r"[a-z0-9]+")


def tokenize(text: str) -> list[str]:
    return _TOKEN_RE.findall(text.lower())


@dataclasses.dataclass
class TfidfVectorizer:
    """Fit on a corpus; transform to dense (n, vocab) float32 features.

    ``max_features`` keeps the most document-frequent terms — bounded input
    width keeps the MLP's first layer small (the paper sizes it to the
    average agent input).  An extra feature column carries the normalized
    prompt length, which for LLM cost prediction is signal, not nuisance.
    """

    max_features: int = 256
    min_df: int = 3              # drop near-hapax terms (pure noise for cost)
    add_length_feature: bool = True

    vocab_: dict[str, int] | None = None
    idf_: np.ndarray | None = None
    len_scale_: float = 1.0

    def fit(self, corpus: Sequence[str]) -> "TfidfVectorizer":
        df: dict[str, int] = {}
        lengths = []
        for doc in corpus:
            toks = set(tokenize(doc))
            lengths.append(len(tokenize(doc)))
            for t in toks:
                df[t] = df.get(t, 0) + 1
        kept = {t: c for t, c in df.items() if c >= self.min_df}
        top = sorted(kept.items(), key=lambda kv: (-kv[1], kv[0]))[: self.max_features]
        self.vocab_ = {t: i for i, (t, _) in enumerate(top)}
        n = max(1, len(corpus))
        self.idf_ = np.array(
            [math.log((1 + n) / (1 + kept[t])) + 1.0 for t, _ in top],
            dtype=np.float32,
        )
        self.len_scale_ = float(max(1.0, np.mean(lengths))) if lengths else 1.0
        return self

    @property
    def dim(self) -> int:
        assert self.vocab_ is not None, "fit first"
        return len(self.vocab_) + (1 if self.add_length_feature else 0)

    def transform(self, corpus: Sequence[str]) -> np.ndarray:
        assert self.vocab_ is not None and self.idf_ is not None, "fit first"
        out = np.zeros((len(corpus), self.dim), dtype=np.float32)
        for r, doc in enumerate(corpus):
            toks = tokenize(doc)
            if not toks:
                continue
            counts: dict[int, int] = {}
            for t in toks:
                j = self.vocab_.get(t)
                if j is not None:
                    counts[j] = counts.get(j, 0) + 1
            for j, c in counts.items():
                out[r, j] = (c / len(toks)) * self.idf_[j]
            # L2 normalize the tf-idf block
            block = out[r, : len(self.vocab_)]
            nrm = float(np.linalg.norm(block))
            if nrm > 0:
                out[r, : len(self.vocab_)] = block / nrm
            if self.add_length_feature:
                out[r, -1] = len(toks) / self.len_scale_
        return out

    def fit_transform(self, corpus: Sequence[str]) -> np.ndarray:
        return self.fit(corpus).transform(corpus)

    # -- msgpack-able state for checkpointing --------------------------------

    def state_dict(self) -> dict:
        assert self.vocab_ is not None and self.idf_ is not None
        return {
            "max_features": self.max_features,
            "add_length_feature": self.add_length_feature,
            "vocab": list(self.vocab_.keys()),
            "idf": self.idf_.tolist(),
            "len_scale": self.len_scale_,
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "TfidfVectorizer":
        v = cls(
            max_features=d["max_features"],
            add_length_feature=d["add_length_feature"],
        )
        v.vocab_ = {t: i for i, t in enumerate(d["vocab"])}
        v.idf_ = np.asarray(d["idf"], dtype=np.float32)
        v.len_scale_ = float(d["len_scale"])
        return v
