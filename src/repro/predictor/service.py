"""Agent cost prediction service (paper §4.2 + Fig. 5 workflow).

One (TF-IDF vectorizer, 4-layer MLP) pair per agent class, trained on ~100
historical samples per class.  ``predict(class_name, prompt)`` is the
runtime path invoked at agent arrival — a few matrix-vector products, ~ms.

Also provides the Table-1 baseline: a single *heavy* transformer-encoder
regressor trained on the pooled corpus (the offline stand-in for the
DistilBERT/S3 approach — one big semantic model for all classes; see
DESIGN.md §7 for the substitution note).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.predictor.mlp import MlpCostModel
from repro.predictor.tfidf import TfidfVectorizer


@dataclasses.dataclass
class TrainedClassModel:
    vectorizer: TfidfVectorizer
    model: MlpCostModel
    train_time_s: float


class AgentCostPredictor:
    """Per-agent-type MLP predictor (the paper's design)."""

    def __init__(self, max_features: int = 192):
        self.max_features = max_features
        self.models: dict[str, TrainedClassModel] = {}

    def fit(
        self,
        samples: dict[str, tuple[Sequence[str], Sequence[float]]],
        *,
        seed: int = 0,
        epochs: int = 800,
    ) -> None:
        """samples: class_name -> (prompts, true agent costs)."""
        for cls_name, (prompts, costs) in samples.items():
            t0 = time.perf_counter()
            vec = TfidfVectorizer(max_features=self.max_features)
            x = vec.fit_transform(list(prompts))
            model = MlpCostModel.train(
                x, np.asarray(costs, np.float64), seed=seed, epochs=epochs
            )
            self.models[cls_name] = TrainedClassModel(
                vectorizer=vec,
                model=model,
                train_time_s=time.perf_counter() - t0,
            )

    def predict(self, cls_name: str, prompt: str) -> float:
        m = self.models[cls_name]
        x = m.vectorizer.transform([prompt])
        return float(m.model.predict(x)[0])

    def predict_batch(self, cls_name: str, prompts: Sequence[str]) -> np.ndarray:
        m = self.models[cls_name]
        return m.model.predict(m.vectorizer.transform(list(prompts)))

    @property
    def total_train_time_s(self) -> float:
        return sum(m.train_time_s for m in self.models.values())


def relative_error(pred: np.ndarray, truth: np.ndarray) -> float:
    """Paper's metric: |pred − truth| / truth, averaged (as a percentage)."""
    pred = np.asarray(pred, np.float64)
    truth = np.asarray(truth, np.float64)
    return float(np.mean(np.abs(pred - truth) / np.maximum(truth, 1e-9)) * 100)
