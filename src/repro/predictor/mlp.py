"""Per-agent-type cost predictor: 4-layer MLP in pure JAX (paper §4.2).

One model per agent class (the agent type is the prior that makes prediction
accurate — App. A's demand stability).  Trained on ~100 samples with MSE +
L2 via Adam; the first hidden width is proportional to the input feature
width, mirroring the paper's "number of neurons in the first layer is
proportional to the average agent input size".

Targets are log-transformed: agent KV token-time spans ~4 orders of
magnitude across classes, and relative (not absolute) error is what the
scheduler cares about.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def init_mlp_params(key, in_dim: int, widths: Sequence[int]) -> list[dict]:
    params = []
    dims = [in_dim, *widths, 1]
    for i, (a, b) in enumerate(zip(dims, dims[1:])):
        key, sub = jax.random.split(key)
        params.append(
            {
                "w": jax.random.normal(sub, (a, b), jnp.float32)
                * jnp.sqrt(2.0 / a),
                "b": jnp.zeros((b,), jnp.float32),
            }
        )
    return params


def mlp_apply(params, x):
    h = x
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    out = h @ params[-1]["w"] + params[-1]["b"]
    return out[..., 0]


def _loss(params, x, y, l2: float):
    pred = mlp_apply(params, x)
    mse = jnp.mean((pred - y) ** 2)
    reg = sum(jnp.sum(p["w"] ** 2) for p in params)
    return mse + l2 * reg


@functools.partial(jax.jit, static_argnames=("lr", "l2"))
def _adam_step(params, opt_state, x, y, step, lr: float, l2: float):
    b1, b2, eps = 0.9, 0.999, 1e-8
    grads = jax.grad(_loss)(params, x, y, l2)
    m, v = opt_state
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, v, grads)
    mhat = jax.tree.map(lambda m_: m_ / (1 - b1 ** step), m)
    vhat = jax.tree.map(lambda v_: v_ / (1 - b2 ** step), v)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mhat, vhat
    )
    return params, (m, v)


@dataclasses.dataclass
class MlpCostModel:
    """log-cost regressor for one agent class.

    Predictions are clipped to the (slightly widened) range of the training
    targets: App. A's *demand stability* means an agent class's cost lives in
    a narrow band across runs, so out-of-band extrapolations of a small MLP
    are never trusted.  With the log-space target this also bounds the worst
    multiplicative error — which is the robustness knob Fig. 10 studies.
    """

    params: list[dict]
    x_mean: np.ndarray
    x_std: np.ndarray
    y_mean: float
    y_lo: float
    y_hi: float

    @classmethod
    def train(
        cls,
        x: np.ndarray,
        cost: np.ndarray,
        *,
        seed: int = 0,
        epochs: int = 800,
        lr: float = 3e-3,
        l2: float = 3e-4,
        width_factor: float = 1.0,
    ) -> "MlpCostModel":
        x = np.asarray(x, np.float32)
        y = np.log1p(np.asarray(cost, np.float32))
        # center the target: a ReLU net initialized near zero should learn
        # the *deviation* from the class-mean log cost, not the ~e^12 scale
        y_mean = float(y.mean())
        y = y - y_mean
        x_mean = x.mean(axis=0)
        # floor the scale: near-constant training features must not explode
        # on unseen inputs (a word seen once in training has std ~0)
        x_std = np.maximum(x.std(axis=0), 1e-2)
        xn = (x - x_mean) / x_std
        in_dim = x.shape[1]
        # 4-layer MLP; first width proportional to the input size (paper)
        w1 = max(16, int(in_dim * width_factor))
        widths = [w1, max(8, w1 // 2), max(8, w1 // 4)]
        params = init_mlp_params(jax.random.PRNGKey(seed), in_dim, widths)
        zeros = jax.tree.map(jnp.zeros_like, params)
        opt_state = (zeros, jax.tree.map(jnp.zeros_like, params))
        # 80/20 train/validation split with early stopping: with ~100
        # samples a small MLP memorizes quickly; the val split picks the
        # epoch with the best generalization (then we keep those weights)
        n = xn.shape[0]
        perm = np.random.default_rng(seed).permutation(n)
        n_val = max(1, n // 5)
        vi, ti = perm[:n_val], perm[n_val:]
        xj, yj = jnp.asarray(xn[ti]), jnp.asarray(y[ti])
        xv, yv = jnp.asarray(xn[vi]), jnp.asarray(y[vi])
        best_val, best_params, since_best = np.inf, params, 0
        for step in range(1, epochs + 1):
            params, opt_state = _adam_step(
                params, opt_state, xj, yj, step, lr, l2
            )
            if step % 5 == 0:
                val = float(jnp.mean((mlp_apply(params, xv) - yv) ** 2))
                if val < best_val - 1e-5:
                    best_val, best_params, since_best = val, params, 0
                else:
                    since_best += 5
                    if since_best >= 60:
                        break
        params = best_params
        margin = 0.25  # ~ +/- 28% beyond the observed band
        return cls(
            params=jax.device_get(params),
            x_mean=x_mean,
            x_std=x_std,
            y_mean=y_mean,
            y_lo=float(y.min() + y_mean - margin),
            y_hi=float(y.max() + y_mean + margin),
        )

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = (np.asarray(x, np.float32) - self.x_mean) / self.x_std
        logc = np.asarray(mlp_apply(self.params, jnp.asarray(x))) + self.y_mean
        return np.expm1(np.clip(logc, self.y_lo, self.y_hi))
