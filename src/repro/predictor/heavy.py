"""Heavy single-model predictor baseline (Table 1's DistilBERT/S3 stand-in).

A small-from-scratch transformer encoder regressor trained on the *pooled*
corpus (one model for every agent class — the S3 design the paper argues
against).  No pretrained weights exist offline, so this is a size/latency-
faithful substitute: it is two orders of magnitude more compute per
prediction than the MLP and lacks the per-class prior, which is exactly the
comparison axis of Table 1 (accuracy, inference overhead, JCT impact,
training time).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.predictor.tfidf import tokenize

VOCAB = 4096
MAX_LEN = 128


def _hash_tokens(prompt: str) -> np.ndarray:
    ids = [(hash(t) % (VOCAB - 2)) + 2 for t in tokenize(prompt)[:MAX_LEN]]
    out = np.zeros(MAX_LEN, np.int32)
    out[: len(ids)] = ids
    return out


def init_encoder_params(key, d: int = 256, n_layers: int = 4, n_heads: int = 4):
    params = {"embed": None, "pos": None, "layers": [], "head": None}
    key, k1, k2 = jax.random.split(key, 3)
    params["embed"] = jax.random.normal(k1, (VOCAB, d)) * 0.02
    params["pos"] = jax.random.normal(k2, (MAX_LEN, d)) * 0.02
    for _ in range(n_layers):
        key, *ks = jax.random.split(key, 7)
        params["layers"].append(
            {
                "wq": jax.random.normal(ks[0], (d, d)) * (d ** -0.5),
                "wk": jax.random.normal(ks[1], (d, d)) * (d ** -0.5),
                "wv": jax.random.normal(ks[2], (d, d)) * (d ** -0.5),
                "wo": jax.random.normal(ks[3], (d, d)) * (d ** -0.5),
                "w1": jax.random.normal(ks[4], (d, 4 * d)) * (d ** -0.5),
                "w2": jax.random.normal(ks[5], (4 * d, d)) * ((4 * d) ** -0.5),
            }
        )
    key, kh = jax.random.split(key)
    params["head"] = jax.random.normal(kh, (d, 1)) * (d ** -0.5)
    return params


N_HEADS = 4


def encoder_apply(params, ids, n_heads: int = N_HEADS):
    x = params["embed"][ids] + params["pos"][None, : ids.shape[1]]
    mask = (ids > 0)[..., None]
    for lyr in params["layers"]:
        b, s, d = x.shape
        hd = d // n_heads

        def split(h):
            return h.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

        q, k, v = split(x @ lyr["wq"]), split(x @ lyr["wk"]), split(x @ lyr["wv"])
        att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(hd)
        att = jax.nn.softmax(att, axis=-1)
        o = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + o @ lyr["wo"]
        x = x + jax.nn.gelu(x @ lyr["w1"]) @ lyr["w2"]
        x = x * mask
    pooled = x.sum(1) / jnp.maximum(mask.sum(1), 1)
    return (pooled @ params["head"])[..., 0]


def _loss(params, ids, y):
    return jnp.mean((encoder_apply(params, ids) - y) ** 2)


@jax.jit
def _sgd_step(params, ids, y, lr):
    grads = jax.grad(_loss)(params, ids, y)
    return jax.tree.map(
        lambda p, g: p - lr * g if isinstance(p, jnp.ndarray) else p,
        params,
        grads,
        is_leaf=lambda x: not isinstance(x, (dict, list)),
    )


@dataclasses.dataclass
class HeavyPredictor:
    params: dict

    @classmethod
    def train(
        cls,
        prompts: Sequence[str],
        costs: Sequence[float],
        *,
        seed: int = 0,
        epochs: int = 30,
        batch: int = 32,
        lr: float = 3e-4,
    ) -> "HeavyPredictor":
        ids = np.stack([_hash_tokens(p) for p in prompts])
        y = np.log1p(np.asarray(costs, np.float32))
        params = init_encoder_params(jax.random.PRNGKey(seed))
        rng = np.random.default_rng(seed)
        n = len(prompts)
        for _ in range(epochs):
            order = rng.permutation(n)
            for s in range(0, n, batch):
                idx = order[s : s + batch]
                params = _sgd_step(
                    params, jnp.asarray(ids[idx]), jnp.asarray(y[idx]),
                    jnp.float32(lr),
                )
        return cls(params=params)

    def predict(self, prompt: str) -> float:
        ids = jnp.asarray(_hash_tokens(prompt)[None])
        logc = float(encoder_apply(self.params, ids)[0])
        return float(np.expm1(np.clip(logc, 0.0, 30.0)))

    def predict_batch(self, prompts: Sequence[str]) -> np.ndarray:
        ids = jnp.asarray(np.stack([_hash_tokens(p) for p in prompts]))
        logc = np.asarray(encoder_apply(self.params, ids))
        return np.expm1(np.clip(logc, 0.0, 30.0))
