"""Memory-centric cost modeling (paper §4.1).

The serving cost of an LLM inference is its cumulative KV-cache occupation
over the decode iterations — "KV token-time":

    c = sum_{i=1..d} (p + i) = p*d + d*(d+1)/2

with ``p`` the prefill (prompt) token length and ``d`` the decode (output)
token length.  The paper quotes the continuous approximation ``pd + d^2/2``;
we use the exact discrete sum everywhere (the difference, ``d/2``, never
changes an ordering decision but exactness makes the property tests crisp).

Units: KV-token-time is measured in (tokens x iterations).  Per the paper's
footnote 1, one "token" of KV here means the KV blocks for one token across
all layers/heads — a model-independent unit, which is what makes the cost
model transfer from GPU to TPU unchanged (see DESIGN.md §3).

Beyond the paper's dense formula we provide the family-adapted variants used
for the assigned architecture pool (DESIGN.md §4): sliding-window attention
(occupation saturates at the window), pure-SSM (constant state), hybrid, and
encoder-decoder (constant cross-attention occupation).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Sequence


class MemoryFamily(enum.Enum):
    """How an architecture family occupies sequence memory during decode."""

    DENSE = "dense"            # full-attention KV cache, grows by 1/token
    SLIDING_WINDOW = "swa"     # KV ring buffer, saturates at window W
    SSM = "ssm"                # constant-size recurrent state
    HYBRID = "hybrid"          # mamba state + a fraction of attn layers
    ENCDEC = "encdec"          # decoder KV grows + constant cross-attn KV


@dataclasses.dataclass(frozen=True)
class InferenceSpec:
    """One LLM inference task inside an agent.

    ``stage`` encodes task-graph ordering inside an agent: stage-k inferences
    are submitted only once every stage-(k-1) inference completed (e.g. the
    merge step of MapReduce-Summarization).  Stage 0 tasks are submitted at
    agent arrival — the "task-parallel" case of the paper.
    """

    prefill: int
    decode: int
    stage: int = 0

    def __post_init__(self) -> None:
        if self.prefill < 0 or self.decode < 0:
            raise ValueError("prefill/decode must be non-negative")


def kv_token_time(prefill: int, decode: int) -> float:
    """Paper Eq. (1), exact discrete form: sum_{i=1..d} (p+i)."""
    p, d = float(prefill), float(decode)
    return p * d + d * (d + 1.0) / 2.0


def swa_kv_token_time(prefill: int, decode: int, window: int) -> float:
    """KV token-time when occupation saturates at a sliding window W.

    c = sum_{i=1..d} min(p+i, W).  Closed form by splitting at the
    saturation iteration i* = max(0, W - p).
    """
    if window <= 0:
        raise ValueError("window must be positive")
    p, d, w = prefill, decode, window
    if p >= w:  # already saturated at iteration 1
        return float(w) * d
    grow = min(d, w - p)  # iterations during which occupation still grows
    c_grow = kv_token_time(p, grow)
    c_flat = float(w) * max(0, d - grow)
    return c_grow + c_flat


def ssm_token_time(decode: int, state_tokens: float) -> float:
    """Constant recurrent state occupying ``state_tokens`` KV-token units."""
    return state_tokens * decode


def hybrid_kv_token_time(
    prefill: int, decode: int, attn_fraction: float, state_tokens: float
) -> float:
    """Mamba-state + shared-attention mix (e.g. zamba2)."""
    return (
        attn_fraction * kv_token_time(prefill, decode)
        + ssm_token_time(decode, state_tokens)
    )


def encdec_kv_token_time(prefill_enc: int, prefill_dec: int, decode: int) -> float:
    """Decoder self-attn KV grows; encoder-output cross-attn KV is constant."""
    return kv_token_time(prefill_dec, decode) + float(prefill_enc) * decode


def inference_cost(
    spec: InferenceSpec,
    family: MemoryFamily = MemoryFamily.DENSE,
    *,
    window: int = 0,
    state_tokens: float = 0.0,
    attn_fraction: float = 1.0,
    prefill_enc: int = 0,
) -> float:
    """KV token-time of one inference under the arch family's memory model."""
    if family is MemoryFamily.DENSE:
        return kv_token_time(spec.prefill, spec.decode)
    if family is MemoryFamily.SLIDING_WINDOW:
        return swa_kv_token_time(spec.prefill, spec.decode, window)
    if family is MemoryFamily.SSM:
        return ssm_token_time(spec.decode, state_tokens)
    if family is MemoryFamily.HYBRID:
        return hybrid_kv_token_time(
            spec.prefill, spec.decode, attn_fraction, state_tokens
        )
    if family is MemoryFamily.ENCDEC:
        return encdec_kv_token_time(prefill_enc, spec.prefill, spec.decode)
    raise ValueError(f"unknown family {family}")


def agent_cost(
    specs: Sequence[InferenceSpec],
    family: MemoryFamily = MemoryFamily.DENSE,
    **kwargs,
) -> float:
    """Paper §4.1: agent cost = sum of the KV token-time of its inferences."""
    return float(sum(inference_cost(s, family, **kwargs) for s in specs))


# --- Compute-centric baseline cost model (VTC, used by the Justitia/C
# --- ablation and by the VTC scheduler's service counter).

def vtc_cost(prefill: int, decode: int, w_p: float = 1.0, w_d: float = 2.0) -> float:
    """VTC's weighted token count: w_p * p + w_d * d (Sheng et al., 2024)."""
    return w_p * prefill + w_d * decode


def vtc_agent_cost(specs: Sequence[InferenceSpec]) -> float:
    return float(sum(vtc_cost(s.prefill, s.decode) for s in specs))
