"""GPS (Generalized Processor Sharing) fluid reference scheduler.

The idealized fair scheduler the paper uses as the fairness yardstick: the
backend's M KV-token units of service rate are arbitrarily divisible and
split equally among the N_t active agents at every instant.  Agent j,
arriving at a_j with total cost C_j (KV token-time), accumulates service at
rate M/N_t and completes at the real time f̄_j where its accumulated service
reaches C_j.

Used by the property tests to check Theorem B.1
(f_j − f̄_j ≤ 2 c_max + C_max / M) against the packetized simulator, and by
the benchmarks to report finish-time fairness against the ideal.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class GpsAgent:
    agent_id: int
    arrival: float
    cost: float  # total KV token-time


def gps_finish_times(agents: Sequence[GpsAgent], total_kv: float) -> dict[int, float]:
    """Event-driven fluid simulation; exact up to float error.

    O((n log n) + n * active) — fine for the benchmark sizes (<=1e4 agents).
    """
    if total_kv <= 0:
        raise ValueError("total_kv must be positive")
    m = float(total_kv)
    pending = sorted(agents, key=lambda a: (a.arrival, a.agent_id))
    finish: dict[int, float] = {}
    active: dict[int, float] = {}  # agent_id -> remaining cost
    t = 0.0
    i = 0
    n = len(pending)
    while i < n or active:
        if not active:
            # jump to next arrival
            t = max(t, pending[i].arrival)
            while i < n and pending[i].arrival <= t:
                active[pending[i].agent_id] = pending[i].cost
                i += 1
            continue
        rate = m / len(active)
        # time until the first active agent would drain at current rate
        min_rem = min(active.values())
        t_drain = t + min_rem / rate
        t_next_arrival = pending[i].arrival if i < n else float("inf")
        t_event = min(t_drain, t_next_arrival)
        dt = t_event - t
        for k in list(active):
            active[k] -= rate * dt
        t = t_event
        done = [k for k, rem in active.items() if rem <= 1e-6]
        if not done and t_event == t_drain and dt <= 0.0:
            # float underflow: min_rem/rate rounds to zero at this time
            # magnitude — the min-remaining agent is done for all purposes
            done = [min(active, key=active.get)]
        for k in done:
            finish[k] = t
            del active[k]
        while i < n and pending[i].arrival <= t + 1e-12:
            active[pending[i].agent_id] = pending[i].cost
            i += 1
    return finish
