"""GPS (Generalized Processor Sharing) fluid reference scheduler.

The idealized fair scheduler the paper uses as the fairness yardstick: the
backend's M KV-token units of service rate are arbitrarily divisible and
split equally among the N_t active agents at every instant.  Agent j,
arriving at a_j with total cost C_j (KV token-time), accumulates service at
rate M/N_t and completes at the real time f̄_j where its accumulated service
reaches C_j.

Used by the property tests to check Theorem B.1
(f_j − f̄_j ≤ 2 c_max + C_max / M) against the packetized simulator, and by
the benchmarks to report finish-time fairness against the ideal.

``gps_finish_times`` applies the standard *virtual-work transform* (WFQ —
Demers et al. 1989; Parekh & Gallager 1993): define V(t) with
dV/dt = M/N_t, i.e. V is the cumulative fair-share work an agent active
since time 0 would have received.  Every active agent accrues service at
exactly dV per dt, so agent j finishes when V crosses the *threshold*
F_j = V(a_j) + C_j — a min-heap of thresholds replaces the per-event
remaining-cost sweep, turning the O(n · active) fluid loop into
O(n log n).  The pre-transform loop is retained as
``gps_finish_times_fluid`` and the two are pinned to each other by an
equivalence property test.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class GpsAgent:
    agent_id: int
    arrival: float
    cost: float  # total KV token-time


def gps_finish_times(agents: Sequence[GpsAgent], total_kv: float) -> dict[int, float]:
    """Virtual-work GPS sweep; O(n log n), exact up to float error.

    Equivalent to :func:`gps_finish_times_fluid` (the event-driven fluid
    integration) but finishes agents by popping virtual thresholds off a
    min-heap instead of rescanning every active agent's remaining cost at
    each event.
    """
    if total_kv <= 0:
        raise ValueError("total_kv must be positive")
    m = float(total_kv)
    order = sorted(agents, key=lambda a: (a.arrival, a.agent_id))
    n = len(order)
    finish: dict[int, float] = {}
    heap: list[tuple[float, int]] = []   # (F_j threshold, agent_id)
    t = 0.0
    v = 0.0                              # virtual work W(t)
    i = 0
    while i < n or heap:
        if not heap:
            # idle: V stalls (only backlogged periods need ordering), the
            # clock jumps to the next arrival batch
            t = max(t, order[i].arrival)
            while i < n and order[i].arrival <= t + 1e-12:
                heapq.heappush(heap, (v + order[i].cost, order[i].agent_id))
                i += 1
            continue
        rate = m / len(heap)             # dV/dt while N_t agents are active
        t_arr = order[i].arrival if i < n else float("inf")
        t_drain = t + max(0.0, heap[0][0] - v) / rate
        if t_drain <= t_arr + 1e-12:
            # V crosses the smallest threshold: that agent (and any other
            # within the fluid loop's drain tolerance) finishes at t_drain
            v = max(v, heap[0][0])
            t = t_drain
            while heap and heap[0][0] <= v + 1e-6:
                _, aid = heapq.heappop(heap)
                finish[aid] = t
        else:
            v += rate * (t_arr - t)
            t = t_arr
            while i < n and order[i].arrival <= t + 1e-12:
                heapq.heappush(heap, (v + order[i].cost, order[i].agent_id))
                i += 1
    return finish


def gps_finish_times_fluid(
    agents: Sequence[GpsAgent], total_kv: float
) -> dict[int, float]:
    """Event-driven fluid simulation; the pre-transform reference.

    O((n log n) + n * active) — retained as the oracle for the virtual-work
    implementation above (see tests/test_sim_equivalence.py); prefer
    :func:`gps_finish_times` everywhere else.
    """
    if total_kv <= 0:
        raise ValueError("total_kv must be positive")
    m = float(total_kv)
    pending = sorted(agents, key=lambda a: (a.arrival, a.agent_id))
    finish: dict[int, float] = {}
    active: dict[int, float] = {}  # agent_id -> remaining cost
    t = 0.0
    i = 0
    n = len(pending)
    while i < n or active:
        if not active:
            # jump to next arrival
            t = max(t, pending[i].arrival)
            while i < n and pending[i].arrival <= t:
                active[pending[i].agent_id] = pending[i].cost
                i += 1
            continue
        rate = m / len(active)
        # time until the first active agent would drain at current rate
        min_rem = min(active.values())
        t_drain = t + min_rem / rate
        t_next_arrival = pending[i].arrival if i < n else float("inf")
        t_event = min(t_drain, t_next_arrival)
        dt = t_event - t
        for k in list(active):
            active[k] -= rate * dt
        t = t_event
        done = [k for k, rem in active.items() if rem <= 1e-6]
        if not done and t_event == t_drain and dt <= 0.0:
            # float underflow: min_rem/rate rounds to zero at this time
            # magnitude — the min-remaining agent is done for all purposes
            done = [min(active, key=active.get)]
        for k in done:
            finish[k] = t
            del active[k]
        while i < n and pending[i].arrival <= t + 1e-12:
            active[pending[i].agent_id] = pending[i].cost
            i += 1
    return finish
