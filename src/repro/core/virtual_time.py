"""GPS virtual time for fair queuing (paper §4.3, Eq. 2-3).

V(0) = 0 ;  dV/dt = M / N_t

where M is the total KV-cache space (in KV-token units) and N_t the number
of agents *active in the GPS reference system* at real time t.  V advances
at the marginal per-agent GPS service rate, so an agent arriving at a_j with
cost C_j finishes in GPS exactly when V reaches

    F_j = V(a_j) + C_j            (virtual finish time; Eq. 3)

F_j is computed once at arrival and never updated: later arrivals slow the
*real-time* mapping of V but never reorder {F_j} — that is the one-shot
property the paper borrows from WFQ (Demers et al. 1989; Parekh & Gallager
1993).

The clock is event-driven: ``advance(t)`` integrates V piecewise-linearly
from the last update to t, popping GPS completions (which change N_t) from a
min-heap of pending virtual finish times as V sweeps past them.
"""

from __future__ import annotations

import heapq


class VirtualClock:
    """Piecewise-linear integrator of the GPS virtual time."""

    def __init__(self, total_kv: float):
        if total_kv <= 0:
            raise ValueError("total_kv must be positive")
        self.m = float(total_kv)
        self._v = 0.0          # current virtual time
        self._t = 0.0          # real time of last update
        self._finish_heap: list[tuple[float, int]] = []  # (F_j, agent_id)
        self._active: set[int] = set()

    # -- inspection ---------------------------------------------------------

    @property
    def n_active(self) -> int:
        return len(self._active)

    def now(self, t: float) -> float:
        """V(t) without mutating state (t must be >= last update time)."""
        v, _ = self._peek(t)
        return v

    # -- core ---------------------------------------------------------------

    def advance(self, t: float) -> None:
        """Integrate V up to real time t, retiring GPS completions."""
        if t < self._t - 1e-9:
            raise ValueError(f"clock moved backwards: {t} < {self._t}")
        v, retired = self._peek(t)
        for agent_id in retired:
            self._active.discard(agent_id)
        # pop retired entries off the heap for real
        while self._finish_heap and self._finish_heap[0][0] <= v + 1e-12:
            heapq.heappop(self._finish_heap)
        self._v, self._t = v, max(t, self._t)

    def on_arrival(self, agent_id: int, t: float, cost: float) -> float:
        """Register agent arrival; returns its virtual finish time F_j."""
        self.advance(t)
        f = self._v + float(cost)
        self._active.add(agent_id)
        heapq.heappush(self._finish_heap, (f, agent_id))
        return f

    # -- internals ----------------------------------------------------------

    def _peek(self, t: float) -> tuple[float, list[int]]:
        """Integrate from (self._t, self._v) to real time t.

        Returns (V(t), agents whose GPS finish V is swept past).  While
        N_t agents are active, dV/dt = M / N_t; when no agent is active V
        stalls (no service is being dealt in GPS — matching the convention
        that V only needs to order *backlogged* periods; an idle system
        re-anchors at the current V).
        """
        v = self._v
        t_cur = t if t > self._t else self._t
        elapsed = t_cur - self._t
        heap = list(self._finish_heap)
        heapq.heapify(heap)
        active = len(self._active)
        retired: list[int] = []
        while elapsed > 0 and active > 0:
            rate = self.m / active
            # real time needed for V to reach the next GPS completion
            if heap:
                f_next = heap[0][0]
                dt_next = max(0.0, (f_next - v)) / rate
            else:
                dt_next = float("inf")
            if dt_next > elapsed:
                v += rate * elapsed
                elapsed = 0.0
            else:
                v = max(v, heap[0][0])
                elapsed -= dt_next
                while heap and heap[0][0] <= v + 1e-12:
                    retired.append(heapq.heappop(heap)[1])
                    active -= 1
        return v, retired
