"""GPS virtual time for fair queuing (paper §4.3, Eq. 2-3).

V(0) = 0 ;  dV/dt = M / N_t

where M is the total KV-cache space (in KV-token units) and N_t the number
of agents *active in the GPS reference system* at real time t.  V advances
at the marginal per-agent GPS service rate, so an agent arriving at a_j with
cost C_j finishes in GPS exactly when V reaches

    F_j = V(a_j) + C_j            (virtual finish time; Eq. 3)

F_j is computed once at arrival and never updated: later arrivals slow the
*real-time* mapping of V but never reorder {F_j} — that is the one-shot
property the paper borrows from WFQ (Demers et al. 1989; Parekh & Gallager
1993).

The clock is event-driven: ``advance(t)`` integrates V piecewise-linearly
from the last update to t, popping GPS completions (which change N_t) from a
min-heap of pending virtual finish times as V sweeps past them.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence


class VirtualClock:
    """Piecewise-linear integrator of the GPS virtual time."""

    def __init__(self, total_kv: float):
        if total_kv <= 0:
            raise ValueError("total_kv must be positive")
        self.m = float(total_kv)
        self._v = 0.0          # current virtual time
        self._t = 0.0          # real time of last update
        self._finish_heap: list[tuple[float, int]] = []  # (F_j, agent_id)
        self._active: set[int] = set()
        self._retired: set[int] = set()   # swept past their F_j

    # -- inspection ---------------------------------------------------------

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def value(self) -> float:
        """V at the last update time (no simulation; pairs with ``now``)."""
        return self._v

    def now(self, t: float) -> float:
        """V(t) without mutating state (t must be >= last update time).

        O(1) when the clock is already advanced to ``t`` — the common case
        after ``GlobalVirtualClock.reconcile`` sweeps every replica clock to
        the same horizon — and a copy-based simulation only for genuinely
        future peeks.
        """
        if t <= self._t:
            return self._v
        v, _ = self._simulate(t, list(self._finish_heap))
        return v

    # -- core ---------------------------------------------------------------

    def advance(self, t: float) -> None:
        """Integrate V up to real time t, retiring GPS completions.

        Destructive integration directly against the live heap — each
        retirement is one O(log n) pop, so sweeping the clock across k
        completions costs O(k log n) rather than the full-heap copy the
        peek-then-repop implementation paid on every call.
        """
        if t < self._t - 1e-9:
            raise ValueError(f"clock moved backwards: {t} < {self._t}")
        if t <= self._t:
            return
        v, retired = self._simulate(t, self._finish_heap)
        for agent_id in retired:
            self._active.discard(agent_id)
            self._retired.add(agent_id)
        self._v, self._t = v, t

    def on_arrival(self, agent_id: int, t: float, cost: float) -> float:
        """Register agent arrival; returns its virtual finish time F_j."""
        self.advance(t)
        f = self._v + float(cost)
        self._active.add(agent_id)
        heapq.heappush(self._finish_heap, (f, agent_id))
        return f

    def deactivate(self, agent_id: int, t: float) -> None:
        """Remove an agent from the GPS reference at real time ``t``.

        Think-time semantics with accrual DISABLED (the Equinox stance —
        see ``ReplicatedBackend(think_time_accrual=False)``): a suspended
        agent stops drawing GPS service, so V speeds up for the agents
        still active and the thinker accrues no virtual time while idle.
        Its F_j stays on the heap (one-shot property untouched); while
        inactive a sweep past F_j does not change the service rate.
        No-op if the agent is not currently active.
        """
        self.advance(t)
        self._active.discard(agent_id)

    def reactivate(self, agent_id: int, t: float) -> None:
        """Re-enter the GPS reference after think time (pairs with
        :meth:`deactivate`).  An agent whose F_j was already swept while
        it was inactive stays retired — re-adding it would suppress the
        clock rate forever, since its heap entry is gone."""
        self.advance(t)
        if agent_id not in self._retired:
            self._active.add(agent_id)

    def forget(self, agent_id: int) -> None:
        """Drop a finished agent's membership bookkeeping (streaming mode).

        The retired set otherwise grows O(agents) over a clock's lifetime —
        it exists only to block ``reactivate`` of an already-swept agent,
        which cannot happen once the agent has left the system for good.
        Never call this for an agent that may still suspend/resume.
        """
        self._retired.discard(agent_id)
        self._active.discard(agent_id)

    # -- internals ----------------------------------------------------------

    def _simulate(self, t: float, heap: list) -> tuple[float, list[int]]:
        """Integrate from (self._t, self._v) to real time t against ``heap``.

        Returns (V(t), agents whose GPS finish V is swept past), popping
        retirements off ``heap`` (pass the live heap to mutate, a copy to
        peek).  While N_t agents are active, dV/dt = M / N_t; when no agent
        is active V stalls (no service is being dealt in GPS — matching the
        convention that V only needs to order *backlogged* periods; an idle
        system re-anchors at the current V).
        """
        v = self._v
        elapsed = t - self._t
        active = len(self._active)
        retired: list[int] = []
        while elapsed > 0 and active > 0:
            rate = self.m / active
            # real time needed for V to reach the next GPS completion
            if heap:
                f_next = heap[0][0]
                dt_next = max(0.0, (f_next - v)) / rate
            else:
                dt_next = float("inf")
            if dt_next > elapsed:
                v += rate * elapsed
                elapsed = 0.0
            else:
                v = max(v, heap[0][0])
                elapsed -= dt_next
                while heap and heap[0][0] <= v + 1e-12:
                    aid = heapq.heappop(heap)[1]
                    retired.append(aid)
                    # a deactivated (thinking) agent was not counted in
                    # ``active``, so sweeping past its F_j changes nothing
                    if aid in self._active:
                        active -= 1
        return v, retired


@dataclasses.dataclass(frozen=True)
class GlobalClockSnapshot:
    """Fleet-level view after reconciling the per-replica clocks to ``time``.

    ``virtual_times[k]`` is V_k(time); the global virtual time is the minimum
    over replicas (the conservative fleet reference: an agent admitted
    anywhere gets F >= min_k V_k, so no replica's backlog can starve it) and
    ``lag`` is the spread max_k V_k - min_k V_k — the price of sharding a
    single fair queue across replicas.  A perfectly balanced router keeps the
    lag near zero; the fleet-wide delay guarantee degrades by at most the lag
    on top of each replica's single-backend bound.
    """

    time: float
    virtual_times: tuple[float, ...]
    global_virtual_time: float
    lag: float
    #: replica indices that were live (not failed) at snapshot time; the
    #: global virtual time and lag are computed over these only.  Empty on
    #: snapshots taken before any replica failed (i.e. all replicas live).
    live: tuple[int, ...] = ()


class GlobalVirtualClock:
    """Reconciles K per-replica GPS clocks into one global virtual time.

    Each replica k runs its own :class:`VirtualClock` over its own service
    capacity M_k (all capacities must be expressed in the same cost-units-
    per-time so the V_k are comparable).  Naive per-replica fair queuing
    breaks *global* fairness exactly when the per-replica clocks drift apart
    (cf. locality-aware fair scheduling): an agent routed to a hot replica
    is charged a later virtual finish than an identical agent routed to a
    cold one.  This class makes the drift observable and bounded:

      * ``register`` buffers arrivals (out-of-submission-order tolerated —
        online submission order need not match arrival-time order);
      * ``reconcile(until)`` replays buffered arrivals in arrival-time order
        into their replica's clock, advances every clock to ``until``, and
        returns a :class:`GlobalClockSnapshot` with the global virtual time
        (min over replicas) and the lag bound (max - min);
      * ``pampering_order`` is the fleet-wide selective-pampering order:
        ascending reconciled virtual finish times across all replicas, which
        equals the single-queue Justitia order whenever the lag is zero.

    The per-replica F_j keep the one-shot property (computed once at
    arrival, never reordered by later arrivals), so reconciliation never
    invalidates a replica's local schedule — it only orders replicas'
    queues against each other.
    """

    def __init__(self, capacities: Sequence[float]):
        caps = [float(m) for m in capacities]
        if not caps:
            raise ValueError("need at least one replica capacity")
        self.capacities = caps
        self.clocks = [VirtualClock(m) for m in caps]
        # (t, submit seq, replica, agent_id, cost, kind) min-heap; kind is
        # "arrive" | "suspend" | "resume", replayed in time order so a
        # suspension's GPS-rate change lands between the right arrivals
        self._pending: list[tuple[float, int, int, int, float, str]] = []
        self._seq = 0
        self._horizon = 0.0            # arrivals <= horizon are replayed
        self.virtual_finish: dict[int, float] = {}
        self.replica_of: dict[int, int] = {}
        self._dead: set[int] = set()

    @property
    def n_replicas(self) -> int:
        return len(self.clocks)

    @property
    def live_indices(self) -> tuple[int, ...]:
        return tuple(
            k for k in range(len(self.clocks)) if k not in self._dead
        )

    def register(
        self, replica: int, agent_id: int, t: float, cost: float
    ) -> None:
        """Buffer one arrival for ``reconcile`` to replay (order-free)."""
        if not 0 <= replica < len(self.clocks):
            raise ValueError(f"replica {replica} out of range")
        if replica in self._dead:
            raise ValueError(f"replica {replica} is dead")
        if t < self._horizon - 1e-9:
            raise ValueError(
                f"arrival at {t} predates reconciled horizon {self._horizon}"
            )
        heapq.heappush(
            self._pending,
            (float(t), self._seq, replica, agent_id, float(cost), "arrive"),
        )
        self._seq += 1

    def note_suspend(self, replica: int, agent_id: int, t: float) -> None:
        """Buffer a think-time suspension (GPS deactivation) for replay.

        Only meaningful when the fleet runs with think-time virtual-time
        accrual DISABLED; silently ignored for dead replicas (their clocks
        are frozen — the agent migrates and re-arrives on a survivor).
        """
        if replica in self._dead:
            return
        heapq.heappush(
            self._pending,
            (max(float(t), self._horizon), self._seq, replica, agent_id,
             0.0, "suspend"),
        )
        self._seq += 1

    def note_resume(self, replica: int, agent_id: int, t: float) -> None:
        """Buffer a think-time resume (GPS reactivation) for replay."""
        if replica in self._dead:
            return
        heapq.heappush(
            self._pending,
            (max(float(t), self._horizon), self._seq, replica, agent_id,
             0.0, "resume"),
        )
        self._seq += 1

    def fail_replica(self, replica: int) -> list[tuple[int, float]]:
        """Mark a replica dead; its clock is frozen at its current V.

        Buffered (un-replayed) arrivals bound for the dead replica are
        dropped from the pending heap and returned as ``[(agent_id, cost)]``
        so the caller can :meth:`migrate` them to survivors.  Agents whose
        arrival was already replayed keep their recorded ``virtual_finish``
        — migration never rewrites accrued virtual time.
        """
        if not 0 <= replica < len(self.clocks):
            raise ValueError(f"replica {replica} out of range")
        self._dead.add(replica)
        orphaned = [
            (aid, cost)
            for (_, _, k, aid, cost, kind) in self._pending
            if k == replica and kind == "arrive"
        ]
        # drop EVERY buffered entry for the dead replica (suspends/resumes
        # included — the frozen clock must never be replayed into again)
        pruned = [entry for entry in self._pending if entry[2] != replica]
        if len(pruned) != len(self._pending):
            self._pending = pruned
            heapq.heapify(self._pending)
        return orphaned

    def migrate(
        self, agent_id: int, new_replica: int, t: float, cost: float
    ) -> Optional[float]:
        """Move an agent to a live replica, carrying accrued virtual time.

        The agent enters ``new_replica``'s GPS reference at real time ``t``
        with remaining cost ``cost`` (it now shares that replica's service
        rate — the re-arrival is buffered like any other and replayed in
        time order by ``reconcile``), but if a global ``virtual_finish``
        was already recorded it is KEPT — the agent's place in the
        fleet-wide pampering order reflects the virtual time it accrued
        before the failure, so a crash cannot demote (or promote) an agent
        relative to its peers.  Returns the carried virtual finish time,
        or ``None`` when the agent's first arrival had not been reconciled
        yet (its F_j materializes at the next ``reconcile``).
        """
        if new_replica in self._dead:
            raise ValueError(f"replica {new_replica} is dead")
        self.register(new_replica, agent_id, t, cost)
        self.replica_of[agent_id] = new_replica
        return self.virtual_finish.get(agent_id)

    def steal(
        self, agent_id: int, frm: int, to: int, t: float, cost: float
    ) -> Optional[float]:
        """Move a queued, never-admitted agent between LIVE replicas.

        Work stealing's clock surgery.  Unlike :meth:`migrate` alone —
        whose source replica is dead and already pruned by
        :meth:`fail_replica` — stealing leaves the source clock running,
        so the agent's presence there must be withdrawn first: an
        un-replayed buffered arrival is simply dropped; an arrival that
        ``reconcile`` already replayed is deactivated from the source's
        GPS reference at the steal time (its F_j heap entry retires
        harmlessly as V sweeps past — the same mechanics as a think-time
        deactivation, except the agent never returns).  The re-arrival on
        ``to`` then goes through :meth:`migrate`, which keeps any
        recorded ``virtual_finish`` — a steal can never demote (or
        promote) an agent in the fleet-wide pampering order.  Returns the
        carried virtual finish, or ``None`` when the agent's arrival had
        not been reconciled yet.
        """
        if frm in self._dead:
            raise ValueError(f"replica {frm} is dead — use fail_replica")
        dropped = False
        pruned = []
        for entry in self._pending:
            if (
                not dropped
                and entry[2] == frm
                and entry[3] == agent_id
                and entry[5] == "arrive"
            ):
                dropped = True
                continue
            pruned.append(entry)
        if dropped:
            self._pending = pruned
            heapq.heapify(self._pending)
        else:
            # already replayed into frm's clock: withdraw its GPS share
            heapq.heappush(
                self._pending,
                (max(float(t), self._horizon), self._seq, frm, agent_id,
                 0.0, "suspend"),
            )
            self._seq += 1
        return self.migrate(agent_id, to, t, cost)

    def forget(self, agent_id: int) -> None:
        """Drop a COMPLETED agent's reconciled bookkeeping.

        Streaming fleets call this (after the agent's arrival has been
        reconciled — i.e. from :meth:`ReplicatedBackend.compact`) so
        ``virtual_finish`` / ``replica_of`` and the per-clock retired
        sets stay bounded by the in-flight population rather than growing
        O(agents).  The agent thereafter no longer appears in
        ``pampering_order``.
        """
        self.virtual_finish.pop(agent_id, None)
        self.replica_of.pop(agent_id, None)
        for clock in self.clocks:
            clock.forget(agent_id)

    def reconcile(self, until: float) -> GlobalClockSnapshot:
        """Replay arrivals up to ``until`` and advance the live clocks.

        Dead replicas' clocks stay frozen at their failure-time V; the
        global virtual time and lag are taken over live replicas only, so a
        crash does not drag the fleet reference backwards (``virtual_times``
        still reports every replica, frozen values included).
        """
        until = float(until)
        while self._pending and self._pending[0][0] <= until:
            t, _, replica, agent_id, cost, kind = heapq.heappop(self._pending)
            if kind == "suspend":
                self.clocks[replica].deactivate(agent_id, t)
                continue
            if kind == "resume":
                self.clocks[replica].reactivate(agent_id, t)
                continue
            f = self.clocks[replica].on_arrival(agent_id, t, cost)
            # never overwrite: a migrated agent's re-arrival joins the new
            # clock's GPS reference but its recorded F_j is carried over
            self.virtual_finish.setdefault(agent_id, f)
            self.replica_of[agent_id] = replica
        live = self.live_indices
        if not live:
            raise RuntimeError("all replicas are dead")
        for k in live:
            self.clocks[k].advance(until)
        self._horizon = max(self._horizon, until)
        v = tuple(
            c.now(until) if k not in self._dead else c.value
            for k, c in enumerate(self.clocks)
        )
        v_live = [v[k] for k in live]
        return GlobalClockSnapshot(
            time=until,
            virtual_times=v,
            global_virtual_time=min(v_live),
            lag=max(v_live) - min(v_live),
            live=live,
        )

    # NB: reading the global time / lag goes through reconcile(t) — it is
    # deliberately the only accessor, because sweeping the clocks to t
    # advances the registration horizon (a "getter" here would mutate)

    def pampering_order(self) -> list[int]:
        """Fleet-wide Justitia order: ascending reconciled virtual finish."""
        return sorted(
            self.virtual_finish,
            key=lambda aid: (self.virtual_finish[aid], aid),
        )

    def delay_bound(
        self, c_max: float, c_agent_max: float, service_rate: float = 1.0
    ) -> float:
        """Fleet-wide Theorem B.1 bound: worst per-replica bound, in this
        clock's TIME units.

        Per replica the theorem gives ``2*c_max + C_max/M_k`` iterations
        with ``M_k`` in KV-token units.  This clock stores capacities as
        ``M_k * service_rate`` (cost-units per time unit), so pass the
        backend's ``service_rate`` (iterations per time unit — e.g. the
        sim's ``decode_rate`` when the clock runs in workload seconds) to
        recover the pool sizes; the default 1.0 covers clocks built
        directly over pool-token capacities in iteration time.  Every
        agent's real finish trails its *own replica's* GPS reference by at
        most this, so the worst replica bounds the whole fleet.
        Heterogeneous fleets with differing per-child service rates need
        per-replica conversion — compute the bound per child instead.

        Dead replicas are excluded: after a failure the bound is re-derived
        over the surviving capacities (it can only grow, since the worst
        live replica may have less capacity headroom than before).
        """
        r = float(service_rate)
        caps = [self.capacities[k] for k in self.live_indices]
        if not caps:
            raise RuntimeError("all replicas are dead")
        return max(
            (2.0 * float(c_max) + float(c_agent_max) * r / cap) / r
            for cap in caps
        )
