"""``OrderedQueue`` — the shared priority queue of the sim and the engine.

Both backends keep two request queues (waiting, swapped) ordered by the
scheduler's priority key, and both used to pay for that ordering on every
admission pass: a full ``sort(key=...)`` re-invoking the policy once per
element, per pass.  This class factors the PR-1 static-key fast path of the
engine into a backend-neutral structure and extends it to dynamic policies:

* **static policies** (``scheduler.dynamic == False`` — Justitia, FCFS,
  SJF, Parrot): a request's key never changes after submission, so it is
  evaluated exactly once at ``push`` and the queue stays sorted by
  construction (``bisect.insort``); no admission pass ever re-sorts.
* **dynamic policies** (VTC, SRJF), plain mode: keys move with the
  scheduler's service counters, so the queue re-sorts lazily at
  ``refresh`` — but only when it can actually be stale: a new item was
  pushed, or the scheduler's ``version`` mutation counter moved since the
  last sort.  Two admission passes with no intervening service deal or
  arrival share one sort.
* **dynamic policies, grouped mode** (``group_fn`` given): for policies
  whose key depends only on the request and its *agent's* record
  (``scheduler.agent_keyed`` — both built-in dynamic policies qualify),
  the queue stays sorted like the static path and ``refresh`` repositions
  only items whose group was invalidated via ``mark_dirty`` since the
  last pass.  A backlogged queue of W requests with k freshly-serviced
  agents re-sorts in O(k log W) key space instead of O(W log W): queued
  agents with no running inference have frozen counters and never move.

Invariant required of dynamic keys (and satisfied by every built-in
policy): ``request_key(req, t)`` must be a function of the *scheduler's
state* (captured by ``AgentScheduler.version``) and the request alone —
never of the clock ``t`` directly.  A policy whose key decays with wall
time would need ``refresh(version=None)`` (sort every pass) instead.
Grouped mode additionally requires the backend to ``mark_dirty(group)``
for every agent whose record it mutates (each ``on_service`` deal and each
arrival); ``push`` self-marks its own group.

``sorts`` and ``key_evals`` are exposed so backends can surface scheduling
overhead (``metrics["sorts"]``, ``SimResult.key_evals``) without wrapping
the policy object.
"""

from __future__ import annotations

import bisect
from typing import Any, Callable, Iterator, Optional

__all__ = ["OrderedQueue"]


class OrderedQueue:
    """Priority queue with cached keys and lazy re-sorting (see module doc).

    ``key_fn`` maps an item to its (totally ordered — include a tie-break
    like ``rid``) sort key; it is the only place the scheduler policy is
    invoked.  Lower key = served first; ``peek``/``popleft`` address the
    head.  ``refresh`` must be called before reading the head under a
    dynamic policy (it is a no-op for static ones).
    """

    def __init__(
        self,
        key_fn: Callable[[Any], Any],
        *,
        dynamic: bool = False,
        group_fn: Optional[Callable[[Any], Any]] = None,
    ):
        self.key_fn = key_fn
        self.dynamic = bool(dynamic)
        self.group_fn = group_fn if dynamic else None
        # _items[_head:] is the live queue; popleft advances _head (O(1))
        # and the dead prefix is compacted away once it dominates —
        # a plain list.pop(0) would memmove the whole backlog per admission
        self._items: list[Any] = []
        self._keys: list[Any] = []        # parallel to _items (sorted modes)
        self._head = 0
        self._dirty = False               # plain dynamic: pushed since sort
        self._dirty_groups: set[Any] = set()
        self._group_items: dict[Any, list[Any]] = {}
        self._item_key: dict[int, Any] = {}   # id(item) -> cached key
        self._last_version: Optional[int] = None
        self.sorts = 0                    # executed re-sorts/repositionings
        self.key_evals = 0                # policy key invocations

    # ------------------------------------------------------------- basics

    @property
    def grouped(self) -> bool:
        return self.group_fn is not None

    def __len__(self) -> int:
        return len(self._items) - self._head

    def __bool__(self) -> bool:
        return len(self._items) > self._head

    def __iter__(self) -> Iterator[Any]:
        return iter(self._items[self._head:])

    # ------------------------------------------------------------ updates

    def _compact(self) -> None:
        if self._head:
            del self._items[: self._head]
            if self._keys:
                del self._keys[: self._head]
            self._head = 0

    def _insort(self, item: Any, key: Any) -> None:
        i = bisect.bisect_right(self._keys, key, self._head)
        self._keys.insert(i, key)
        self._items.insert(i, item)

    def push(self, item: Any) -> None:
        if self.dynamic and not self.grouped:
            self._items.append(item)
            self._dirty = True
            return
        key = self.key_fn(item)
        self.key_evals += 1
        self._insort(item, key)
        # sorted modes cache every item's key: refresh uses it to extract
        # stale grouped items, remove() uses it to locate an arbitrary item
        # in O(log n) instead of a linear scan
        self._item_key[id(item)] = key
        if self.grouped:
            g = self.group_fn(item)
            self._group_items.setdefault(g, []).append(item)
            # the key was sampled at push time; revalidate at next refresh
            # in case the group's counters move before the next decision
            self._dirty_groups.add(g)

    def mark_dirty(self, group: Any) -> None:
        """Grouped mode: ``group``'s keys may have moved (no-op otherwise)."""
        if self.grouped and group in self._group_items:
            self._dirty_groups.add(group)

    def mark_dirty_many(self, groups: set) -> None:
        """Bulk ``mark_dirty`` (set intersection, C-speed)."""
        if self.grouped:
            self._dirty_groups.update(groups & self._group_items.keys())

    def refresh(self, version: Optional[int] = None) -> None:
        """Bring the queue into key order for the next admission pass.

        ``version`` is the scheduler's mutation counter (plain dynamic
        mode); passing the same value twice with no pushes in between skips
        the sort — the keys cannot have moved.  Grouped mode ignores it and
        repositions exactly the items whose group was marked dirty.
        """
        if not self.dynamic:
            return
        if self.grouped:
            self._refresh_grouped()
            return
        if (
            not self._dirty
            and version is not None
            and version == self._last_version
        ):
            return
        self._dirty = False
        self._last_version = version
        self._compact()
        n = len(self._items)
        if n <= 1:
            return
        keys = [self.key_fn(it) for it in self._items]
        self.key_evals += n
        order = sorted(range(n), key=keys.__getitem__)   # stable
        self._items = [self._items[i] for i in order]
        self.sorts += 1

    def _refresh_grouped(self) -> None:
        if not self._dirty_groups:
            return
        moved: list[Any] = []
        for g in self._dirty_groups:
            moved.extend(self._group_items.get(g, ()))
        self._dirty_groups.clear()
        if not moved:
            return
        # two-phase: extract every stale item at its cached key, then
        # re-insert at the fresh one (the untouched remainder stays sorted)
        for item in moved:
            old_key = self._item_key[id(item)]
            i = bisect.bisect_left(self._keys, old_key, self._head)
            while self._items[i] is not item:
                i += 1
            del self._keys[i]
            del self._items[i]
        for item in moved:
            key = self.key_fn(item)
            self.key_evals += 1
            self._item_key[id(item)] = key
            self._insort(item, key)
        self.sorts += 1

    def peek(self) -> Any:
        return self._items[self._head]

    def peek_right(self) -> Any:
        """Item with the WORST key (sorted modes: the tail).

        Call ``refresh`` first under a dynamic policy — exactly as for
        ``peek`` — or the tail may be stale.  This is what backends use for
        swap-victim selection: the running set ordered by scheduler key has
        its eviction candidate at the right end.
        """
        if self._head >= len(self._items):
            # guard explicitly: when a popleft'd (tombstoned) prefix has
            # not been compacted yet, _items[-1] would silently return a
            # dead None slot instead of raising
            raise IndexError("peek_right from empty OrderedQueue")
        return self._items[-1]

    def pop_right(self) -> Any:
        """Remove and return the worst-key item (see ``peek_right``)."""
        if self._head >= len(self._items):
            raise IndexError("pop_right from empty OrderedQueue")
        item = self._items.pop()
        if not self.dynamic or self.grouped:
            self._keys.pop()
        self._forget(item)
        return item

    def popleft(self) -> Any:
        head = self._head
        item = self._items[head]
        self._items[head] = None          # drop the reference
        if not self.dynamic or self.grouped:
            self._keys[head] = None
        self._head = head + 1
        if self._head > 32 and self._head * 2 > len(self._items):
            self._compact()
        self._forget(item)
        return item

    def remove(self, item: Any) -> None:
        """Remove ``item`` (identity comparison) from anywhere in the queue.

        Sorted modes locate it through its cached key — O(log n) bisect
        plus a scan over equal-key siblings (built-in policies tie-break on
        ``rid``, so keys are unique and the scan is O(1)).  Plain dynamic
        mode has no key cache and falls back to a linear identity scan.
        Backends use this to retire a running-set entry on completion.
        """
        if self.dynamic and not self.grouped:
            for i in range(self._head, len(self._items)):
                if self._items[i] is item:
                    del self._items[i]
                    return
            raise ValueError("item not in queue")
        key = self._item_key[id(item)]
        i = bisect.bisect_left(self._keys, key, self._head)
        # identity scan over equal-key siblings (cf. popleft: __eq__ on
        # items is not usable — fields like numpy prompts don't compare)
        while self._items[i] is not item:
            i += 1
        del self._keys[i]
        del self._items[i]
        self._forget(item)

    def _forget(self, item: Any) -> None:
        """Drop the key cache / group bookkeeping of a removed item."""
        if not self.dynamic or self.grouped:
            self._item_key.pop(id(item), None)
        if self.grouped:
            g = self.group_fn(item)
            bucket = self._group_items[g]
            # identity-based removal: list.remove would run __eq__ against
            # same-group siblings, whose fields need not be comparable
            # (e.g. numpy prompt arrays on engine requests)
            for i, x in enumerate(bucket):
                if x is item:
                    del bucket[i]
                    break
            if not bucket:
                del self._group_items[g]
                self._dirty_groups.discard(g)

    def head_key(self) -> Any:
        """Cached key of the head (sorted modes only)."""
        if self.dynamic and not self.grouped:
            raise TypeError("plain dynamic OrderedQueue does not cache keys")
        return self._keys[self._head]

    def clear(self) -> None:
        self._items.clear()
        self._keys.clear()
        self._head = 0
        self._dirty = False
        self._dirty_groups.clear()
        self._group_items.clear()
        self._item_key.clear()
