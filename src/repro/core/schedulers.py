"""Agent-level schedulers: Justitia (the paper) and the five baselines.

The same scheduler objects drive both the discrete-event cluster simulator
(`repro.sim`) and the real continuous-batching engine (`repro.engine`) — the
policy code is identical, only the backend differs.

Contract
--------
The backend notifies the scheduler of agent arrivals/completions and of
service as it is dealt, and asks for a *priority key* per pending request
whenever it makes an admission (or swap-victim) decision.  Lower key = served
first.  Keys may be dynamic (VTC, SRJF) and are therefore recomputed at every
scheduling decision; Justitia's key is static by construction (the one-shot
virtual finish time).

Non-preemption (paper §4.3 + App. C) is enforced by the *backend*: a waiting
request never preempts a running inference; swapping happens only on memory
pressure, evicting the running request with the *worst* key.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

from repro.core.cost import InferenceSpec
from repro.core.registry import (
    register_scheduler,
    resolve_scheduler,
    scheduler_names,
)
from repro.core.virtual_time import VirtualClock


@dataclasses.dataclass
class Request:
    """One inference task as seen by the scheduler/backend."""

    agent_id: int
    rid: int                      # globally unique, monotone with submit order
    spec: InferenceSpec
    submit_time: float
    pred_cost: float = 0.0        # predicted inference-level KV token-time
    #: expected cached-prefix length (tokens) for this request's prompt —
    #: a STATIC workload hint (shared system prefix / conversation
    #: history), not a live cache probe: scheduler keys must stay stable
    #: between ``version`` bumps, so they must not query the allocator
    cached_prefix: float = 0.0

    # runtime state owned by the backend
    decoded: int = 0              # decode tokens produced so far


@dataclasses.dataclass
class AgentRecord:
    agent_id: int
    arrival: float
    predicted_cost: float         # predicted agent-level cost (model units)
    virtual_finish: float = float("inf")   # Justitia F_j
    serviced_kv: float = 0.0      # accumulated KV token-time service
    serviced_vtc: float = 0.0     # accumulated VTC-weighted token service
    completed: bool = False


class AgentScheduler:
    """Base class; default key is inference-level FCFS."""

    name = "base"
    #: whether this scheduler's admission key depends on runtime state
    dynamic = False
    #: dynamic policies only: True iff ``request_key`` reads nothing beyond
    #: the request and its own agent's record — then a queued request's key
    #: can only move when that agent is serviced, and backends may keep
    #: queues sorted and reposition just the serviced agents' requests
    #: (``repro.core.OrderedQueue`` grouped mode) instead of re-sorting
    agent_keyed = False

    def __init__(self) -> None:
        self.agents: dict[int, AgentRecord] = {}
        #: mutation counter: bumped whenever scheduler state that keys may
        #: read changes (arrivals, completions, service deals).  Backends
        #: pass it to ``repro.core.OrderedQueue.refresh`` so dynamic-policy
        #: queues re-sort only when keys can actually have moved.  Keys must
        #: not depend on the clock ``t`` directly (see queueing module doc).
        self.version = 0

    # -- lifecycle ----------------------------------------------------------

    def on_agent_arrival(self, agent_id: int, t: float, predicted_cost: float) -> None:
        self.agents[agent_id] = AgentRecord(agent_id, t, float(predicted_cost))
        self.version += 1

    def on_agent_complete(self, agent_id: int, t: float) -> None:
        rec = self.agents.get(agent_id)
        if rec is not None:
            rec.completed = True
        self.version += 1

    def on_agent_cancel(self, agent_id: int, t: float) -> None:
        """The agent was withdrawn before any of its requests ran (fleet
        work stealing, PR 10).  Default: the completion cleanup — the
        record is marked done so dynamic policies stop considering it.
        Policies that registered the agent in auxiliary state at arrival
        (Justitia's GPS clock) override to undo that registration too."""
        self.on_agent_complete(agent_id, t)

    def on_agent_suspend(self, agent_id: int, t: float) -> None:
        """The agent entered think time (PR 9): it holds no decode slot
        until the matching :meth:`on_agent_resume`.  Default: no-op —
        the stock policies key on arrival-anchored or service-accrued
        state, neither of which a suspension moves."""

    def on_agent_resume(self, agent_id: int, t: float) -> None:
        """Think time ended; the agent's next stage was submitted."""

    def on_service(
        self,
        agent_id: int,
        *,
        kv_token_time: float = 0.0,
        prefill_tokens: float = 0.0,
        decode_tokens: float = 0.0,
        w_p: float = 1.0,
        w_d: float = 2.0,
    ) -> None:
        rec = self.agents.get(agent_id)
        if rec is None:
            return
        rec.serviced_kv += kv_token_time
        rec.serviced_vtc += w_p * prefill_tokens + w_d * decode_tokens
        self.version += 1

    # -- the decision -------------------------------------------------------

    def request_key(self, req: Request, t: float) -> tuple:
        return (req.submit_time, req.rid)

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, total_kv: float, service_rate: float = 1.0) -> "AgentScheduler":
        """Uniform constructor used by the registry-backed factory; policies
        that need backend capacity parameters (Justitia) override this."""
        return cls()


@register_scheduler("vllm-fcfs", "vllm", "fcfs")
class VllmFcfsScheduler(AgentScheduler):
    """Baseline (a): vLLM — inference-level First-Come-First-Serve."""

    name = "vllm-fcfs"


@register_scheduler("vllm-sjf", "sjf")
class VllmSjfScheduler(AgentScheduler):
    """Baseline (b): vLLM-SJF — inference-level Shortest-Job-First using the
    per-inference predicted cost (the paper uses DistilBERT-predicted
    durations; we feed it the same predictor output as everyone else)."""

    name = "vllm-sjf"

    def request_key(self, req: Request, t: float) -> tuple:
        return (req.pred_cost, req.submit_time, req.rid)


@register_scheduler("parrot", "agent-fcfs")
class ParrotScheduler(AgentScheduler):
    """Baseline (c): Parrot — agent-level FCFS (all inferences of the
    earliest-arrived agent served consecutively)."""

    name = "parrot"

    def request_key(self, req: Request, t: float) -> tuple:
        rec = self.agents[req.agent_id]
        return (rec.arrival, rec.agent_id, req.rid)


@register_scheduler("vtc")
class VtcScheduler(AgentScheduler):
    """Baseline (d): Virtual Token Counter (Sheng et al., OSDI'24).

    Tracks the weighted token service each agent has received and always
    admits from the agent with the smallest counter — approximating
    instantaneous fair sharing.  On arrival of an agent during a backlogged
    period its counter is lifted to the minimum over active agents
    (the paper's 'counter lift' that prevents gaming by idling).

    The lift is O(log n) amortized via a lazy min-heap of *lower bounds*
    (the original VTC paper ships an O(log n) counter for exactly this
    reason): each live agent keeps one ``(counter, agent_id)`` entry,
    pushed at arrival.  Counters only grow, so an entry is always a lower
    bound on its agent's current counter; when the heap top is stale it is
    ``heapreplace``-refreshed in place, and when the top matches its
    agent's live counter that value IS the minimum.  Service deals never
    touch the heap — the refresh work collapses into the next lift.  A
    linear scan per arrival made the lift O(n²) across a backlogged
    workload.
    """

    name = "vtc"
    dynamic = True
    agent_keyed = True

    def __init__(self) -> None:
        super().__init__()
        self._min_heap: list[tuple[float, int]] = []  # (lower bound, aid)

    def _min_live(self) -> Optional[float]:
        """Smallest ``serviced_vtc`` over live agents (lazy lower bounds)."""
        heap = self._min_heap
        agents = self.agents
        while heap:
            v, aid = heap[0]
            rec = agents.get(aid)
            if rec is None or rec.completed:
                heapq.heappop(heap)
                continue
            current = rec.serviced_vtc
            if current == v:
                # v is a true live counter and every other entry is a
                # lower bound of its own (>= v) counter: v is the min
                return v
            heapq.heapreplace(heap, (current, aid))
        return None

    def on_agent_arrival(self, agent_id: int, t: float, predicted_cost: float) -> None:
        super().on_agent_arrival(agent_id, t, predicted_cost)
        lifted = self._min_live()
        rec = self.agents[agent_id]
        if lifted is not None:
            rec.serviced_vtc = lifted
        heapq.heappush(self._min_heap, (rec.serviced_vtc, agent_id))

    def request_key(self, req: Request, t: float) -> tuple:
        rec = self.agents[req.agent_id]
        return (rec.serviced_vtc, rec.arrival, req.rid)


@register_scheduler("srjf")
class SrjfScheduler(AgentScheduler):
    """Baseline (e): Shortest-Remaining-Job-First at the *agent* level, on
    the same predicted KV token-time costs Justitia uses."""

    name = "srjf"
    dynamic = True
    agent_keyed = True

    def request_key(self, req: Request, t: float) -> tuple:
        rec = self.agents[req.agent_id]
        remaining = max(0.0, rec.predicted_cost - rec.serviced_kv)
        return (remaining, rec.arrival, req.rid)


@register_scheduler("justitia")
class JustitiaScheduler(AgentScheduler):
    """The paper: virtual-time fair queuing with selective pampering.

    On agent arrival we compute, one-shot, its GPS virtual finish time
    F_j = V(a_j) + C_j (predicted) and use ascending F_j as a *static*
    agent priority; all inferences of the pampered agent run consecutively
    and saturate the backend.  Theorem B.1 bounds the worst-case delay vs
    GPS by 2*c_max + C_max/M.
    """

    name = "justitia"

    def __init__(self, total_kv: float, service_rate: float = 1.0):
        """``total_kv``: pool size M in KV-token units (the paper's M).

        ``service_rate``: how many decode iterations the backend completes
        per unit of real time (tokens/s per running sequence).  The GPS
        virtual clock must advance at the backend's *service capacity*
        M * service_rate in KV-token-time per second — the cost model's
        units are token·iterations while wall time is seconds (Eq. 2 is
        stated with time measured in iterations; this converts it).
        """
        super().__init__()
        self.clock = VirtualClock(total_kv * service_rate)

    def on_agent_arrival(self, agent_id: int, t: float, predicted_cost: float) -> None:
        super().on_agent_arrival(agent_id, t, predicted_cost)
        f = self.clock.on_arrival(agent_id, t, predicted_cost)
        self.agents[agent_id].virtual_finish = f

    def on_agent_complete(self, agent_id: int, t: float) -> None:
        super().on_agent_complete(agent_id, t)
        self.clock.advance(t)

    def on_agent_cancel(self, agent_id: int, t: float) -> None:
        # a stolen agent leaves WITHOUT service: pull it out of the GPS
        # reference so it stops depressing V's rate for the agents that
        # stay (its F_j heap entry retires harmlessly as V sweeps past)
        super().on_agent_cancel(agent_id, t)
        self.clock.deactivate(agent_id, t)

    def request_key(self, req: Request, t: float) -> tuple:
        rec = self.agents[req.agent_id]
        return (rec.virtual_finish, rec.arrival, req.rid)

    @classmethod
    def build(cls, total_kv: float, service_rate: float = 1.0) -> "JustitiaScheduler":
        return cls(total_kv, service_rate)


@register_scheduler("locality_fair")
class LocalityFairScheduler(VtcScheduler):
    """Deficit-bounded longest-prefix-match scheduling (PR 6).

    *Locality-aware Fair Scheduling in LLM Serving* (PAPERS.md) shows the
    two pure extremes both fail on conversational workloads: strict fair
    queuing (VTC/Justitia order) interleaves agents and destroys prefix-
    cache locality, while pure longest-prefix-match starves cold agents.
    This policy serves the best cache-locality candidate — highest
    expected cached-prefix fraction, from the static workload hint on
    each request — *unless* the candidate agent's fairness deficit
    exceeds ``deficit_bound``, at which point it falls behind every
    in-bound agent and the order degrades to Justitia's virtual-finish
    fair queue.

    The deficit is measured in VTC service units: ``serviced_vtc`` minus
    the minimum over live agents (VTC's lazy O(log n) min-heap, reused).
    An over-served agent keeps its locality bonus only while within
    ``deficit_bound`` of the most-starved agent, so the max extra delay
    any agent can suffer to locality is the time to deal
    ``deficit_bound`` service units — the bounded-pampering knob the
    BENCH cells sweep.  The default bound is ONE pool capacity of
    service: a multi-turn session accumulates service of the same order
    as the pool itself, so a materially tighter bound (e.g. half a
    pool) trips mid-session under contention and collapses the order to
    plain fair queuing — BENCH_cache's deficit sweep shows the hit rate
    degrading from the pure-LPM ceiling toward VTC's as the bound
    shrinks below one pool.

    ``dynamic=True`` and ``agent_keyed=False`` per the OrderedQueue
    contract: the key reads the GLOBAL min counter, so one agent's
    service deal can move every queued request's key — backends re-sort
    lazily when ``version`` moves, not per-agent.
    """

    name = "locality_fair"
    dynamic = True
    agent_keyed = False

    def __init__(self, total_kv: float, service_rate: float = 1.0,
                 deficit_bound: Optional[float] = None):
        super().__init__()
        self.clock = VirtualClock(total_kv * service_rate)
        #: max VTC-service lead an agent may hold and still keep its
        #: locality bonus; defaults to one pool's KV-token capacity of
        #: service (see the class docstring for why tighter bounds
        #: collapse to fair queuing on multi-turn sessions)
        self.deficit_bound = (
            float(total_kv) if deficit_bound is None
            else float(deficit_bound)
        )

    def on_agent_arrival(self, agent_id: int, t: float,
                         predicted_cost: float) -> None:
        super().on_agent_arrival(agent_id, t, predicted_cost)  # VTC lift
        f = self.clock.on_arrival(agent_id, t, predicted_cost)
        self.agents[agent_id].virtual_finish = f

    def on_agent_complete(self, agent_id: int, t: float) -> None:
        super().on_agent_complete(agent_id, t)
        self.clock.advance(t)

    def request_key(self, req: Request, t: float) -> tuple:
        rec = self.agents[req.agent_id]
        m = self._min_live()
        deficit = rec.serviced_vtc - (m if m is not None else 0.0)
        over = 1 if deficit > self.deficit_bound else 0
        frac = min(
            1.0, req.cached_prefix / max(1.0, float(req.spec.prefill))
        )
        return (over, -frac, rec.virtual_finish, rec.arrival, req.rid)

    @classmethod
    def build(cls, total_kv: float,
              service_rate: float = 1.0) -> "LocalityFairScheduler":
        return cls(total_kv, service_rate)


def make_scheduler(
    name: str, total_kv: float, service_rate: float = 1.0
) -> AgentScheduler:
    """Factory used by the simulator, the engine, and the benchmarks.

    Resolves ``name`` through the plugin registry
    (``repro.core.registry``); any policy decorated with
    ``@register_scheduler`` — including ones defined outside this module —
    is constructible here.  ``service_rate`` (decode iterations per second)
    only matters for Justitia's virtual clock; see
    ``JustitiaScheduler.__init__``.
    """
    return resolve_scheduler(name).build(total_kv, service_rate)


def __getattr__(attr: str):
    # ALL_SCHEDULERS is derived from the registry at access time so that
    # policies registered after this module imported still show up.
    if attr == "ALL_SCHEDULERS":
        return scheduler_names()
    raise AttributeError(f"module {__name__!r} has no attribute {attr!r}")
