"""Scheduler plugin registry and the typed ``SchedulerPolicy`` contract.

The backend/scheduler contract that ``AgentScheduler`` implied informally is
formalized here as a ``typing.Protocol``: a policy is anything that accepts
agent arrival/completion/service notifications and answers ``request_key``
queries.  Policies register themselves by name::

    @register_scheduler("justitia")
    class JustitiaScheduler(AgentScheduler):
        ...

and every consumer — the simulator, the engine, ``AgentService``, the
benchmarks — resolves names through :func:`resolve_scheduler` /
``make_scheduler`` instead of a hard-coded if-chain.  ``ALL_SCHEDULERS`` is
derived from the registry, so a policy added by a plugin module shows up in
sweeps automatically.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Protocol, runtime_checkable

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.schedulers import Request


@runtime_checkable
class SchedulerPolicy(Protocol):
    """What a backend requires of a scheduling policy.

    Lifecycle: the backend calls ``on_agent_arrival`` exactly once per agent,
    ``on_service`` as service is dealt, and ``on_agent_complete`` once when
    the agent's last inference finishes.  Decisions: ``request_key`` returns
    a totally-ordered key (lower = served first) for a pending request at
    time ``t``; it must be pure (no state mutation).  ``dynamic`` declares
    whether keys can change between calls with identical arguments — static
    policies (``dynamic = False``) allow backends to keep their queues
    incrementally sorted instead of re-sorting at every decision.

    Two OPTIONAL performance attributes (not required members of this
    protocol — the backends degrade gracefully via ``getattr`` when they
    are absent, and ``AgentScheduler`` subclasses get both for free):
    ``version`` is a mutation counter gating queue re-sorts under dynamic
    policies — bump it whenever state that ``request_key`` reads changes;
    absent, dirty queues re-sort every admission pass.  ``agent_keyed``
    declares that a dynamic key reads nothing beyond the request and its
    own agent's record, unlocking grouped queue invalidation (see
    ``repro.core.queueing`` and ROADMAP "Scheduler-plugin invariants");
    absent, it is taken as False.
    """

    name: str
    dynamic: bool

    def on_agent_arrival(
        self, agent_id: int, t: float, predicted_cost: float
    ) -> None: ...

    def on_agent_complete(self, agent_id: int, t: float) -> None: ...

    def on_service(
        self,
        agent_id: int,
        *,
        kv_token_time: float = 0.0,
        prefill_tokens: float = 0.0,
        decode_tokens: float = 0.0,
        w_p: float = 1.0,
        w_d: float = 2.0,
    ) -> None: ...

    def request_key(self, req: "Request", t: float) -> tuple: ...


_REGISTRY: dict[str, type] = {}
_ALIASES: dict[str, str] = {}


def register_scheduler(name: str, *aliases: str):
    """Class decorator: register a :class:`SchedulerPolicy` under ``name``.

    ``name`` becomes the canonical entry (listed by :func:`scheduler_names`);
    ``aliases`` resolve to the same class but are not listed.  Registering a
    duplicate canonical name or alias raises ``ValueError`` so two plugins
    cannot silently shadow each other.
    """

    canonical = name.lower()

    def deco(cls: type) -> type:
        # validate every name before mutating anything, so a collision
        # cannot leave a half-registered plugin behind
        if canonical in _REGISTRY or canonical in _ALIASES:
            raise ValueError(f"scheduler {canonical!r} already registered")
        lowered = [a.lower() for a in aliases]
        for alias in lowered:
            if alias in _REGISTRY or alias in _ALIASES:
                raise ValueError(f"scheduler alias {alias!r} already taken")
        _REGISTRY[canonical] = cls
        cls.name = canonical
        for alias in lowered:
            _ALIASES[alias] = canonical
        return cls

    return deco


def unregister_scheduler(name: str) -> None:
    """Remove a canonical registration and its aliases (test plumbing)."""
    canonical = name.lower()
    _REGISTRY.pop(canonical, None)
    for alias in [a for a, c in _ALIASES.items() if c == canonical]:
        del _ALIASES[alias]


def resolve_scheduler(name: str) -> type:
    """Name (or alias) -> registered policy class; ValueError if unknown."""
    key = name.lower()
    key = _ALIASES.get(key, key)
    try:
        return _REGISTRY[key]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise ValueError(
            f"unknown scheduler {name!r} (registered: {known})"
        ) from None


def scheduler_names() -> list[str]:
    """Canonical names in registration order (drives benchmark sweeps)."""
    return list(_REGISTRY)
