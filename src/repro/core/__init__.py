"""Justitia core: memory-centric cost model, virtual-time fair queuing,
selective-pampering scheduler, and the baseline schedulers (paper §4)."""

from repro.core.cost import (
    InferenceSpec,
    MemoryFamily,
    agent_cost,
    encdec_kv_token_time,
    hybrid_kv_token_time,
    inference_cost,
    kv_token_time,
    ssm_token_time,
    swa_kv_token_time,
    vtc_agent_cost,
    vtc_cost,
)
from repro.core.gps import GpsAgent, gps_finish_times, gps_finish_times_fluid
from repro.core.queueing import OrderedQueue
from repro.core.registry import (
    SchedulerPolicy,
    register_scheduler,
    resolve_scheduler,
    scheduler_names,
    unregister_scheduler,
)
from repro.core.schedulers import (
    AgentRecord,
    AgentScheduler,
    JustitiaScheduler,
    ParrotScheduler,
    Request,
    SrjfScheduler,
    VllmFcfsScheduler,
    VllmSjfScheduler,
    VtcScheduler,
    make_scheduler,
)
from repro.core.virtual_time import (
    GlobalClockSnapshot,
    GlobalVirtualClock,
    VirtualClock,
)


def __getattr__(attr: str):
    # live view of the registry (see repro.core.schedulers.__getattr__)
    if attr == "ALL_SCHEDULERS":
        return scheduler_names()
    raise AttributeError(f"module {__name__!r} has no attribute {attr!r}")


__all__ = [
    "InferenceSpec",
    "MemoryFamily",
    "agent_cost",
    "encdec_kv_token_time",
    "hybrid_kv_token_time",
    "inference_cost",
    "kv_token_time",
    "ssm_token_time",
    "swa_kv_token_time",
    "vtc_agent_cost",
    "vtc_cost",
    "GpsAgent",
    "gps_finish_times",
    "gps_finish_times_fluid",
    "OrderedQueue",
    "ALL_SCHEDULERS",
    "AgentRecord",
    "AgentScheduler",
    "JustitiaScheduler",
    "ParrotScheduler",
    "Request",
    "SrjfScheduler",
    "VllmFcfsScheduler",
    "VllmSjfScheduler",
    "VtcScheduler",
    "make_scheduler",
    "SchedulerPolicy",
    "register_scheduler",
    "resolve_scheduler",
    "scheduler_names",
    "unregister_scheduler",
    "GlobalClockSnapshot",
    "GlobalVirtualClock",
    "VirtualClock",
]
