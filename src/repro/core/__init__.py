"""Justitia core: memory-centric cost model, virtual-time fair queuing,
selective-pampering scheduler, and the baseline schedulers (paper §4)."""

from repro.core.cost import (
    InferenceSpec,
    MemoryFamily,
    agent_cost,
    encdec_kv_token_time,
    hybrid_kv_token_time,
    inference_cost,
    kv_token_time,
    ssm_token_time,
    swa_kv_token_time,
    vtc_agent_cost,
    vtc_cost,
)
from repro.core.gps import GpsAgent, gps_finish_times
from repro.core.schedulers import (
    ALL_SCHEDULERS,
    AgentRecord,
    AgentScheduler,
    JustitiaScheduler,
    ParrotScheduler,
    Request,
    SrjfScheduler,
    VllmFcfsScheduler,
    VllmSjfScheduler,
    VtcScheduler,
    make_scheduler,
)
from repro.core.virtual_time import VirtualClock

__all__ = [
    "InferenceSpec",
    "MemoryFamily",
    "agent_cost",
    "encdec_kv_token_time",
    "hybrid_kv_token_time",
    "inference_cost",
    "kv_token_time",
    "ssm_token_time",
    "swa_kv_token_time",
    "vtc_agent_cost",
    "vtc_cost",
    "GpsAgent",
    "gps_finish_times",
    "ALL_SCHEDULERS",
    "AgentRecord",
    "AgentScheduler",
    "JustitiaScheduler",
    "ParrotScheduler",
    "Request",
    "SrjfScheduler",
    "VllmFcfsScheduler",
    "VllmSjfScheduler",
    "VtcScheduler",
    "make_scheduler",
    "VirtualClock",
]
