"""Meta-tests for the in-repo hypothesis fallback (tests/_minihyp.py).

These guard the guarantee the satellite work relies on: property bodies
actually EXECUTE (the old stub skipped them), generation is deterministic
across runs, bounds are respected, and a failing property surfaces the
falsifying example.  The shared contracts run under the real hypothesis
too; determinism-across-calls is minihyp-specific (real hypothesis
deliberately varies examples between runs) and is skipped there.
"""

import hypothesis
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

# conftest installs tests/_minihyp.py under the "hypothesis" name when the
# real package is absent; its module __name__ tells the two apart
IS_MINIHYP = getattr(hypothesis, "__name__", "") == "_minihyp"


def test_given_runs_the_body():
    runs = []

    @given(st.integers(0, 10))
    @settings(max_examples=7, deadline=None)
    def prop(x):
        runs.append(x)
        assert 0 <= x <= 10

    prop()
    assert len(runs) >= 7


@pytest.mark.skipif(
    not IS_MINIHYP,
    reason="real hypothesis varies examples across runs by design",
)
def test_generation_is_deterministic_across_calls():
    seen: list[list] = []

    @given(st.lists(st.tuples(st.integers(0, 100), st.floats(0.0, 1.0)),
                    min_size=1, max_size=5))
    @settings(max_examples=10, deadline=None)
    def prop(xs):
        seen.append(xs)

    prop()
    first = list(seen)
    seen.clear()
    prop()
    assert seen == first


def test_bounds_and_kwargs_strategies():
    @given(p=st.integers(3, 9), f=st.floats(min_value=-2.0, max_value=2.0),
           c=st.sampled_from(["a", "b"]))
    @settings(max_examples=30, deadline=None)
    def prop(p, f, c):
        assert 3 <= p <= 9
        assert -2.0 <= f <= 2.0
        assert c in ("a", "b")

    prop()


def test_failing_property_raises():
    @given(st.integers(0, 1000))
    @settings(max_examples=50, deadline=None)
    def prop(x):
        assert x < 500  # falsified at ~even odds per draw

    with pytest.raises(AssertionError):
        prop()
