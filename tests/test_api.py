"""Tests for the unified serving API: scheduler registry, online arrivals,
engine-vs-simulator equivalence through AgentService, and the engine's
static-key queue fast path / stall diagnostics."""

import jax
import numpy as np
import pytest

import repro.core as core
import repro.core.schedulers as schedulers_mod
from repro.api import (
    AgentHooks,
    AgentService,
    AgentSpec,
    EngineBackend,
    SimBackend,
)
from repro.configs import get_config
from repro.core import (
    AgentScheduler,
    InferenceSpec,
    SchedulerPolicy,
    make_scheduler,
    register_scheduler,
    scheduler_names,
    unregister_scheduler,
)
from repro.engine import EngineAgent, EngineStalledError, ServeEngine
from repro.models import Model

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("granite-3-2b").reduced(vocab=VOCAB)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# -------------------------------------------------------------- registry


def test_registry_registration_lookup_and_all_schedulers():
    @register_scheduler("test-rr", "rr-alias")
    class _RoundRobin(AgentScheduler):
        pass

    try:
        s = make_scheduler("test-rr", 10.0)
        assert isinstance(s, _RoundRobin)
        assert s.name == "test-rr"
        assert isinstance(s, SchedulerPolicy)
        # aliases resolve but are not listed
        assert isinstance(make_scheduler("rr-alias", 10.0), _RoundRobin)
        assert "rr-alias" not in scheduler_names()
        # ALL_SCHEDULERS is auto-derived from the registry, live
        assert "test-rr" in scheduler_names()
        assert "test-rr" in core.ALL_SCHEDULERS
        assert "test-rr" in schedulers_mod.ALL_SCHEDULERS
    finally:
        unregister_scheduler("test-rr")
    assert "test-rr" not in core.ALL_SCHEDULERS
    with pytest.raises(ValueError):
        make_scheduler("test-rr", 10.0)


def test_registry_unknown_name_and_duplicates():
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("nope", 1.0)
    with pytest.raises(ValueError, match="already registered"):

        @register_scheduler("justitia")
        class _Shadow(AgentScheduler):
            pass


def test_all_schedulers_canonical_order():
    assert core.ALL_SCHEDULERS == [
        "vllm-fcfs", "vllm-sjf", "parrot", "vtc", "srjf", "justitia",
    ]


def test_builtin_schedulers_satisfy_policy_protocol():
    for name in core.ALL_SCHEDULERS:
        assert isinstance(make_scheduler(name, 100.0), SchedulerPolicy)


# ------------------------------------------- engine/sim order equivalence

# Sequential-contention workload: the pool fits exactly one inference at a
# time on both backends (p=33 > remaining free while anything runs), so the
# completion order is exactly the scheduler's key order at each completion
# — observable identically through the engine and the simulator.
_EQUIV = [  # (arrival_s, decode)
    (0.0, 16),
    (2.0, 8),
    (4.0, 12),
    (6.0, 4),
]


def _equiv_specs():
    return [
        AgentSpec(stages=[[InferenceSpec(33, d)]], arrival=t)
        for t, d in _EQUIV
    ]


def _completion_order(jct_finish: dict) -> list:
    return [aid for aid, _ in sorted(jct_finish.items(), key=lambda kv: kv[1])]


@pytest.mark.parametrize("sched_name", ["justitia", "vtc"])
def test_online_arrivals_same_completion_order_engine_vs_sim(
    tiny_model, sched_name
):
    model, params = tiny_model
    sim_svc = AgentService(
        SimBackend(
            sched_name, total_kv=64.0, decode_rate=1.0, prefill_rate=33.0
        )
    )
    sim_svc.submit_many(_equiv_specs())
    sim_res = sim_svc.drain()

    eng_svc = AgentService(
        EngineBackend(
            model, params, sched_name,
            pool_tokens=64, block_size=16, max_batch=4, cache_len=64,
            token_scale=1, time_scale=1.0,
        )
    )
    # online: agents enter the engine's pending heap with future arrival
    # iterations and are released mid-run, not submitted upfront
    eng_svc.submit_many(_equiv_specs())
    assert eng_svc.backend.engine.pending, "future arrivals should be pending"
    eng_res = eng_svc.drain()

    assert set(sim_res.finish) == set(eng_res.finish) == {0, 1, 2, 3}
    assert _completion_order(sim_res.finish) == _completion_order(
        eng_res.finish
    ), f"order diverged under {sched_name}"
    # no swap divergence: this workload must be swap-free on both backends
    assert sim_res.swaps == 0 and eng_res.swaps == 0


def test_engine_mid_run_submission_matches_upfront_schedule(tiny_model):
    """Submitting during run(until=...) behaves like a scheduled arrival."""
    model, params = tiny_model

    def serve(online: bool):
        svc = AgentService(
            EngineBackend(
                model, params, "justitia",
                pool_tokens=256, max_batch=2, cache_len=128,
            )
        )
        svc.submit(AgentSpec(stages=[[InferenceSpec(32, 24)]], arrival=0.0))
        if online:
            svc.run(until=10.0)  # clock is now past 10 iterations
            svc.submit(
                AgentSpec(stages=[[InferenceSpec(16, 8)]], arrival=10.0)
            )
        else:
            svc.submit(
                AgentSpec(stages=[[InferenceSpec(16, 8)]], arrival=10.0)
            )
        return svc.drain()

    upfront = serve(online=False)
    online = serve(online=True)
    assert upfront.finish == online.finish


# -------------------------------------------------- facade + event stream


def test_service_streams_events_and_hooks(tiny_model):
    model, params = tiny_model
    svc = AgentService.engine(
        model, params, "justitia",
        pool_tokens=256, max_batch=2, cache_len=128,
    )
    seen = []
    h = svc.submit(
        AgentSpec(stages=[[InferenceSpec(16, 6)], [InferenceSpec(16, 4)]]),
        hooks=AgentHooks(
            on_stage_complete=lambda ev: seen.append(("stage", ev.stage)),
            on_complete=lambda ev: seen.append(("done", ev.agent_id)),
        ),
    )
    res = svc.drain()
    assert h.done and h.finish == res.finish[0]
    assert h.tokens and len(h.tokens) == 10  # per-token streaming
    assert [e for e in seen if e[0] == "stage"] == [("stage", 0), ("stage", 1)]
    assert seen[-1] == ("done", 0)
    assert h.stage_finish[0] < h.stage_finish[1]
    assert res.event_counts["TokenGenerated"] == 10


def test_sim_backend_same_workload_one_flag(tiny_model):
    """The acceptance scenario in miniature: identical AgentSpec list through
    both backends via AgentService."""
    model, params = tiny_model
    specs = [
        AgentSpec(stages=[[InferenceSpec(64, 32)] * 2], arrival=0.0),
        AgentSpec(stages=[[InferenceSpec(32, 8)]], arrival=3.0),
    ]
    results = {}
    for backend in ("sim", "engine"):
        if backend == "sim":
            svc = AgentService.sim("justitia", total_kv=2048.0)
        else:
            svc = AgentService.engine(
                model, params, "justitia", pool_tokens=2048,
                max_batch=4, cache_len=128,
            )
        svc.submit_many([
            AgentSpec(stages=s.stages, arrival=s.arrival) for s in specs
        ])
        results[backend] = svc.drain()
    for backend, res in results.items():
        assert set(res.finish) == {0, 1}, backend
        assert res.stats.n == 2
        assert res.backend == backend


# ------------------------------------- engine satellites: sorts + stalls


def test_static_scheduler_skips_admission_resort(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(0)

    def run(name):
        eng = ServeEngine(
            model, params, make_scheduler(name, 512.0),
            pool_tokens=512, max_batch=2, cache_len=128,
        )
        for aid in range(3):
            stage = [(rng.integers(0, VOCAB, size=24), 12) for _ in range(2)]
            eng.submit_agent(EngineAgent(aid, 0, [stage], 100.0 + aid))
        eng.run_until_idle()
        return eng.metrics

    assert run("justitia")["sorts"] == 0     # static key: lazy sorted insert
    assert run("vtc")["sorts"] > 0           # dynamic key: re-sorts per admit


def test_run_until_idle_stall_carries_diagnostics(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(1)
    eng = ServeEngine(
        model, params, make_scheduler("justitia", 512.0),
        pool_tokens=512, max_batch=2, cache_len=256,
    )
    eng.submit_agent(
        EngineAgent(0, 0, [[(rng.integers(0, VOCAB, size=16), 64)]], 10.0)
    )
    with pytest.raises(EngineStalledError) as ei:
        eng.run_until_idle(max_iters=4)
    err = ei.value
    assert isinstance(err, RuntimeError)      # backward compatible
    for fragment in ("waiting=", "swapped=", "running=", "free_blocks=",
                     "live_per_agent="):
        assert fragment in str(err)
    assert err.completions == {}
    assert err.metrics["tokens"] > 0          # partial progress surfaced
