"""Tests for the unified serving API: scheduler registry, online arrivals,
engine-vs-simulator equivalence through AgentService (single-backend and
``ReplicatedBackend`` fleets), the router registry, and the engine's
static-key queue fast path / stall diagnostics."""

import jax
import numpy as np
import pytest

import repro.core as core
import repro.core.schedulers as schedulers_mod
from repro.api import (
    AgentHooks,
    AgentService,
    AgentSpec,
    EngineBackend,
    ReplicatedBackend,
    SimBackend,
    resolve_router,
    router_names,
)
from repro.configs import get_config
from repro.core import (
    AgentScheduler,
    InferenceSpec,
    SchedulerPolicy,
    make_scheduler,
    register_scheduler,
    scheduler_names,
    unregister_scheduler,
)
from repro.engine import EngineAgent, EngineStalledError, ServeEngine
from repro.models import Model

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("granite-3-2b").reduced(vocab=VOCAB)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# -------------------------------------------------------------- registry


def test_registry_registration_lookup_and_all_schedulers():
    @register_scheduler("test-rr", "rr-alias")
    class _RoundRobin(AgentScheduler):
        pass

    try:
        s = make_scheduler("test-rr", 10.0)
        assert isinstance(s, _RoundRobin)
        assert s.name == "test-rr"
        assert isinstance(s, SchedulerPolicy)
        # aliases resolve but are not listed
        assert isinstance(make_scheduler("rr-alias", 10.0), _RoundRobin)
        assert "rr-alias" not in scheduler_names()
        # ALL_SCHEDULERS is auto-derived from the registry, live
        assert "test-rr" in scheduler_names()
        assert "test-rr" in core.ALL_SCHEDULERS
        assert "test-rr" in schedulers_mod.ALL_SCHEDULERS
    finally:
        unregister_scheduler("test-rr")
    assert "test-rr" not in core.ALL_SCHEDULERS
    with pytest.raises(ValueError):
        make_scheduler("test-rr", 10.0)


def test_registry_unknown_name_and_duplicates():
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("nope", 1.0)
    with pytest.raises(ValueError, match="already registered"):

        @register_scheduler("justitia")
        class _Shadow(AgentScheduler):
            pass


def test_all_schedulers_canonical_order():
    assert core.ALL_SCHEDULERS == [
        "vllm-fcfs", "vllm-sjf", "parrot", "vtc", "srjf", "justitia",
        "locality_fair",
    ]


def test_builtin_schedulers_satisfy_policy_protocol():
    for name in core.ALL_SCHEDULERS:
        assert isinstance(make_scheduler(name, 100.0), SchedulerPolicy)


# ------------------------------------------- engine/sim order equivalence

# Sequential-contention workload: the pool fits exactly one inference at a
# time on both backends (p=33 > remaining free while anything runs), so the
# completion order is exactly the scheduler's key order at each completion
# — observable identically through the engine and the simulator.
_EQUIV = [  # (arrival_s, decode)
    (0.0, 16),
    (2.0, 8),
    (4.0, 12),
    (6.0, 4),
]


def _equiv_specs():
    return [
        AgentSpec(stages=[[InferenceSpec(33, d)]], arrival=t)
        for t, d in _EQUIV
    ]


def _completion_order(jct_finish: dict) -> list:
    return [aid for aid, _ in sorted(jct_finish.items(), key=lambda kv: kv[1])]


@pytest.mark.parametrize("sched_name", ["justitia", "vtc"])
def test_online_arrivals_same_completion_order_engine_vs_sim(
    tiny_model, sched_name
):
    model, params = tiny_model
    sim_svc = AgentService(
        SimBackend(
            sched_name, total_kv=64.0, decode_rate=1.0, prefill_rate=33.0
        )
    )
    sim_svc.submit_many(_equiv_specs())
    sim_res = sim_svc.drain()

    eng_svc = AgentService(
        EngineBackend(
            model, params, sched_name,
            pool_tokens=64, block_size=16, max_batch=4, cache_len=64,
            token_scale=1, time_scale=1.0,
        )
    )
    # online: agents enter the engine's pending heap with future arrival
    # iterations and are released mid-run, not submitted upfront
    eng_svc.submit_many(_equiv_specs())
    assert eng_svc.backend.engine.pending, "future arrivals should be pending"
    eng_res = eng_svc.drain()

    assert set(sim_res.finish) == set(eng_res.finish) == {0, 1, 2, 3}
    assert _completion_order(sim_res.finish) == _completion_order(
        eng_res.finish
    ), f"order diverged under {sched_name}"
    # no swap divergence: this workload must be swap-free on both backends
    assert sim_res.swaps == 0 and eng_res.swaps == 0


def test_engine_mid_run_submission_matches_upfront_schedule(tiny_model):
    """Submitting during run(until=...) behaves like a scheduled arrival."""
    model, params = tiny_model

    def serve(online: bool):
        svc = AgentService(
            EngineBackend(
                model, params, "justitia",
                pool_tokens=256, max_batch=2, cache_len=128,
            )
        )
        svc.submit(AgentSpec(stages=[[InferenceSpec(32, 24)]], arrival=0.0))
        if online:
            svc.run(until=10.0)  # clock is now past 10 iterations
            svc.submit(
                AgentSpec(stages=[[InferenceSpec(16, 8)]], arrival=10.0)
            )
        else:
            svc.submit(
                AgentSpec(stages=[[InferenceSpec(16, 8)]], arrival=10.0)
            )
        return svc.drain()

    upfront = serve(online=False)
    online = serve(online=True)
    assert upfront.finish == online.finish


# -------------------------------------------------- facade + event stream


def test_service_streams_events_and_hooks(tiny_model):
    model, params = tiny_model
    svc = AgentService.engine(
        model, params, "justitia",
        pool_tokens=256, max_batch=2, cache_len=128,
    )
    seen = []
    h = svc.submit(
        AgentSpec(stages=[[InferenceSpec(16, 6)], [InferenceSpec(16, 4)]]),
        hooks=AgentHooks(
            on_stage_complete=lambda ev: seen.append(("stage", ev.stage)),
            on_complete=lambda ev: seen.append(("done", ev.agent_id)),
        ),
    )
    res = svc.drain()
    assert h.done and h.finish == res.finish[0]
    assert h.tokens and len(h.tokens) == 10  # per-token streaming
    assert [e for e in seen if e[0] == "stage"] == [("stage", 0), ("stage", 1)]
    assert seen[-1] == ("done", 0)
    assert h.stage_finish[0] < h.stage_finish[1]
    assert res.event_counts["TokenGenerated"] == 10


def test_sim_backend_same_workload_one_flag(tiny_model):
    """The acceptance scenario in miniature: identical AgentSpec list through
    both backends via AgentService."""
    model, params = tiny_model
    specs = [
        AgentSpec(stages=[[InferenceSpec(64, 32)] * 2], arrival=0.0),
        AgentSpec(stages=[[InferenceSpec(32, 8)]], arrival=3.0),
    ]
    results = {}
    for backend in ("sim", "engine"):
        if backend == "sim":
            svc = AgentService.sim("justitia", total_kv=2048.0)
        else:
            svc = AgentService.engine(
                model, params, "justitia", pool_tokens=2048,
                max_batch=4, cache_len=128,
            )
        svc.submit_many([
            AgentSpec(stages=s.stages, arrival=s.arrival) for s in specs
        ])
        results[backend] = svc.drain()
    for backend, res in results.items():
        assert set(res.finish) == {0, 1}, backend
        assert res.stats.n == 2
        assert res.backend == backend


# ------------------------------------------------- replicated fleets


def test_router_registry():
    from repro.api import Router, register_router

    assert router_names() == [
        "round_robin", "least_loaded", "memory_cost_aware",
    ]
    assert resolve_router("rr") is resolve_router("round_robin")
    assert resolve_router("mca") is resolve_router("memory_cost_aware")
    with pytest.raises(ValueError, match="unknown router"):
        resolve_router("nope")
    # neither a canonical name nor an alias may shadow an existing one
    with pytest.raises(ValueError, match="already registered"):

        @register_router("custom", "least_loaded")
        class _Hijack(Router):
            pass

    # the rejected registration must not leave partial state behind
    with pytest.raises(ValueError, match="unknown router"):
        resolve_router("custom")
    with pytest.raises(ValueError, match="already registered"):

        @register_router("round_robin")
        class _Shadow(Router):
            pass


def _fleet_equiv_specs(rng) -> list[AgentSpec]:
    """8 agents, sequential contention per replica (p=33 saturates a pool
    of 64 while anything runs), staggered online arrivals, randomized but
    seed-fixed decode budgets."""
    decodes = rng.integers(4, 17, size=8)
    return [
        AgentSpec(stages=[[InferenceSpec(33, int(d))]], arrival=float(t))
        for t, d in enumerate(decodes)
    ]


def _per_replica_orders(res, assignment) -> dict[int, list[int]]:
    orders: dict[int, list[int]] = {}
    for aid, t in sorted(res.finish.items(), key=lambda kv: (kv[1], kv[0])):
        orders.setdefault(assignment[aid], []).append(aid)
    return orders


@pytest.mark.parametrize("router", ["round_robin", "memory_cost_aware"])
def test_replicated_engine_vs_sim_same_assignment_and_order(
    tiny_model, fixed_seed, router
):
    """Same routing seed => same per-replica assignment AND the same
    per-replica completion order on the replicated sim and engine fleets
    (deterministic across pytest runs via the fixed_seed fixture)."""
    model, params = tiny_model
    specs = _fleet_equiv_specs(np.random.default_rng(fixed_seed))

    sim_svc = AgentService.sim(
        "justitia", replicas=2, router=router, seed=fixed_seed,
        total_kv=64.0, decode_rate=1.0, prefill_rate=33.0,
    )
    sim_svc.submit_many(specs)
    sim_res = sim_svc.drain()

    eng_svc = AgentService.engine(
        model, params, "justitia", replicas=2, router=router,
        seed=fixed_seed,
        pool_tokens=64, block_size=16, max_batch=4, cache_len=64,
        token_scale=1, time_scale=1.0,
    )
    eng_svc.submit_many(specs)
    eng_res = eng_svc.drain()

    assert isinstance(sim_svc.backend, ReplicatedBackend)
    assert set(sim_res.finish) == set(eng_res.finish) == set(range(8))
    # identical routing decisions on both backends
    assert sim_svc.backend.assignment == eng_svc.backend.assignment
    assignment = sim_svc.backend.assignment
    assert set(assignment.values()) == {0, 1}
    # identical per-replica completion order
    assert _per_replica_orders(sim_res, assignment) == _per_replica_orders(
        eng_res, assignment
    ), f"per-replica order diverged under router={router}"
    # handles learned their replica from the event stream on both services
    for svc in (sim_svc, eng_svc):
        for aid, handle in svc.handles.items():
            assert handle.replica == assignment[aid]
    # fleet metrics surfaced on both
    for res in (sim_res, eng_res):
        assert res.metrics["replicas"] == 2
        assert res.metrics["router"] == router
        assert res.metrics["virtual_lag"] >= 0.0
        assert set(res.per_replica) == {0, 1}


def test_replicated_submit_drain_rounds_interleave(fixed_seed):
    """Backend contract: submissions may happen at any point, including
    after a drain.  The fleet re-anchors its children at the fleet makespan
    between rounds, so a short replica's clock never trails the reconciled
    horizon (regression: second-round submit used to raise ValueError)."""
    svc = AgentService.sim(
        "justitia", replicas=2, router="round_robin", seed=fixed_seed,
        total_kv=256.0, decode_rate=1.0,
    )
    # round 1: replica 0 finishes late, replica 1 early
    svc.submit(AgentSpec(stages=[[InferenceSpec(16, 40)]], arrival=0.0))
    svc.submit(AgentSpec(stages=[[InferenceSpec(16, 2)]], arrival=0.0))
    r1 = svc.drain()
    assert set(r1.finish) == {0, 1}
    horizon = max(r1.finish.values())
    # round 2: next agents land on both replicas at or after the horizon
    svc.submit(AgentSpec(stages=[[InferenceSpec(16, 4)]], arrival=0.0))
    svc.submit(AgentSpec(stages=[[InferenceSpec(16, 4)]], arrival=0.0))
    r2 = svc.drain()
    # the service's finish view is cumulative across drain rounds
    assert set(r2.finish) == {0, 1, 2, 3}
    assert r2.finish[2] >= horizon and r2.finish[3] >= horizon
    assert svc.backend.assignment == {0: 0, 1: 1, 2: 0, 3: 1}


def test_mixed_fleet_submit_after_drain(tiny_model):
    """Heterogeneous fleet (sim + engine children) survives interleaved
    submit/drain rounds: the engine child's run() must advance AT LEAST to
    the fleet makespan when re-anchoring, even when the sim child drains at
    a fractional time (regression: round-to-nearest left the engine clock
    trailing the reconciled horizon and the next submit raised)."""
    model, params = tiny_model
    children = [
        SimBackend("justitia", total_kv=256.0, decode_rate=7.0),
        EngineBackend(
            model, params, "justitia",
            pool_tokens=128, block_size=16, max_batch=2, cache_len=64,
            token_scale=1, time_scale=1.0,
        ),
    ]
    svc = AgentService.replicated(children, router="round_robin")
    # sim agent outlasts the engine one and ends at a fractional time
    svc.submit(AgentSpec(stages=[[InferenceSpec(16, 200)]]))  # sim
    svc.submit(AgentSpec(stages=[[InferenceSpec(16, 4)]]))    # engine
    r1 = svc.drain()
    assert r1.makespan != int(r1.makespan)  # the round really is fractional
    svc.submit(AgentSpec(stages=[[InferenceSpec(16, 3)]]))   # sim
    svc.submit(AgentSpec(stages=[[InferenceSpec(16, 3)]]))   # engine
    r2 = svc.drain()
    assert set(r2.finish) == {0, 1, 2, 3}
    for aid in (2, 3):
        assert r2.finish[aid] >= r1.makespan


def test_replicas3_drains_50_agent_mixed_workload_sim(fixed_seed):
    """Acceptance scenario, sim half: AgentService with replicas=3 drains a
    50-agent mixed workload and fleet-level fairness holds — every agent's
    service gap (real finish vs its replica's GPS reference) stays within
    the reconciled virtual-time bound."""
    from repro.api import specs_from_classes
    from repro.core import (
        GlobalVirtualClock,
        agent_cost,
        gps_finish_times,
        inference_cost,
    )
    from repro.core.gps import GpsAgent

    decode_rate, m = 30.0, 8192.0
    rng = np.random.default_rng(fixed_seed)
    specs = specs_from_classes(rng, 50, 60.0)
    service = AgentService.sim(
        "justitia", replicas=3, router="memory_cost_aware",
        total_kv=m, decode_rate=decode_rate,
        prefill_rate=1e12, swap_penalty=0.0,   # theorem-mode children
        record_events=False,
    )
    handles = service.submit_many(specs)
    res = service.drain()

    assert len(res.finish) == 50
    assert set(res.per_replica) == {0, 1, 2}
    assert sum(s.n for s in res.per_replica.values()) == 50

    assignment = service.backend.assignment
    flat = [s for spec in specs for st_ in spec.stages for s in st_]
    c_max = max(inference_cost(s) for s in flat)
    c_agent_max = max(
        agent_cost([s for st_ in spec.stages for s in st_])
        for spec in specs
    )
    gclock = GlobalVirtualClock([m] * 3)
    for h in handles:
        gclock.register(
            assignment[h.agent_id], h.agent_id,
            h.arrival * decode_rate, h.spec.resolved_costs()[1],
        )
    snap = gclock.reconcile(max(res.finish.values()) * decode_rate)
    bound_iters = gclock.delay_bound(c_max, c_agent_max)
    assert snap.lag >= 0.0

    for replica in range(3):
        mine = [h for h in handles if assignment[h.agent_id] == replica]
        gps = gps_finish_times(
            [
                GpsAgent(h.agent_id, h.arrival * decode_rate,
                         h.spec.resolved_costs()[1])
                for h in mine
            ],
            m,
        )
        for h in mine:
            delay = res.finish[h.agent_id] * decode_rate - gps[h.agent_id]
            assert delay <= bound_iters * 1.05 + 1.0, (
                f"agent {h.agent_id} on replica {replica}: service gap "
                f"{delay:.1f} iters exceeds reconciled bound "
                f"{bound_iters:.1f}"
            )


def test_replicas3_drains_50_agent_mixed_workload_engine(
    tiny_model, fixed_seed
):
    """Acceptance scenario, engine half: the same fleet API drains 50
    mixed task-parallel agents across 3 real engines, with per-replica
    metrics aggregated and the load spread across all replicas."""
    model, params = tiny_model
    rng = np.random.default_rng(fixed_seed)
    specs = []
    for i in range(50):
        n_stages = 1 + int(rng.integers(0, 2))
        stages = [
            [
                InferenceSpec(int(rng.integers(8, 25)),
                              int(rng.integers(4, 11)))
                for _ in range(1 + int(rng.integers(0, 2)))
            ]
            for _ in range(n_stages)
        ]
        specs.append(AgentSpec(stages=stages, arrival=float(i)))
    service = AgentService.engine(
        model, params, "justitia", replicas=3, router="least_loaded",
        seed=fixed_seed,
        pool_tokens=512, block_size=16, max_batch=4, cache_len=64,
        token_scale=1, time_scale=1.0, record_events=False,
    )
    service.submit_many(specs)
    res = service.drain()

    assert len(res.finish) == 50
    assert res.metrics["replicas"] == 3
    assert set(res.per_replica) == {0, 1, 2}
    assert sum(s.n for s in res.per_replica.values()) == 50
    # least_loaded keeps the live-agent spread tight at every decision
    agents_per_replica = [p["agents"] for p in res.metrics["per_replica"]]
    assert max(agents_per_replica) - min(agents_per_replica) <= 5
    assert res.metrics["virtual_lag"] >= 0.0


# ------------------------------------- engine satellites: sorts + stalls


def test_static_scheduler_skips_admission_resort(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(0)

    def run(name):
        eng = ServeEngine(
            model, params, make_scheduler(name, 512.0),
            pool_tokens=512, max_batch=2, cache_len=128,
        )
        for aid in range(3):
            stage = [(rng.integers(0, VOCAB, size=24), 12) for _ in range(2)]
            eng.submit_agent(EngineAgent(aid, 0, [stage], 100.0 + aid))
        eng.run_until_idle()
        return eng.metrics

    assert run("justitia")["sorts"] == 0     # static key: lazy sorted insert
    assert run("vtc")["sorts"] > 0           # dynamic key: re-sorts per admit


def test_run_until_idle_stall_carries_diagnostics(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(1)
    eng = ServeEngine(
        model, params, make_scheduler("justitia", 512.0),
        pool_tokens=512, max_batch=2, cache_len=256,
    )
    eng.submit_agent(
        EngineAgent(0, 0, [[(rng.integers(0, VOCAB, size=16), 64)]], 10.0)
    )
    with pytest.raises(EngineStalledError) as ei:
        eng.run_until_idle(max_iters=4)
    err = ei.value
    assert isinstance(err, RuntimeError)      # backward compatible
    for fragment in ("waiting=", "swapped=", "running=", "free_blocks=",
                     "live_per_agent="):
        assert fragment in str(err)
    assert err.completions == {}
    assert err.metrics["tokens"] > 0          # partial progress surfaced
