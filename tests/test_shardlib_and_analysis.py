"""Unit tests: logical-axis sharding helpers + the loop-aware HLO analyzer
+ workload statistics (the paper's 72/26/2 size mix)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.shardlib import (
    active_rules,
    logical_to_spec,
    param_spec,
    shard,
    use_sharding,
)


def test_shard_is_noop_without_mesh():
    x = jnp.ones((4, 8))
    assert shard(x, "batch", "embed") is x
    assert active_rules() is None


def test_shard_rank_mismatch_raises():
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    with use_sharding(mesh, {"batch": "data"}):
        with pytest.raises(ValueError):
            shard(jnp.ones((4, 8)), "batch")


def test_logical_to_spec_mapping():
    from jax.sharding import PartitionSpec as P

    rules = {"batch": ("pod", "data"), "ffn": "model", "embed": None}
    spec = logical_to_spec(["batch", None, "ffn"], rules)
    assert spec == P(("pod", "data"), None, "model")


def test_use_sharding_nests_and_restores():
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    with use_sharding(mesh, {"batch": "data"}):
        assert active_rules()[1] == {"batch": "data"}
        with use_sharding(mesh, {"batch": None}):
            assert active_rules()[1] == {"batch": None}
        assert active_rules()[1] == {"batch": "data"}
    assert active_rules() is None


# -------------------------------------------------------- hlo analysis


def test_hlo_analysis_scales_loop_trip_counts():
    """A scan of 10 matmuls must count 10x one matmul's FLOPs."""
    from repro.launch.hlo_analysis import total_stats

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    comp = jax.jit(f).lower(
        jnp.ones((128, 128)), jnp.ones((128, 128))
    ).compile()
    st = total_stats(comp.as_text())
    expect = 10 * 2 * 128 ** 3
    assert st.flops == pytest.approx(expect, rel=0.01)


def test_hlo_analysis_nested_loops_multiply():
    from repro.launch.hlo_analysis import total_stats

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    comp = jax.jit(f).lower(
        jnp.ones((64, 64)), jnp.ones((64, 64))
    ).compile()
    st = total_stats(comp.as_text())
    expect = 12 * 2 * 64 ** 3
    assert st.flops == pytest.approx(expect, rel=0.01)


# ------------------------------------------------------------- workloads


def test_size_mix_matches_paper():
    """72/26/2 small/medium/large sampling probabilities (paper §5.1)."""
    from repro.workloads import SIZE_BUCKETS, sample_mixed_suite

    rng = np.random.default_rng(0)
    suite = sample_mixed_suite(rng, 2000)
    by_size = {"small": 0, "medium": 0, "large": 0}
    for a in suite:
        for size, names in SIZE_BUCKETS.items():
            if a.name in names:
                by_size[size] += 1
    n = len(suite)
    assert abs(by_size["small"] / n - 0.72) < 0.04
    assert abs(by_size["medium"] / n - 0.26) < 0.04
    assert abs(by_size["large"] / n - 0.02) < 0.015


def test_agent_demand_stability():
    """App. A: within-class demand spread is narrow relative to the
    across-class spread (what makes per-class prediction work)."""
    from repro.workloads import AGENT_CLASSES, sample_agent

    rng = np.random.default_rng(1)
    class_means = {}
    within_cv = []
    for cls in AGENT_CLASSES:
        costs = np.array([sample_agent(rng, cls).true_cost
                          for _ in range(40)])
        class_means[cls] = costs.mean()
        within_cv.append(costs.std() / costs.mean())
    means = np.array(list(class_means.values()))
    across_spread = means.max() / means.min()
    assert across_spread > 50          # classes span orders of magnitude
    assert np.mean(within_cv) < 1.0    # within-class is comparatively tight


def test_arrivals_sorted_within_window():
    from repro.workloads import DENSITY_WINDOWS_S, arrivals_for_density

    rng = np.random.default_rng(2)
    for density in (1, 2, 3):
        t = arrivals_for_density(rng, 300, density)
        assert len(t) == 300
        assert (np.diff(t) >= 0).all()
        assert t.min() >= 0 and t.max() <= DENSITY_WINDOWS_S[density]
