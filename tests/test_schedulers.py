"""Unit tests for the scheduler policies (Justitia + the five baselines)."""

import pytest

from repro.core import (
    ALL_SCHEDULERS,
    InferenceSpec,
    JustitiaScheduler,
    Request,
    make_scheduler,
)


def req(agent_id, rid, t=0.0, p=100, d=50, pred=0.0):
    return Request(
        agent_id=agent_id,
        rid=rid,
        spec=InferenceSpec(p, d),
        submit_time=t,
        pred_cost=pred,
    )


def test_factory_covers_all():
    for name in ALL_SCHEDULERS:
        s = make_scheduler(name, 1000.0)
        assert s.name == name
    with pytest.raises(ValueError):
        make_scheduler("nope", 1.0)


def test_fcfs_orders_by_submit_time():
    s = make_scheduler("vllm-fcfs", 1000.0)
    s.on_agent_arrival(1, 0.0, 10.0)
    s.on_agent_arrival(2, 1.0, 10.0)
    assert s.request_key(req(1, 0, t=0.0), 2.0) < s.request_key(req(2, 1, t=1.0), 2.0)


def test_sjf_orders_by_predicted_cost():
    s = make_scheduler("vllm-sjf", 1000.0)
    s.on_agent_arrival(1, 0.0, 10.0)
    s.on_agent_arrival(2, 0.0, 10.0)
    assert s.request_key(req(2, 1, pred=5.0), 1.0) < s.request_key(
        req(1, 0, pred=50.0), 1.0
    )


def test_parrot_groups_by_agent_arrival():
    s = make_scheduler("parrot", 1000.0)
    s.on_agent_arrival(1, 0.0, 10.0)
    s.on_agent_arrival(2, 1.0, 1.0)
    # agent 1 arrived first: ALL its requests outrank agent 2's
    assert s.request_key(req(1, 5), 2.0) < s.request_key(req(2, 1), 2.0)


def test_vtc_prefers_least_serviced_and_lifts_on_arrival():
    s = make_scheduler("vtc", 1000.0)
    s.on_agent_arrival(1, 0.0, 10.0)
    s.on_service(1, prefill_tokens=100, decode_tokens=50)  # counter = 200
    s.on_agent_arrival(2, 1.0, 10.0)  # lifted to min(live) = 200
    assert s.agents[2].serviced_vtc == pytest.approx(200.0)
    s.on_service(2, decode_tokens=10)  # 220
    assert s.request_key(req(1, 0), 2.0) < s.request_key(req(2, 1), 2.0)


def test_srjf_uses_remaining_predicted_cost():
    s = make_scheduler("srjf", 1000.0)
    s.on_agent_arrival(1, 0.0, 1000.0)
    s.on_agent_arrival(2, 0.0, 600.0)
    assert s.request_key(req(2, 1), 0.0) < s.request_key(req(1, 0), 0.0)
    s.on_service(1, kv_token_time=900.0)  # remaining 100 < 600
    assert s.request_key(req(1, 0), 0.0) < s.request_key(req(2, 1), 0.0)


def test_justitia_priority_is_static_virtual_finish():
    s = JustitiaScheduler(total_kv=100.0)
    s.on_agent_arrival(1, 0.0, 500.0)
    s.on_agent_arrival(2, 0.0, 300.0)   # same V(0): smaller cost wins
    k1 = s.request_key(req(1, 0), 0.0)
    k2 = s.request_key(req(2, 1), 0.0)
    assert k2 < k1
    # service amounts do NOT change Justitia's order (static pampering order)
    s.on_service(1, kv_token_time=499.0)
    assert s.request_key(req(2, 1), 5.0) < s.request_key(req(1, 0), 5.0)


def test_justitia_late_small_agent_does_not_jump_started_queue():
    """An agent arriving after much virtual time has passed gets a later F_j
    than an equal-cost agent that arrived early (no gaming by arriving late)."""
    s = JustitiaScheduler(total_kv=10.0)
    s.on_agent_arrival(1, 0.0, 1000.0)
    s.on_agent_arrival(2, 50.0, 1000.0)  # V(50) = 500 (solo rate 10)
    assert s.agents[1].virtual_finish < s.agents[2].virtual_finish


def test_all_inferences_of_one_agent_consecutive_under_justitia():
    s = JustitiaScheduler(total_kv=100.0)
    s.on_agent_arrival(1, 0.0, 500.0)
    s.on_agent_arrival(2, 0.0, 400.0)
    keys = [
        s.request_key(req(2, 10), 1.0),
        s.request_key(req(1, 11), 1.0),
        s.request_key(req(2, 12), 1.0),
        s.request_key(req(1, 13), 1.0),
    ]
    order = sorted(range(4), key=lambda i: keys[i])
    # agent 2's requests (idx 0, 2) strictly precede agent 1's (idx 1, 3)
    assert order == [0, 2, 1, 3]
