"""Behavioural tests for the discrete-event cluster simulator."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import InferenceSpec, make_scheduler
from repro.sim import ClusterSim, SimAgent, jct_stats
from repro.workloads import sample_mixed_suite, arrivals_for_density


def one_shot_agent(agent_id, arrival, specs, cost=None):
    from repro.core import agent_cost

    c = cost if cost is not None else agent_cost(specs)
    return SimAgent(
        agent_id=agent_id,
        arrival=arrival,
        stages=[list(specs)],
        predicted_cost=c,
        true_cost=c,
    )


def run(name, agents, m=2000.0, **kw):
    decode_rate = kw.get("decode_rate", 30.0)
    sim = ClusterSim(make_scheduler(name, m, service_rate=decode_rate), m, **kw)
    return sim.run(agents)


def test_single_agent_completes_at_solo_time():
    # p=100, d=300 at 30 tok/s decode, 4000 tok/s prefill
    a = one_shot_agent(0, 0.0, [InferenceSpec(100, 300)])
    res = run("justitia", [a])
    expect = 100 / 4000.0 + 300 / 30.0
    assert res.jct[0] == pytest.approx(expect, rel=1e-6)


def test_parallel_inferences_overlap():
    specs = [InferenceSpec(100, 300)] * 4  # fits in pool together
    a = one_shot_agent(0, 0.0, specs)
    res = run("justitia", [a], m=100000.0)
    # all four run concurrently: JCT == single-inference time
    expect = 100 / 4000.0 + 300 / 30.0
    assert res.jct[0] == pytest.approx(expect, rel=1e-6)


def test_staged_agent_serializes_stages():
    stages = [[InferenceSpec(100, 300)], [InferenceSpec(100, 300)]]
    a = SimAgent(0, 0.0, stages, predicted_cost=1.0, true_cost=1.0)
    res = run("justitia", [a], m=100000.0)
    expect = 2 * (100 / 4000.0 + 300 / 30.0)
    assert res.jct[0] == pytest.approx(expect, rel=1e-6)


def test_every_agent_finishes():
    rng = np.random.default_rng(7)
    suite = sample_mixed_suite(rng, 60)
    arr = arrivals_for_density(rng, 60, 3)
    agents = [
        SimAgent(i, float(t), [list(s) for s in a.stages], a.true_cost, a.true_cost)
        for i, (a, t) in enumerate(zip(suite, arr))
    ]
    for name in ["justitia", "vtc", "vllm-fcfs", "srjf"]:
        res = run(name, [SimAgent(x.agent_id, x.arrival,
                                  [list(s) for s in x.stages],
                                  x.predicted_cost, x.true_cost)
                         for x in agents], m=16384.0)
        assert len(res.jct) == 60
        assert all(v > 0 for v in res.jct.values())


def test_justitia_pampering_beats_vtc_under_contention():
    """Fig. 3 in miniature: competing large agents, pampering wins on mean
    JCT without delaying the later-finishing agent."""
    specs = [InferenceSpec(200, 600)] * 6
    a0 = one_shot_agent(0, 0.0, specs)
    a1 = one_shot_agent(1, 0.0, specs)
    m = 3000.0  # forces contention: both can't run saturated together
    r_vtc = run("vtc", [a0, a1], m=m)
    a0b = one_shot_agent(0, 0.0, specs)
    a1b = one_shot_agent(1, 0.0, specs)
    r_jus = run("justitia", [a0b, a1b], m=m)
    mean_vtc = np.mean(list(r_vtc.jct.values()))
    mean_jus = np.mean(list(r_jus.jct.values()))
    assert mean_jus < mean_vtc  # pampering reduces average JCT
    # the slower (unpampered) agent finishes no later than under fair share
    assert max(r_jus.jct.values()) <= max(r_vtc.jct.values()) * 1.05


def test_head_of_line_blocking_under_fcfs_not_justitia():
    """Elephant first, mouse second: FCFS blocks the mouse; Justitia lets the
    mouse (earlier GPS finish) go first."""
    elephant = one_shot_agent(0, 0.0, [InferenceSpec(1800, 2000)] * 3)
    mouse = one_shot_agent(1, 0.1, [InferenceSpec(50, 30)])
    m = 2500.0
    r_f = run("vllm-fcfs", [elephant, mouse], m=m)
    elephant2 = one_shot_agent(0, 0.0, [InferenceSpec(1800, 2000)] * 3)
    mouse2 = one_shot_agent(1, 0.1, [InferenceSpec(50, 30)])
    r_j = run("justitia", [elephant2, mouse2], m=m)
    assert r_j.jct[1] < r_f.jct[1] / 5  # mouse unblocked by Justitia


def test_non_preemption_running_not_interrupted():
    """A tiny high-priority agent arriving mid-flight must wait for memory,
    not preempt: with ample memory it starts instantly; the running elephant
    inference is never rolled back (its JCT equals solo time)."""
    elephant = one_shot_agent(0, 0.0, [InferenceSpec(100, 3000)])
    mouse = one_shot_agent(1, 10.0, [InferenceSpec(50, 30)])
    res = run("justitia", [elephant, mouse], m=100000.0)
    solo_elephant = 100 / 4000.0 + 3000 / 30.0
    assert res.jct[0] == pytest.approx(solo_elephant, rel=1e-6)


def test_swap_preserves_progress():
    """Pool pressure forces swaps; swapped sequences resume (everything
    still completes, with swap count > 0)."""
    agents = [
        one_shot_agent(i, i * 0.01, [InferenceSpec(400, 800)] * 3)
        for i in range(6)
    ]
    res = run("justitia", agents, m=2000.0)
    assert len(res.jct) == 6
    assert res.swaps > 0


def test_work_conservation_reasonable_makespan():
    """Total service demanded / max service rate lower-bounds makespan; a
    work-conserving backend should be within ~2x of it for saturated loads."""
    rng = np.random.default_rng(3)
    suite = sample_mixed_suite(rng, 40)
    m = 8192.0
    agents = [
        SimAgent(i, 0.0, [list(s) for s in a.stages], a.true_cost, a.true_cost)
        for i, a in enumerate(suite)
    ]
    total_cost = sum(a.true_cost for a in agents)  # KV token-iterations
    res = run("justitia", agents, m=m)
    lower_bound_s = total_cost / (m * 30.0)  # pool * decode_rate
    assert res.makespan >= 0.5 * lower_bound_s


def test_simulator_deterministic():
    rng = np.random.default_rng(11)
    suite = sample_mixed_suite(rng, 30)
    arr = arrivals_for_density(np.random.default_rng(11), 30, 2)

    def go():
        agents = [
            SimAgent(i, float(t), [list(s) for s in a.stages],
                     a.true_cost, a.true_cost)
            for i, (a, t) in enumerate(zip(suite, arr))
        ]
        return run("justitia", agents, m=8192.0).jct

    assert go() == go()
