"""Cross-backend event-conformance suite (the PR 5 headline test work).

For identical workloads served through :class:`repro.api.AgentService` on
the sim, engine, and replicated backends, every agent's event stream must
satisfy the same lifecycle grammar:

    Arrival <= Admit <= (SwapOut/SwapIn)* <= StageComplete*
            <= (Suspended <= Resumed)* <= AgentComplete

with timestamps monotone non-decreasing in workload seconds (in emission
order), per-request ``TokenGenerated`` counts summing to each stage's
decode demand, and — on a :class:`ReplicatedBackend` fleet — the
``replica`` field set on every event.  The sim streams tokens through its
discretized ``token_events`` decode model, the engine through its real
sampled tokens, so the grammar (not the token values) is the
backend-uniform contract.

Also here: the closed-loop acceptance scenario (multi-turn sessions end to
end on sim, engine, and a 2-replica fleet, with identical per-agent turn
counts across all three), the closed-loop re-entrancy guard, and the
stale-``until`` no-op regressions for ``EngineBackend.run`` /
``SimBackend.run``.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (
    AdmissionDeferred,
    AgentArrived,
    AgentCompleted,
    AgentRequeued,
    AgentResumed,
    AgentService,
    AgentSpec,
    AgentSuspended,
    EngineBackend,
    ReplicatedBackend,
    RequestAdmitted,
    RequestSwappedIn,
    RequestSwappedOut,
    SimBackend,
    StageCompleted,
    TokenGenerated,
    specs_from_closed_loop,
)
from repro.configs import get_config
from repro.core import InferenceSpec
from repro.models import Model

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("granite-3-2b").reduced(vocab=VOCAB)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# ------------------------------------------------------ conformance checker


def assert_conformant_stream(
    handle, *, expect_replica=False, token_demands=None, expect_tokens=True,
    allow_requeue=False,
):
    """Assert one agent's event stream satisfies the lifecycle grammar.

    ``token_demands``: multiset (sorted list) of per-request decode demands
    the agent was served with — compared against the per-rid token counts.

    ``allow_requeue=True`` admits failover migrations into the grammar: an
    ``AgentRequeued`` event restarts the lifecycle on the survivor (rid
    space, swap chains, and stage indices all reset to the re-submitted
    remaining-stage spec; subsequent events must carry the new replica),
    timestamps stay monotone across the migration, and the token-demand
    multiset check is skipped for migrated agents — the in-progress stage
    is replayed from its start, so its per-rid counts legitimately repeat.
    Returns the stage count observed on the FINAL replica.

    Suspension grammar (PR 9), checked unconditionally: an
    ``AgentSuspended`` may appear only immediately after a
    ``StageCompleted`` (tool-call think time starts at a stage boundary)
    with ``until >= time``; while the suspension is open the agent emits
    NO admissions, tokens, swaps, or stage completions; the suspension is
    closed by ``AgentResumed`` or — on a crashed replica — by
    ``AgentRequeued`` (the resume is emitted just before the requeue);
    at most one suspension is open at a time, an agent never completes
    suspended, and for never-requeued agents suspensions == resumes.
    """
    evs = handle.events
    aid = handle.agent_id
    assert evs, f"agent {aid}: no events recorded"
    assert isinstance(evs[0], AgentArrived), f"agent {aid}: first event"
    assert isinstance(evs[-1], AgentCompleted), f"agent {aid}: last event"
    assert sum(isinstance(e, AgentArrived) for e in evs) == 1
    assert sum(isinstance(e, AgentCompleted) for e in evs) == 1

    # timestamps monotone non-decreasing in emission order
    times = [e.time for e in evs]
    for a, b in zip(times, times[1:]):
        assert b >= a - 1e-9, f"agent {aid}: time went backwards {a}->{b}"

    admitted: set = set()
    swapped_out: dict = {}
    token_counts: dict = {}
    stages_seen = 0
    requeues = 0
    suspended = False
    suspensions = 0
    resumes = 0
    prev_ev = evs[0]
    cur_replica = evs[0].replica
    for ev in evs[1:-1]:
        assert ev.agent_id == aid
        if expect_replica:
            assert ev.replica is not None, f"agent {aid}: {ev} lacks replica"
        if suspended:
            assert isinstance(ev, (AgentResumed, AgentRequeued)), (
                f"agent {aid}: {type(ev).__name__} emitted while "
                f"suspended — a thinking agent holds no decode slot"
            )
        if isinstance(ev, AgentSuspended):
            assert isinstance(prev_ev, StageCompleted), (
                f"agent {aid}: AgentSuspended after "
                f"{type(prev_ev).__name__}, not a StageCompleted — "
                f"think time starts at a stage boundary"
            )
            assert ev.until >= ev.time - 1e-9, (
                f"agent {aid}: suspension resumes in the past "
                f"({ev.until} < {ev.time})"
            )
            suspended = True
            suspensions += 1
            prev_ev = ev
            continue
        if isinstance(ev, AgentResumed):
            assert suspended, (
                f"agent {aid}: AgentResumed without an open suspension"
            )
            suspended = False
            resumes += 1
            prev_ev = ev
            continue
        prev_ev = ev
        if isinstance(ev, AgentRequeued):
            assert allow_requeue, f"agent {aid}: unexpected AgentRequeued"
            suspended = False
            if expect_replica:
                assert ev.from_replica == cur_replica, (
                    f"agent {aid}: requeued from replica "
                    f"{ev.from_replica}, was on {cur_replica}"
                )
                assert ev.replica != ev.from_replica, (
                    f"agent {aid}: requeued onto the failed replica"
                )
            cur_replica = ev.replica
            admitted = set()
            swapped_out = {}
            stages_seen = 0
            requeues += 1
            continue
        if expect_replica and cur_replica is not None:
            assert ev.replica == cur_replica, (
                f"agent {aid}: {ev} on replica {ev.replica}, expected "
                f"{cur_replica}"
            )
        if isinstance(ev, AdmissionDeferred):
            assert ev.rid not in admitted, (
                f"agent {aid}: rid {ev.rid} deferred after admission"
            )
        elif isinstance(ev, RequestAdmitted):
            assert ev.rid not in admitted, (
                f"agent {aid}: rid {ev.rid} admitted twice"
            )
            admitted.add(ev.rid)
        elif isinstance(ev, RequestSwappedOut):
            assert ev.rid in admitted, f"agent {aid}: swap-out before admit"
            assert not swapped_out.get(ev.rid), (
                f"agent {aid}: rid {ev.rid} swapped out twice in a row"
            )
            swapped_out[ev.rid] = True
        elif isinstance(ev, RequestSwappedIn):
            assert swapped_out.get(ev.rid), (
                f"agent {aid}: swap-in without a prior swap-out"
            )
            swapped_out[ev.rid] = False
        elif isinstance(ev, TokenGenerated):
            assert ev.rid in admitted, f"agent {aid}: token before admit"
            assert not swapped_out.get(ev.rid), (
                f"agent {aid}: token from a swapped-out request"
            )
            token_counts[ev.rid] = token_counts.get(ev.rid, 0) + 1
        elif isinstance(ev, StageCompleted):
            assert ev.stage == stages_seen, (
                f"agent {aid}: stage {ev.stage} completed out of order "
                f"(expected {stages_seen})"
            )
            stages_seen += 1
    assert stages_seen >= 1, f"agent {aid}: no StageCompleted"
    assert not any(swapped_out.values()), (
        f"agent {aid}: completed while a request was swapped out"
    )
    assert not suspended, f"agent {aid}: completed while suspended"
    if requeues == 0:
        assert suspensions == resumes, (
            f"agent {aid}: {suspensions} suspensions vs {resumes} "
            f"resumes with no failover migration"
        )
    if expect_tokens:
        assert token_counts, f"agent {aid}: no TokenGenerated events"
    if token_demands is not None and requeues == 0:
        assert sorted(token_counts.values()) == sorted(token_demands), (
            f"agent {aid}: per-request token counts "
            f"{sorted(token_counts.values())} != decode demands "
            f"{sorted(token_demands)}"
        )
    return stages_seen


def _specs(raw):
    return [
        AgentSpec(
            stages=[[InferenceSpec(p, d) for p, d in stage]
                    for stage in stages],
            arrival=float(arr),
        )
        for arr, stages in raw
    ]


def _demands(raw_agent):
    _, stages = raw_agent
    return [d for stage in stages for _, d in stage]


# per-agent: 1-2 stages x 1-2 parallel inferences, staggered arrivals
workload_strategy = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=30.0),
        st.lists(
            st.lists(
                st.tuples(
                    st.integers(min_value=40, max_value=300),   # prefill
                    st.integers(min_value=5, max_value=60),     # decode
                ),
                min_size=1, max_size=2,
            ),
            min_size=1, max_size=2,
        ),
    ),
    min_size=1, max_size=6,
)


# ------------------------------------------------------------ sim backend


@given(
    workload_strategy,
    st.sampled_from([900.0, 4000.0]),          # swap pressure / roomy
    st.sampled_from(["justitia", "vtc"]),
)
@settings(max_examples=25, deadline=None)
def test_sim_stream_conformance(raw, m, sched):
    svc = AgentService(
        SimBackend(sched, total_kv=m, token_events=True)
    )
    handles = svc.submit_many(_specs(raw))
    res = svc.drain()
    assert len(res.finish) == len(raw)
    for h, raw_agent in zip(handles, raw):
        assert_conformant_stream(h, token_demands=_demands(raw_agent))


@given(workload_strategy)
@settings(max_examples=15, deadline=None)
def test_replicated_sim_stream_conformance(raw):
    svc = AgentService.sim(
        "justitia", replicas=2, router="round_robin",
        total_kv=2000.0, token_events=True,
    )
    handles = svc.submit_many(_specs(raw))
    res = svc.drain()
    assert len(res.finish) == len(raw)
    assert isinstance(svc.backend, ReplicatedBackend)
    for h, raw_agent in zip(handles, raw):
        assert_conformant_stream(
            h, expect_replica=True, token_demands=_demands(raw_agent)
        )
        assert h.replica == svc.backend.assignment[h.agent_id]


@given(workload_strategy)
@settings(max_examples=10, deadline=None)
def test_concurrent_replicated_sim_stream_conformance(raw):
    """The thread-pooled fleet stepper (PR 10) replays each child's
    buffered events in child-index order, so every agent's stream obeys
    the same lifecycle grammar — and with stealing armed, a migrated
    agent's stream restarts on the target replica exactly like a failover
    requeue (AgentRequeued, then a fresh admission cycle)."""
    svc = AgentService.sim(
        "justitia", replicas=2, router="round_robin",
        total_kv=2000.0, token_events=True,
        fleet_workers=2, steal_threshold=1.3, steal_interval=0.5,
    )
    handles = svc.submit_many(_specs(raw))
    res = svc.drain()
    assert len(res.finish) == len(raw)
    for h, raw_agent in zip(handles, raw):
        assert_conformant_stream(
            h, expect_replica=True, token_demands=_demands(raw_agent),
            allow_requeue=True,
        )


# ----------------------------------------------------------- engine backend


@pytest.mark.parametrize("pool_tokens", [2048, 128])   # roomy / swap-heavy
def test_engine_stream_conformance(tiny_model, pool_tokens):
    model, params = tiny_model
    rng = np.random.default_rng(11)
    raw = [
        (
            float(i),
            [
                [
                    (int(rng.integers(8, 25)), int(rng.integers(4, 12)))
                    for _ in range(1 + int(rng.integers(0, 2)))
                ]
                for _ in range(1 + int(rng.integers(0, 2)))
            ],
        )
        for i in range(6)
    ]
    svc = AgentService(
        EngineBackend(
            model, params, "justitia",
            pool_tokens=pool_tokens, block_size=16, max_batch=4,
            cache_len=64, token_scale=1, time_scale=1.0,
        )
    )
    handles = svc.submit_many(_specs(raw))
    res = svc.drain()
    assert len(res.finish) == len(raw)
    swaps = 0
    for h, raw_agent in zip(handles, raw):
        assert_conformant_stream(h, token_demands=_demands(raw_agent))
        swaps += sum(isinstance(e, RequestSwappedOut) for e in h.events)
    if pool_tokens == 128:
        assert swaps > 0, "swap-heavy cell produced no swaps"


def test_fused_engine_stream_conformance(tiny_model):
    """fused_prefill=True serves the same grammar: prompts riding the
    decode windows as chunk slices must not reorder, drop, or duplicate
    any lifecycle event, and per-request token counts still equal the
    decode demands."""
    model, params = tiny_model
    rng = np.random.default_rng(13)
    raw = [
        (
            float(i),
            [
                [
                    (int(rng.integers(8, 25)), int(rng.integers(4, 12)))
                    for _ in range(1 + int(rng.integers(0, 2)))
                ]
                for _ in range(1 + int(rng.integers(0, 2)))
            ],
        )
        for i in range(6)
    ]
    svc = AgentService(
        EngineBackend(
            model, params, "justitia",
            pool_tokens=512, block_size=16, max_batch=4,
            cache_len=64, prefill_chunk=8, token_scale=1,
            time_scale=1.0, fused_prefill=True,
        )
    )
    handles = svc.submit_many(_specs(raw))
    res = svc.drain()
    assert len(res.finish) == len(raw)
    assert svc.backend.engine.metrics["fused_slices"] > 0
    for h, raw_agent in zip(handles, raw):
        assert_conformant_stream(h, token_demands=_demands(raw_agent))


def test_replicated_engine_stream_conformance(tiny_model):
    model, params = tiny_model
    svc = AgentService.engine(
        model, params, "justitia", replicas=2, router="round_robin",
        pool_tokens=256, block_size=16, max_batch=2, cache_len=64,
        token_scale=1, time_scale=1.0,
    )
    raw = [(float(i), [[(16, 6)]]) for i in range(4)]
    handles = svc.submit_many(_specs(raw))
    res = svc.drain()
    assert len(res.finish) == 4
    for h, raw_agent in zip(handles, raw):
        assert_conformant_stream(
            h, expect_replica=True, token_demands=_demands(raw_agent)
        )


# --------------------------------------------------- closed-loop acceptance


def _spied_closed_loop_specs(seed, n_agents, window_s):
    """Closed-loop specs whose callbacks record the stages they generate."""
    rng = np.random.default_rng(seed)
    specs = specs_from_closed_loop(rng, n_agents, window_s)
    generated = {i: [list(s.stages[0])] for i, s in enumerate(specs)}
    for i, spec in enumerate(specs):
        session = spec.next_stage

        def spy(outcome, _session=session, _aid=i):
            stage = _session(outcome)
            if stage:
                generated[_aid].append(list(stage))
            return stage

        spec.next_stage = spy
    return specs, generated


def test_closed_loop_multi_turn_all_backends(tiny_model):
    """Acceptance: a closed-loop multi-turn workload runs end-to-end on
    sim, engine, and a 2-replica fleet through AgentService — with the
    SAME per-agent turn counts on all three backends (sessions depend only
    on their own turn counters), conformant event streams, and token
    counts matching the lazily generated stages' decode demands."""
    model, params = tiny_model
    n, seed = 5, 20260731
    turn_counts = {}

    # --- sim (token streaming on)
    specs, generated = _spied_closed_loop_specs(seed, n, 20.0)
    svc = AgentService(
        SimBackend("justitia", total_kv=16384.0, token_events=True)
    )
    handles = svc.submit_many(specs)
    res = svc.drain()
    assert len(res.finish) == n
    for h in handles:
        demands = [
            s.decode for stage in generated[h.agent_id] for s in stage
        ]
        turns = assert_conformant_stream(h, token_demands=demands)
        assert turns == len(generated[h.agent_id])
        turn_counts[h.agent_id] = turns
    assert any(t > 1 for t in turn_counts.values()), (
        "workload degenerated: no multi-turn session"
    )

    # --- 2-replica sim fleet
    specs, generated = _spied_closed_loop_specs(seed, n, 20.0)
    svc = AgentService.sim(
        "justitia", replicas=2, router="round_robin",
        total_kv=8192.0, token_events=True,
    )
    handles = svc.submit_many(specs)
    res = svc.drain()
    assert len(res.finish) == n
    for h in handles:
        demands = [
            s.decode for stage in generated[h.agent_id] for s in stage
        ]
        turns = assert_conformant_stream(
            h, expect_replica=True, token_demands=demands
        )
        assert turns == turn_counts[h.agent_id], (
            f"agent {h.agent_id}: fleet served {turns} turns, "
            f"single sim {turn_counts[h.agent_id]}"
        )

    # --- engine (scaled demands; same turn structure)
    specs, generated = _spied_closed_loop_specs(seed, n, 20.0)
    svc = AgentService.engine(
        model, params, "justitia",
        pool_tokens=4096, max_batch=4, cache_len=512,
        token_scale=16, time_scale=1.0, seed=seed,
    )
    handles = svc.submit_many(specs)
    res = svc.drain()
    assert len(res.finish) == n
    for h in handles:
        demands = [
            max(1, int(round(s.decode / 16)))
            for stage in generated[h.agent_id]
            for s in stage
        ]
        turns = assert_conformant_stream(h, token_demands=demands)
        assert turns == turn_counts[h.agent_id], (
            f"agent {h.agent_id}: engine served {turns} turns, "
            f"sim {turn_counts[h.agent_id]}"
        )


def test_closed_loop_callback_must_not_reenter_service():
    """ROADMAP invariant: stage callbacks must not call run/drain."""
    svc = AgentService(SimBackend("justitia", total_kv=4096.0))

    def bad(outcome):
        svc.run(100.0)

    svc.submit(AgentSpec(stages=[[InferenceSpec(32, 8)]], next_stage=bad))
    with pytest.raises(RuntimeError, match="must not call run"):
        svc.drain()


def test_backend_reentrancy_guards_direct():
    """The backends themselves also refuse re-entrant advancement (a raw
    listener bypassing the service layer gets the same protection)."""
    from repro.core import make_scheduler
    from repro.sim import ClusterSim, SimAgent

    sim = ClusterSim(make_scheduler("justitia", 4096.0), 4096.0)

    class Evil:
        def on_stage_complete(self, aid, stage, t):
            sim.advance(1e9)

    sim.listener = Evil()
    sim.submit(SimAgent(0, 0.0, [[InferenceSpec(32, 8)]], 1.0, 1.0))
    with pytest.raises(RuntimeError, match="re-entrant"):
        sim.drain()


# --------------------------------------------- stale-until no-op regressions


def test_engine_backend_run_stale_until_is_noop(tiny_model):
    """``run(until)`` at-or-before the current clock must not advance the
    engine.  At large clocks ``until * time_scale`` floats far enough
    above the integer ``now`` that the old ``ceil(x - 1e-9)`` produced a
    STALE target one iteration past the clock: ``now=543101033090`` with
    ``time_scale=1000.0`` overshoots by 6.1e-5 — way past the fp guard —
    so ``run(until=now)`` used to advance the engine by one iteration."""
    import math

    model, params = tiny_model
    be = EngineBackend(
        model, params, "justitia",
        pool_tokens=256, max_batch=2, cache_len=64,
        token_scale=1, time_scale=1000.0,
    )
    svc = AgentService(be)
    svc.submit(AgentSpec(stages=[[InferenceSpec(16, 8)]], arrival=0.0))
    res = svc.drain()
    assert set(res.finish) == {0}
    # park the idle engine at a big clock (a legal idle jump: run() does
    # exactly this over empty stretches)
    big = 543_101_033_090
    be.engine.now = big
    # this IS the overshooting case the old code mis-ceiled
    assert math.ceil((big / 1000.0) * 1000.0 - 1e-9) > big
    for until in (be.now, be.now - 1e-6, 0.0):
        svc.run(until=until)
        assert be.engine.now == big, (
            f"run(until={until}) advanced the clock "
            f"{big} -> {be.engine.now}"
        )
    # a genuinely future horizon still advances
    svc.run(until=be.now + 1.0)
    assert be.engine.now > big


def test_sim_backend_run_stale_until_is_noop():
    be = SimBackend("justitia", total_kv=4096.0)
    svc = AgentService(be)
    svc.submit(AgentSpec(stages=[[InferenceSpec(64, 2000)]], arrival=0.0))
    svc.run(until=10.0)
    assert be.now == 10.0
    events_before = be.sim.result.events
    for until in (10.0, 7.5, 0.0):
        svc.run(until=until)
        assert be.now == 10.0, f"run(until={until}) moved the sim clock"
        assert be.sim.result.events == events_before, (
            "stale advance() processed events"
        )
    res = svc.drain()
    assert set(res.finish) == {0}
