"""Tests for ``benchmarks/trend.py`` (satellite: previously untested).

Golden-renders a tiny synthetic BENCH_sim/BENCH_engine JSON pair into the
TREND.md markdown and asserts the table rows, the speedup/ratio lines, the
closed-loop cells, and the CLI surface (default discovery, --out, unknown
benchmark kinds, missing paths).
"""

import json

import pytest

from benchmarks.trend import DEFAULT_CANDIDATES, main as trend_main, render

SIM_DATA = {
    "benchmark": "sim_core_perf",
    "quick": True,
    "seed": 0,
    "oracle": {"match": True, "max_abs_diff": 1.5e-9},
    "optimized": [
        {
            "agents": 1000, "scheduler": "justitia", "replicas": 1,
            "events_per_s": 6100.5, "agents_per_s": 800.25,
            "sorts": 0, "swaps": 42,
        },
        {
            "agents": 1000, "scheduler": "vtc", "replicas": 4,
            "events_per_s": 4000.0, "agents_per_s": 650.0,
            "sorts": 1234, "swaps": 7,
        },
    ],
    "speedup": {"1000": {"justitia": 2.27, "vtc": 1.75}},
    "speedup_10k_min": 7.5,
    "closed_loop": {
        "agents": 300, "scheduler": "justitia", "turns": 1318,
        "tokens_streamed": 150664, "agents_per_s": 164.7,
        "events_per_s": 5000.0, "streaming_overhead": 2.22,
        "jct_identical": True,
    },
}

ENGINE_DATA = {
    "benchmark": "engine_hot_path_perf",
    "quick": False,
    "seed": 0,
    "oracle": {"match": True, "cells": 6, "rounds_checked_per_cell": 4},
    "sim_equivalence": {"match": True, "schedulers": ["justitia", "vtc"]},
    "cells": [
        {
            "scheduler": "justitia", "pressure": "low",
            "optimized": {
                "iters_per_s": 2218.5, "swaps": 0, "avg_window": 6.8,
                "host_syncs_per_decode_step": 0.28,
            },
            "baseline": {"iters_per_s": 658.7, "swaps": 0},
            "speedup": 3.37,
        },
    ],
    "speedup_min": 2.98,
    "speedup_geomean": 4.11,
    "host_syncs_per_decode_step_max": 0.352,
    "closed_loop": {
        "scheduler": "justitia", "agents_per_round": 6, "rounds": 2,
        "turns_timed": 61, "iters_per_s": 372.5, "tokens_per_s": 1159.5,
        "swaps": 0, "avg_window": 1.9,
        "host_syncs_per_decode_step": 0.61,
    },
}


@pytest.fixture
def bench_pair(tmp_path):
    sim = tmp_path / "BENCH_sim_quick.json"
    eng = tmp_path / "BENCH_engine.json"
    sim.write_text(json.dumps(SIM_DATA))
    eng.write_text(json.dumps(ENGINE_DATA))
    return sim, eng


def test_render_golden_rows(bench_pair):
    sim, eng = bench_pair
    md = render([sim, eng])
    lines = md.splitlines()

    # header names both sources and the regen command
    assert lines[0] == "# Perf trend — tracked BENCH artifacts"
    assert any(
        "`BENCH_sim_quick.json`, `BENCH_engine.json`" in ln for ln in lines
    )
    assert any("python -m benchmarks.trend" in ln for ln in lines)

    # sim section: tier, oracle verdict, one table row per sweep cell
    assert "## BENCH_sim_quick.json — simulator core (`benchmarks/perf.py`)" \
        in lines
    assert any(
        "Tier: **quick (CI)**" in ln and "**True**" in ln
        and "1.5e-09" in ln for ln in lines
    )
    assert "| 1,000 | justitia | 1 | 6,100.5 | 800.2 | 0 | 42 |" in lines
    assert "| 1,000 | vtc | 4 | 4,000.0 | 650.0 | 1,234 | 7 |" in lines
    # speedup ratio line + acceptance line
    assert any(
        "Speedup vs pre-rewrite reference core" in ln
        and "justitia 2.27x, vtc 1.75x" in ln
        for ln in lines
    )
    assert "**Acceptance (10k tier): min speedup 7.5x.**" in lines
    # closed-loop cell
    assert any(
        "Closed-loop + token streaming (300 sessions, 1318 turns)" in ln
        and "150,664 tokens streamed" in ln
        and "overhead 2.22x" in ln
        for ln in lines
    )

    # engine section: tier, oracle, table row, ratio line, closed-loop
    assert ("## BENCH_engine.json — serving engine hot path "
            "(`benchmarks/perf_engine.py`)") in lines
    assert any(
        "Tier: **full**" in ln and "(6 cells x 4 rounds)" in ln
        and "justitia, vtc" in ln for ln in lines
    )
    assert ("| justitia | low | 2,218.5 | 658.7 | 3.37x | 6.8 | 0 "
            "| 0.28 |") in lines
    assert any(
        "**Speedup vs pre-rewrite engine: min 2.98x, geomean 4.11x**" in ln
        and "<= 0.352" in ln for ln in lines
    )
    assert any(
        "Closed-loop serving (6 sessions/round, 61 turns over 2 timed "
        "rounds)" in ln
        and "372.5 it/s" in ln and "1,159.5 tok/s" in ln
        for ln in lines
    )


def test_render_skips_unknown_benchmark_kind(tmp_path):
    weird = tmp_path / "BENCH_weird.json"
    weird.write_text(json.dumps({"benchmark": "nope", "rows": []}))
    md = render([weird])
    assert "## BENCH_weird.json" in md
    assert "unknown benchmark kind" in md and "`nope`" in md


def test_main_writes_out_and_discovers_defaults(bench_pair, tmp_path,
                                                capsys):
    sim, eng = bench_pair
    out = tmp_path / "TREND.md"
    md = trend_main([str(sim), str(eng), "--out", str(out)])
    assert out.exists() and out.read_text() == md
    assert "simulator core" in md and "serving engine hot path" in md
    capsys.readouterr()

    # explicit missing path: a clean SystemExit, not a traceback
    with pytest.raises(SystemExit, match="missing BENCH files"):
        trend_main([str(tmp_path / "nope.json")])

    # the default candidate list is the repo-root contract other tooling
    # (ci.sh artifact upload) relies on
    assert DEFAULT_CANDIDATES == (
        "BENCH_sim.json", "BENCH_sim_quick.json",
        "BENCH_engine.json", "BENCH_engine_quick.json",
        "BENCH_cache.json", "BENCH_cache_quick.json",
        "BENCH_slo.json", "BENCH_slo_quick.json",
        "BENCH_faults.json", "BENCH_faults_quick.json",
        "BENCH_suspend.json", "BENCH_suspend_quick.json",
        "BENCH_fleet.json", "BENCH_fleet_quick.json",
    )


CACHE_DATA = {
    "benchmark": "prefix_cache_perf",
    "quick": True,
    "config": {
        "family": "chat", "agents": 32, "pool_tokens": 384,
        "delay_bound_ratio": 1.15,
    },
    "gates": {
        "cache_off_bit_identical": True,
        "locality_hit_gt_justitia": True,
        "max_delay_ratio": 0.972,
    },
    "engine_cells": [
        {
            "scheduler": "justitia", "hit_rate": 0.728,
            "prefill_tokens_saved": 11600.0, "evictions": 162.0,
            "jct_mean_delta": -259.0, "jct_max_delta": -468.0,
        },
        {
            "scheduler": "locality_fair", "hit_rate": 0.754,
            "prefill_tokens_saved": 12016.0, "evictions": 135.0,
            "jct_mean_delta": -401.1, "jct_max_delta": -523.0,
        },
    ],
    "sim_cells": [
        {
            "scheduler": "justitia", "hit_fraction_mean": 0.813,
            "jct_mean_delta": -0.94,
        },
        {
            "scheduler": "locality_fair", "hit_fraction_mean": 0.813,
            "jct_mean_delta": -0.97,
        },
    ],
    "deficit_sweep": [
        {"bound_pools": 0.5, "hit_rate": 0.567, "jct_max": 795.0},
        {"bound_pools": 1.0, "hit_rate": 0.754, "jct_max": 651.0},
    ],
}


FAULTS_DATA = {
    "benchmark": "faults_perf",
    "quick": True,
    "config": {
        "replicas": 4, "agents": 16, "watchdog_timeout": 0.5,
        "watermark": [0.5, 0.75],
    },
    "gates": {
        "fault_off_bit_identical": True,
        "chaos_deterministic": True,
        "watermark_cuts_swaps": True,
    },
    "crash_cells": [
        {
            "seed": 7, "crashed_replica": 0, "crash_time": 4.33,
            "agents_requeued": 4, "max_jct_ratio": 1.51,
            "makespan_ratio": 1.38,
        },
    ],
    "watermark_cells": [
        {
            "seed": 7, "swaps_off": 5, "swaps_wm": 0, "deferrals": 19,
            "jct_mean_ratio": 1.48,
        },
    ],
    "engine_crash": {
        "agents": 4, "agents_requeued": 2, "makespan": 103.0,
    },
}


def test_render_faults_golden_rows(tmp_path):
    path = tmp_path / "BENCH_faults_quick.json"
    path.write_text(json.dumps(FAULTS_DATA))
    md = render([path])
    lines = md.splitlines()
    assert ("## BENCH_faults_quick.json — fault-tolerant fleet serving "
            "(`benchmarks/perf_faults.py`)") in lines
    assert any(
        "Tier: **quick (CI)**" in ln and "4 replicas, 16 agents" in ln
        and "fault-off bit-identical: **True**" in ln
        and "chaos deterministic: **True**" in ln
        for ln in lines
    )
    assert "| 7 | r0 | 4.33 | 4 | 1.51 | 1.38 |" in lines
    assert any(
        "Watermark admission [0.5, 0.75]" in ln
        and "swaps 5 -> 0 (19 deferrals, jct ratio 1.48)" in ln
        for ln in lines
    )
    assert any(
        "Engine fleet crash: 2 requeued, 4 completed on the survivor" in ln
        for ln in lines
    )


SUSPEND_DATA = {
    "benchmark": "suspend_perf",
    "quick": True,
    "config": {
        "replicas": 2, "agents": 12, "family": "tooluse",
        "max_retention_jct_ratio": 3.0,
    },
    "gates": {
        "suspend_off_bit_identical": True,
        "think_fleet_deterministic": True,
        "drop_evictions_lt_hold": True,
        "hold_escalates_under_pressure": True,
    },
    "retention_cells": [
        {
            "seed": 7,
            "per_retention": {
                "hold": {"swaps": 5, "suspensions": 31, "resumes": 31,
                         "suspend_spills": 52, "held_peak": 1184.0,
                         "jct_mean": 14.91, "max_jct": 36.02},
                "drop": {"swaps": 5, "suspensions": 31, "resumes": 31,
                         "suspend_spills": 0, "held_peak": 0.0,
                         "jct_mean": 14.82, "max_jct": 35.48},
            },
            "evictions_hold": 57, "evictions_drop": 5,
            "max_jct_spread": 1.02,
        },
    ],
    "engine_retention": {
        "agents": 6,
        "per_retention": {
            "hold": {"swaps": 23, "suspensions": 18, "resumes": 18,
                     "suspend_spills": 18},
        },
    },
}


def test_render_suspend_golden_rows(tmp_path):
    path = tmp_path / "BENCH_suspend_quick.json"
    path.write_text(json.dumps(SUSPEND_DATA))
    md = render([path])
    lines = md.splitlines()
    assert ("## BENCH_suspend_quick.json — think-time suspension + KV "
            "retention (`benchmarks/perf_suspend.py`)") in lines
    assert any(
        "Tier: **quick (CI)**" in ln and "12 tooluse sessions" in ln
        and "suspend-off bit-identical: **True**" in ln
        and "drop evicts < hold: **True**" in ln
        for ln in lines
    )
    assert "| 7 | hold | 5 | 31 | 52 | 1,184.0 | 14.91 | 36.02 |" in lines
    assert "| 7 | drop | 5 | 31 | 0 | 0.00 | 14.82 | 35.48 |" in lines
    assert any(
        "evictions hold 57 vs drop 5" in ln
        and "max-JCT spread 1.02" in ln for ln in lines
    )
    assert any(
        "Engine retention (6 sessions, tight pool)" in ln
        and "hold: 18 suspensions, 18 escalations, swaps 23" in ln
        for ln in lines
    )


def test_render_cache_golden_rows(tmp_path):
    path = tmp_path / "BENCH_cache_quick.json"
    path.write_text(json.dumps(CACHE_DATA))
    md = render([path])
    lines = md.splitlines()
    assert ("## BENCH_cache_quick.json — prefix cache fairness-vs-hit-rate "
            "(`benchmarks/perf_cache.py`)") in lines
    assert any(
        "Tier: **quick (CI)**" in ln and "chat family, 32 sessions" in ln
        and "cache-off bit-identical: **True**" in ln
        and "max-delay ratio 0.972" in ln
        for ln in lines
    )
    assert ("| justitia | 0.728 | 11,600.0 | 162.0 | -259.0 | -468.0 "
            "| 0.813 | -0.94 |") in lines
    assert ("| locality_fair | 0.754 | 12,016.0 | 135.0 | -401.1 "
            "| -523.0 | 0.813 | -0.97 |") in lines
    assert any(
        "Deficit-bound sweep (locality_fair)" in ln
        and "0.5x pool: hit 0.567" in ln and "1.0x pool: hit 0.754" in ln
        for ln in lines
    )
