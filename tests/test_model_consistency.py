"""Model correctness properties:

  * prefill + incremental decode == teacher-forced forward (per arch);
  * mLSTM parallel form == recurrent scan form (short sequences);
  * SWA ring-buffer decode == full-cache decode with a window mask;
  * ragged prompts: per-row lens mask the cache correctly.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import Model
from repro.models import ssm as ssm_mod

# full-zoo consistency sweeps dominate tier-1 runtime; run via `pytest -m slow`
pytestmark = pytest.mark.slow

B, S, EXTRA = 2, 12, 3


def _cfg(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        # dropless capacity so decode (cap=1/token) matches teacher forcing
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    return cfg


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = _cfg(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (B, S + EXTRA), 0, cfg.vocab
    ).astype(jnp.int32)
    extra, n_off = {}, 0
    if cfg.kind == "encdec":
        extra["embeds"] = (
            jax.random.normal(jax.random.PRNGKey(2),
                              (B, cfg.n_audio_frames, cfg.d_model)) * 0.1
        )
    if cfg.kind == "vlm":
        extra["embeds"] = (
            jax.random.normal(jax.random.PRNGKey(2),
                              (B, cfg.n_image_tokens, cfg.d_model)) * 0.1
        )
        n_off = cfg.n_image_tokens

    full, _ = model.forward(params, {"tokens": toks, **extra})
    lg, cache = model.prefill(
        params, {"tokens": toks[:, :S], **extra}, cache_len=n_off + S + 8
    )
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(full[:, S - 1], np.float32),
        atol=2e-2, rtol=1e-2,
    )
    for i in range(EXTRA):
        pos = jnp.full((B,), n_off + S + i, jnp.int32)
        lg, cache = model.decode(params, cache, toks[:, S + i : S + i + 1], pos)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full[:, S + i], np.float32),
            atol=2e-2, rtol=1e-2,
        )


def test_mlstm_parallel_equals_recurrent():
    key = jax.random.PRNGKey(0)
    p = ssm_mod.init_mlstm(key, 64, 2, 32, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 64)) * 0.5
    y_par = ssm_mod.mlstm_parallel(p, x)
    y_rec, _ = ssm_mod.mlstm_forward(p, x)
    np.testing.assert_allclose(
        np.asarray(y_par), np.asarray(y_rec), atol=1e-4, rtol=1e-3
    )


def test_mlstm_decode_continues_forward():
    key = jax.random.PRNGKey(0)
    p = ssm_mod.init_mlstm(key, 64, 2, 32, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 11, 64)) * 0.5
    y_all, _ = ssm_mod.mlstm_forward(p, x)
    y10, st = ssm_mod.mlstm_forward(p, x[:, :10])
    y_last, _ = ssm_mod.mlstm_decode(p, x[:, 10:11], st)
    np.testing.assert_allclose(
        np.asarray(y_all[:, 10]), np.asarray(y_last[:, 0]),
        atol=1e-4, rtol=1e-3,
    )


def test_mamba2_decode_continues_forward():
    key = jax.random.PRNGKey(0)
    p = ssm_mod.init_mamba2(key, 64, 16, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 11, 64)) * 0.5
    y_all, _ = ssm_mod.mamba2_forward(p, x)
    y10, (st, conv) = ssm_mod.mamba2_forward(p, x[:, :10])
    y_last, _ = ssm_mod.mamba2_decode(p, x[:, 10:11], st, conv)
    np.testing.assert_allclose(
        np.asarray(y_all[:, 10]), np.asarray(y_last[:, 0]),
        atol=1e-4, rtol=1e-3,
    )


def test_swa_ring_buffer_matches_windowed_full_cache():
    """h2o-danube reduced: decode with the ring cache (T=window) must equal
    decode with a big full cache — SWA masking makes them equivalent."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    assert cfg.sliding_window == 64
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_steps = 8
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (B, S + n_steps), 0, cfg.vocab
    ).astype(jnp.int32)

    # ring cache: cache_len > window -> ring of size window
    _, ring_cache = model.prefill(
        params, {"tokens": toks[:, :S]}, cache_len=cfg.sliding_window + 16
    )
    assert ring_cache["k"].shape[2] == cfg.sliding_window
    # full cache: cache_len < window -> plain cache
    cfg_full = dataclasses.replace(cfg, sliding_window=64)
    model_full = Model(cfg_full)
    _, full_cache = model_full.prefill(
        params, {"tokens": toks[:, :S]}, cache_len=S + n_steps
    )
    assert full_cache["k"].shape[2] == S + n_steps

    for i in range(n_steps):
        pos = jnp.full((B,), S + i, jnp.int32)
        lg_r, ring_cache = model.decode(
            params, ring_cache, toks[:, S + i : S + i + 1], pos
        )
        lg_f, full_cache = model_full.decode(
            params, full_cache, toks[:, S + i : S + i + 1], pos
        )
        np.testing.assert_allclose(
            np.asarray(lg_r, np.float32), np.asarray(lg_f, np.float32),
            atol=2e-2, rtol=1e-2,
        )


def test_ragged_prompt_lens_respected():
    """Row 1's prompt is shorter; its cache slots beyond lens are masked, so
    its decode output must equal an unpadded run of the same prompt."""
    cfg = get_config("granite-3-2b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab
                              ).astype(jnp.int32)
    short = 7
    # batched run: row0 full prompt, row1 short prompt padded with junk
    toks2 = jnp.concatenate(
        [toks, jnp.concatenate([toks[:, :short],
                                jnp.full((1, S - short), 5, jnp.int32)], 1)]
    )
    lens = jnp.array([S, short], jnp.int32)
    lg_b, cache_b = model.prefill(
        params, {"tokens": toks2, "lens": lens}, cache_len=S + 4
    )
    # solo run of the short prompt
    lg_s, _ = model.prefill(
        params, {"tokens": toks[:, :short]}, cache_len=S + 4
    )
    # prefill returns logits at the LAST padded position for row 1; instead
    # compare a decode step conditioned on the masked cache
    nxt = jnp.full((2, 1), 9, jnp.int32)
    pos = jnp.array([S, short], jnp.int32)
    lg_step, _ = model.decode(params, cache_b, nxt, pos)
    _, cache_s = model.prefill(
        params, {"tokens": toks[:, :short]}, cache_len=S + 4
    )
    lg_solo, _ = model.decode(
        params, cache_s, nxt[1:], jnp.array([short], jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(lg_step[1], np.float32),
        np.asarray(lg_solo[0], np.float32),
        atol=2e-2, rtol=1e-2,
    )


def test_mamba2_chunked_equals_sequential():
    key = jax.random.PRNGKey(0)
    p = ssm_mod.init_mamba2(key, 64, 16, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 64)) * 0.5
    y_seq, (st_seq, _) = ssm_mod.mamba2_forward(p, x)
    y_ch, (st_ch, _) = ssm_mod.mamba2_forward_chunked(p, x, chunk=16)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ch),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_seq), np.asarray(st_ch),
                               atol=1e-5, rtol=1e-4)


def test_mlstm_chunked_equals_sequential():
    key = jax.random.PRNGKey(0)
    p = ssm_mod.init_mlstm(key, 64, 2, 32, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 48, 64)) * 0.5
    y_seq, (c1, n1, m1) = ssm_mod.mlstm_forward(p, x)
    y_ch, (c2, n2, m2) = ssm_mod.mlstm_forward_chunked(p, x, chunk=16)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ch),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                               atol=1e-5, rtol=1e-4)


def test_chunked_attention_equals_full():
    from repro.models.layers import chunked_gqa_attention, gqa_attention

    key = jax.random.PRNGKey(0)
    b, s, nh, nkv, hd = 2, 64, 4, 2, 32
    q = jax.random.normal(key, (b, s, nh, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd))
    for window in (0, 24):
        full = gqa_attention(q, k, v, window=window)
        ch = chunked_gqa_attention(q, k, v, window=window,
                                   chunk_q=16, chunk_k=16)
        np.testing.assert_allclose(np.asarray(full), np.asarray(ch),
                                   atol=1e-5, rtol=1e-4)


def test_chunked_lm_loss_equals_plain():
    from repro.training import chunked_lm_loss, lm_loss

    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 24, 16, 64
    x = jax.random.normal(key, (b, s, d))
    head = jax.random.normal(jax.random.PRNGKey(1), (d, v)) * 0.1
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    plain = lm_loss(logits, toks)
    chunked = chunked_lm_loss(x, head, toks, chunk=8)
    np.testing.assert_allclose(float(plain), float(chunked), rtol=1e-5)
