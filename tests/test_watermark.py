"""Watermark admission control (PR 8, satellite S4).

Property-fuzzes the hysteresis admission gate shared by the sim, the
frozen reference core, and the engine:

  * with ``admission_watermark=(low, high)`` a busy pool never admits a
    NEW request above the high watermark (``wm_admit_peak <= high * M``
    whenever the idle-pool bypass never fired), yet every agent still
    completes — deferred requests are eventually admitted once occupancy
    drains below the low watermark;
  * deferral delays but never reorders admission: under a static
    scheduler the admitted-rid sequence is identical with and without
    the gate;
  * the gate is LOCKSTEP with the frozen reference core — same results,
    same deferral counts, watermark on or off (the frozen-oracle
    invariant extended to its third flag, after token_events and
    prefix_cache);
  * on a contended pool the gate trades queueing delay for swap thrash:
    strictly fewer swaps at equal completions;
  * each deferred rid emits AdmissionDeferred exactly once, before its
    admit, and the serving layer surfaces it on the agent handle;
  * the engine's block-granular gate defers and still completes.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from test_event_conformance import assert_conformant_stream

from repro.api import AdmissionDeferred, AgentService, AgentSpec
from repro.configs import get_config
from repro.core import InferenceSpec, agent_cost, make_scheduler
from repro.models import Model
from repro.sim import ClusterSim, SimAgent
from repro.sim.reference import ReferenceClusterSim

DECODE_RATE = 30.0

agents_strategy = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=30.0),        # arrival
        st.lists(                                        # one stage
            st.tuples(
                st.integers(min_value=50, max_value=600),   # prefill
                st.integers(min_value=8, max_value=120),    # decode
            ),
            min_size=1,
            max_size=2,
        ),
    ),
    min_size=2,
    max_size=10,
)

watermark_strategy = st.tuples(
    st.floats(min_value=0.3, max_value=0.6),             # low
    st.floats(min_value=0.6, max_value=0.95),            # high
)


def _sim_agents(raw):
    agents = []
    for i, (arr, stage) in enumerate(raw):
        stages = [[InferenceSpec(p, d) for p, d in stage]]
        cost = agent_cost(stages[0])
        agents.append(
            SimAgent(agent_id=i, arrival=float(arr), stages=stages,
                     predicted_cost=cost, true_cost=cost)
        )
    return agents


class _AdmitLog:
    """Listener capturing admit order and deferral emissions."""

    def __init__(self):
        self.admits = []        # rid admission order
        self.deferred = []      # (agent_id, rid) deferral emissions

    def on_admit(self, agent_id, rid, t):
        self.admits.append(rid)

    def on_admission_deferred(self, agent_id, rid, t):
        self.deferred.append((agent_id, rid))


def test_watermark_validation():
    sched = make_scheduler("justitia", 1000.0, service_rate=DECODE_RATE)
    for bad in ((0.0, 0.5), (0.9, 0.5), (0.5, 1.5), (-0.1, 0.5)):
        with pytest.raises(ValueError, match="admission_watermark"):
            ClusterSim(sched, 1000.0, admission_watermark=bad)
        with pytest.raises(ValueError, match="admission_watermark"):
            ReferenceClusterSim(sched, 1000.0, admission_watermark=bad)


@given(agents_strategy, watermark_strategy,
       st.sampled_from(["justitia", "vtc", "vllm-fcfs"]))
@settings(max_examples=25, deadline=None)
def test_gate_bounds_peak_and_always_completes(raw, wm, sched):
    """Never admit above high while busy (absent bypass); always drain."""
    low, high = wm
    m = 1000.0
    res = ClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m,
        admission_watermark=(low, high),
    ).run(_sim_agents(raw))
    assert set(res.finish) == set(range(len(raw))), "gate starved an agent"
    if res.wm_bypass_admits == 0:
        assert res.wm_admit_peak <= high * m + 1e-9
    assert res.admission_deferrals >= 0


@given(agents_strategy, watermark_strategy,
       st.sampled_from(["justitia", "vtc", "srjf", "vllm-fcfs"]))
@settings(max_examples=25, deadline=None)
def test_watermark_lockstep_with_frozen_reference(raw, wm, sched):
    """ClusterSim and the frozen reference agree bit-for-bit, gate ON."""
    m = 1200.0
    la, lb = _AdmitLog(), _AdmitLog()
    new = ClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m,
        listener=la, admission_watermark=wm,
    ).run(_sim_agents(raw))
    ref = ReferenceClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m,
        listener=lb, admission_watermark=wm,
    ).run(_sim_agents(raw))
    assert new.finish == ref.finish
    assert new.jct == ref.jct
    assert new.swaps == ref.swaps
    assert new.admission_deferrals == ref.admission_deferrals
    assert new.wm_admit_peak == ref.wm_admit_peak
    assert new.wm_bypass_admits == ref.wm_bypass_admits
    assert la.admits == lb.admits
    assert la.deferred == lb.deferred


@given(agents_strategy, st.sampled_from(["justitia", "vllm-fcfs"]))
@settings(max_examples=15, deadline=None)
def test_watermark_off_bit_identical(raw, sched):
    """admission_watermark=None leaves the admission pass untouched."""
    m = 900.0
    off = ClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m,
        admission_watermark=None,
    ).run(_sim_agents(raw))
    ref = ReferenceClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m,
    ).run(_sim_agents(raw))
    assert off.finish == ref.finish
    assert off.jct == ref.jct
    assert off.swaps == ref.swaps
    assert off.admission_deferrals == 0
    assert off.wm_admit_peak == 0.0


@given(agents_strategy)
@settings(max_examples=15, deadline=None)
def test_gate_delays_but_never_reorders_admission(raw):
    """Static FCFS: the admitted-rid sequence is identical with and
    without the gate — deferral preserves scheduler order.  Pool is wide
    enough that nothing swaps (re-admission order is timing-dependent),
    but the high watermark sits well below it so deferrals still occur."""
    m = 4000.0
    runs = []
    for wm in (None, (0.3, 0.45)):
        log = _AdmitLog()
        res = ClusterSim(
            make_scheduler("vllm-fcfs", m, service_rate=DECODE_RATE), m,
            listener=log, admission_watermark=wm,
        ).run(_sim_agents(raw))
        assert res.swaps == 0
        runs.append((log, res))
    (log_off, _), (log_wm, res_wm) = runs
    assert log_wm.admits == log_off.admits
    assert len(log_wm.deferred) == res_wm.admission_deferrals
    # exactly-once emission per deferred rid
    assert len(set(log_wm.deferred)) == len(log_wm.deferred)


def test_idle_pool_bypass_admits_oversized():
    """An agent bigger than the high watermark admits on an idle pool
    (progress guarantee) and the violation is recorded."""
    m = 1000.0
    agents = [
        SimAgent(agent_id=0, arrival=0.0,
                 stages=[[InferenceSpec(900, 30)]],
                 predicted_cost=1.0, true_cost=1.0)
    ]
    res = ClusterSim(
        make_scheduler("justitia", m, service_rate=DECODE_RATE), m,
        admission_watermark=(0.4, 0.6),
    ).run(agents)
    assert set(res.finish) == {0}
    assert res.wm_bypass_admits >= 1
    assert res.wm_admit_peak > 0.6 * m


def _contended_specs(n=14, seed=3):
    rng = np.random.default_rng(seed)
    specs = []
    for i in range(n):
        pf = int(rng.integers(250, 500))
        specs.append(
            AgentSpec(
                stages=[[InferenceSpec(pf, int(rng.integers(40, 90)))]],
                arrival=float(rng.uniform(0.0, 2.0)),
                name=f"c{i}",
            )
        )
    return specs


def test_watermark_reduces_swap_thrash_at_equal_completions():
    """The headline trade: on a contended pool the gate strictly cuts
    swaps while every agent still completes (the perf_faults.py
    watermark cell asserts the same in-run oracle)."""
    results = {}
    for wm in (None, (0.5, 0.75)):
        svc = AgentService.sim(total_kv=1000.0, admission_watermark=wm)
        [svc.submit(s) for s in _contended_specs()]
        results[wm] = svc.drain()
    off, on = results[None], results[(0.5, 0.75)]
    assert set(on.finish) == set(off.finish)
    assert on.metrics["admission_deferrals"] > 0
    assert on.swaps < off.swaps, (
        f"watermark did not cut swaps: {on.swaps} vs {off.swaps}"
    )


def test_deferral_surfaces_on_handle_and_conformance():
    """AdmissionDeferred lands on the agent handle before its admit and
    the extended conformance grammar accepts (and checks) it."""
    svc = AgentService.sim(total_kv=1000.0,
                           admission_watermark=(0.5, 0.75))
    handles = [svc.submit(s) for s in _contended_specs()]
    res = svc.drain()
    assert res.event_counts.get("AdmissionDeferred", 0) == (
        res.metrics["admission_deferrals"]
    )
    deferred_handles = 0
    for h in handles:
        assert_conformant_stream(h, expect_tokens=False)
        evs = [e for e in h.events if isinstance(e, AdmissionDeferred)]
        if evs:
            deferred_handles += 1
            # exactly-once per rid
            rids = [e.rid for e in evs]
            assert len(set(rids)) == len(rids)
    assert deferred_handles > 0


def test_fleet_aggregates_deferrals():
    svc = AgentService.sim(replicas=2, total_kv=1000.0,
                           admission_watermark=(0.5, 0.75),
                           router="round_robin")
    [svc.submit(s) for s in _contended_specs(n=20)]
    res = svc.drain()
    assert set(res.finish) == set(range(20))
    assert res.metrics["admission_deferrals"] > 0


def test_engine_watermark_defers_and_completes():
    import jax

    cfg = get_config("granite-3-2b").reduced(vocab=256)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    svc = AgentService.engine(
        model, params, "justitia",
        pool_tokens=192, block_size=16, max_batch=3, cache_len=64,
        token_scale=1, time_scale=1.0,
        admission_watermark=(0.3, 0.5),
    )
    handles = [
        svc.submit(AgentSpec(stages=[[InferenceSpec(40, 12)]],
                             arrival=float(i) * 0.5))
        for i in range(5)
    ]
    res = svc.drain()
    assert set(res.finish) == {h.agent_id for h in handles}
    assert res.metrics["admission_deferrals"] > 0
    assert res.event_counts.get("AdmissionDeferred", 0) == (
        res.metrics["admission_deferrals"]
    )
    for h in handles:
        assert_conformant_stream(h)
