"""Test-suite bootstrap.

The container this repo runs in does not ship ``hypothesis`` (and nothing
may be pip-installed).  Without it, five test modules fail at *collection*,
which under ``pytest -x`` aborts the whole tier-1 run.  This conftest
installs a minimal stand-in when the real package is missing: strategy
constructors return inert placeholders and ``@given`` replaces the test
body with an explicit skip, so property tests are reported as skipped while
every example-based test in the same modules still runs.  When hypothesis
IS available, this file does nothing.
"""

from __future__ import annotations

import sys
import types

try:  # pragma: no cover - exercised only where hypothesis exists
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    class _Strategy:
        """Inert placeholder: composes like a strategy, generates nothing."""

        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, *args, **kwargs):
            return self

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

        def flatmap(self, fn):
            return self

    def _make_strategy(*args, **kwargs):
        return _Strategy()

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _make_strategy

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg on purpose: pytest must not mistake the property
            # test's strategy parameters for fixtures
            def skipper():
                pytest.skip("hypothesis not installed (stubbed by conftest)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = lambda *a, **k: True
    hyp.note = lambda *a, **k: None
    hyp.strategies = strategies
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies
