"""Test-suite bootstrap.

The container this repo runs in does not ship ``hypothesis`` (and nothing
may be pip-installed).  When the real package is missing, this conftest
installs ``tests/_minihyp.py`` in its place: a minimal, seeded property-test
runner implementing the strategy surface this suite uses (``integers``,
``floats``, ``lists``, ``tuples``, ``sampled_from``), so ``@given``
properties execute their assertions for real — deterministically across
pytest runs — instead of being skipped as they were with the old inert
stub.  When hypothesis IS available, this file leaves it alone.

Also provides the ``fixed_seed`` fixture used by the multi-replica
equivalence tests to keep routing/workload sampling identical across runs.
"""

from __future__ import annotations

import importlib.util
import pathlib
import sys

import pytest

try:  # pragma: no cover - exercised only where hypothesis exists
    import hypothesis  # noqa: F401
except ImportError:
    _path = pathlib.Path(__file__).with_name("_minihyp.py")
    _spec = importlib.util.spec_from_file_location("_minihyp", _path)
    _minihyp = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_minihyp)
    sys.modules["hypothesis"] = _minihyp
    sys.modules["hypothesis.strategies"] = _minihyp.strategies


@pytest.fixture
def fixed_seed() -> int:
    """One seed for routing/workload RNGs: deterministic across pytest runs."""
    return 20260730
