"""Concurrent fleet advancement + load-triggered work stealing (PR 10).

The contract under test: ``fleet_workers > 1`` is purely a wall-clock
knob — same plan, same workload, same steal configuration must produce an
event-for-event bit-identical run (orders, timestamps, JCTs, global-clock
sequence assignment) to the sequential lockstep loop, because the only
difference is that each slice's children step on a thread pool and their
buffered events are replayed in child-index order.  Work stealing must
only ever migrate queued, never-admitted, never-suspended agents, and the
``least_loaded`` router must normalize its live-agent counts by replica
capacity.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import AgentService, FaultPlan
from repro.api.backend import AgentSpec, InferenceSpec, SimBackend
from repro.api.replicated import ReplicatedBackend
from repro.api.workload import specs_from_closed_loop
from repro.core.virtual_time import GlobalVirtualClock


# ------------------------------------------------------------- helpers


class RawTape:
    """Duck-typed fleet listener recording every forwarded callback as an
    exact ``(event, agent_id, args, t, replica)`` tuple — the raw global
    stream whose order and timestamps the bit-identity property compares.
    """

    _EVENTS = (
        "on_arrival", "on_admit", "on_swap_out", "on_swap_in", "on_token",
        "on_prefix_hit", "on_admission_deferred", "on_stage_complete",
        "on_suspend", "on_resume", "on_agent_complete", "on_requeued",
        "on_replica_failed", "on_replica_recovered",
    )

    def __init__(self):
        self.events = []

    def __getattr__(self, name):
        if name in self._EVENTS:
            def record(agent_id, *args, replica=None):
                # last positional is the timestamp by channel convention
                self.events.append((name, agent_id, args, replica))
            return record
        raise AttributeError(name)


def _specs(raw):
    return [
        AgentSpec(
            stages=[[InferenceSpec(p, d) for p, d in stage]
                    for stage in stages],
            arrival=float(arr),
        )
        for arr, stages in raw
    ]


workload_strategy = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=8.0),
        st.lists(
            st.lists(
                st.tuples(
                    st.integers(min_value=40, max_value=300),   # prefill
                    st.integers(min_value=5, max_value=60),     # decode
                ),
                min_size=1, max_size=2,
            ),
            min_size=1, max_size=2,
        ),
    ),
    min_size=2, max_size=8,
)


def _fleet(n=3, *, total_kv=900.0, plan=None, **kw):
    children = [
        SimBackend("justitia", total_kv=total_kv, token_events=True)
        for _ in range(n)
    ]
    return ReplicatedBackend(
        children, router="round_robin", fault_plan=plan, **kw
    )


def _raw_run(raw, *, plan=None, watchdog=None, **kw):
    fleet = _fleet(
        plan=plan, watchdog_timeout=watchdog, **kw
    )
    tape = RawTape()
    fleet.set_listener(tape)
    for aid, spec in enumerate(_specs(raw)):
        fleet.submit(spec, aid)
    fleet.run(4.0)
    fleet.run(40.0)
    res = fleet.drain()
    order = fleet.pampering_order()
    fleet.close()
    return tape.events, res, order


# ------------------------------------------- bit-identity property tests


@given(workload_strategy)
@settings(max_examples=10, deadline=None)
def test_concurrent_raw_stream_bit_identical(raw):
    """Concurrent advancement replays the sequential loop's exact global
    event stream — same events, same order, same timestamps, same serving
    replicas — with and without a fault plan, and the reconciled
    pampering order (global F_j sequence assignment) matches too."""
    for plan, wd in [(None, None), (FaultPlan().crash(0, 1.5), 2.0)]:
        seq_ev, seq_res, seq_ord = _raw_run(raw, plan=plan, watchdog=wd)
        con_ev, con_res, con_ord = _raw_run(
            raw, plan=plan, watchdog=wd, fleet_workers=3
        )
        assert con_ev == seq_ev
        assert con_res.jct == seq_res.jct
        assert con_res.finish == seq_res.finish
        assert con_ord == seq_ord


@given(workload_strategy)
@settings(max_examples=6, deadline=None)
def test_concurrent_with_steal_bit_identical(raw):
    """The steal configuration slices both modes at the same interval
    targets, so sequential-with-steal and concurrent-with-steal agree
    event for event (including the AgentRequeued migrations)."""
    kw = dict(steal_threshold=1.3, steal_interval=0.5)
    seq_ev, seq_res, seq_ord = _raw_run(raw, **kw)
    con_ev, con_res, con_ord = _raw_run(raw, fleet_workers=3, **kw)
    assert con_ev == seq_ev
    assert con_res.jct == seq_res.jct
    assert con_ord == seq_ord


@given(st.integers(min_value=0, max_value=10_000),
       st.sampled_from([True, False]))
@settings(max_examples=6, deadline=None)
def test_concurrent_closed_loop_and_suspend_identical(seed, accrual):
    """Service-level identity across closed-loop sessions (in-band
    advancement during concurrent slices) and think-time suspensions,
    under both GPS accrual stances."""

    def run(**fleet):
        rng = np.random.default_rng(seed)
        specs = specs_from_closed_loop(
            rng, 8, 8.0, classes=("chat", "tooluse")
        )
        svc = AgentService.sim(
            "justitia", replicas=2, total_kv=768.0, token_events=True,
            think_time_accrual=accrual, **fleet,
        )
        handles = [svc.submit(s) for s in specs]
        svc.run(5.0)
        res = svc.drain()
        streams = {
            h.agent_id: [
                (type(e).__name__, e.time, getattr(e, "replica", None))
                for e in h.events
            ]
            for h in handles
        }
        return res, streams

    seq_res, seq_streams = run()
    con_res, con_streams = run(fleet_workers=2)
    assert con_streams == seq_streams
    assert con_res.jct == seq_res.jct
    assert con_res.event_counts == seq_res.event_counts


# --------------------------------------------------------- work stealing


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=8, deadline=None)
def test_steal_never_migrates_admitted_or_suspended(seed):
    """Every stolen agent was queued and cold at the moment of the steal:
    no RequestAdmitted / AgentSuspended event for it precedes its
    AgentRequeued timestamp, and completions are conserved."""
    rng = np.random.default_rng(seed)
    raw = [
        (
            float(rng.uniform(0.0, 3.0)),
            [[(int(rng.integers(80, 300)), int(rng.integers(10, 50)))]
             for _ in range(int(rng.integers(1, 3)))],
        )
        for _ in range(int(rng.integers(6, 14)))
    ]
    tape_events, res, _ = _raw_run(
        raw, total_kv=400.0, steal_threshold=1.2, steal_interval=0.25,
        fleet_workers=3,
    )
    steal_t = {}
    for name, aid, args, _rep in tape_events:
        if name == "on_requeued":
            steal_t.setdefault(aid, args[-1])   # first migration time
    for name, aid, args, _rep in tape_events:
        if aid in steal_t and name in ("on_admit", "on_suspend"):
            assert args[-1] >= steal_t[aid] - 1e-9, (
                f"agent {aid} had {name} at {args[-1]} before its steal "
                f"at {steal_t[aid]}"
            )
    assert len(res.finish) == len(raw)


def test_steal_threshold_validation():
    with pytest.raises(ValueError, match="steal_threshold"):
        _fleet(steal_threshold=1.0)
    with pytest.raises(ValueError, match="steal_interval"):
        _fleet(steal_threshold=1.5, steal_interval=0.0)
    with pytest.raises(ValueError, match="replicated fleet"):
        AgentService.sim("justitia", replicas=1, fleet_workers=2)


def test_steal_carries_virtual_finish():
    """A steal's clock surgery: an un-reconciled pending arrival moves
    wholesale; a reconciled one keeps its recorded F_j (the pampering
    order cannot change) while its GPS share leaves the source clock."""
    g = GlobalVirtualClock([10.0, 10.0])
    g.register(0, 1, 0.0, 50.0)
    g.register(0, 2, 1.0, 50.0)
    # agent 1 reconciled, agent 2 still pending at steal time
    g.reconcile(0.5)
    f1 = g.virtual_finish[1]
    assert g.steal(1, 0, 1, 1.0, 50.0) == pytest.approx(f1)
    assert g.steal(2, 0, 1, 1.0, 50.0) is None
    snap = g.reconcile(2.0)
    assert g.virtual_finish[1] == pytest.approx(f1)   # carried, not redone
    assert g.replica_of[1] == 1 and g.replica_of[2] == 1
    assert snap.time == 2.0
    with pytest.raises(ValueError, match="dead"):
        g.fail_replica(0)
        g.steal(2, 0, 1, 3.0, 50.0)


def test_backend_cancel_only_never_admitted():
    """Backend.cancel is the authoritative steal gate: queued whole-stage
    agents withdraw silently, anything ever admitted refuses."""
    b = SimBackend("justitia", total_kv=200.0)
    b.submit(AgentSpec(stages=[[InferenceSpec(50, 20)]], arrival=5.0), 0)
    b.submit(AgentSpec(stages=[[InferenceSpec(50, 20)]], arrival=0.0), 1)
    assert b.cancel(0)            # still in the arrival heap
    assert not b.cancel(0)        # already gone
    b.run(0.5)                    # agent 1 admitted and decoding
    assert not b.cancel(1)
    res = b.drain()
    assert set(res.finish) == {1}


# ------------------------------------------------- least_loaded satellite


def test_least_loaded_normalizes_by_capacity():
    """2:1 capacity fleet, 6 far-future agents: the capacity-normalized
    router places 4:2 (proportional), where the raw-count router used to
    alternate 3:3 and overload the small replica."""
    children = [
        SimBackend("justitia", total_kv=1024.0),
        SimBackend("justitia", total_kv=512.0),
    ]
    fleet = ReplicatedBackend(children, router="least_loaded")
    assert fleet.virtual_capacities[0] == 2 * fleet.virtual_capacities[1]
    for aid in range(6):
        fleet.submit(
            AgentSpec(stages=[[InferenceSpec(60, 20)]], arrival=1e6), aid
        )
    picks = [fleet.assignment[a] for a in range(6)]
    assert picks == [0, 1, 0, 0, 1, 0]
    assert fleet.live_agents == [4, 2]


def test_least_loaded_homogeneous_unchanged():
    """Equal capacities: normalization divides by a constant, so the
    placement sequence is the classic fewest-live-agents alternation."""
    children = [SimBackend("justitia", total_kv=512.0) for _ in range(3)]
    fleet = ReplicatedBackend(children, router="least_loaded")
    for aid in range(6):
        fleet.submit(
            AgentSpec(stages=[[InferenceSpec(60, 20)]], arrival=1e6), aid
        )
    assert [fleet.assignment[a] for a in range(6)] == [0, 1, 2, 0, 1, 2]


# ------------------------------------------- watchdog diagnostics satellite


def test_queue_depth_snapshot_labels_dead_replicas():
    """After a failover the diagnostic snapshot reports live replicas'
    in-flight counts and labels the dead one explicitly instead of
    counting its stranded queue as drainable backlog."""
    plan = FaultPlan().crash(0, 1.0)
    fleet = _fleet(plan=plan, watchdog_timeout=0.5, watchdog_retries=1)
    for aid, spec in enumerate(_specs(
        [(0.0, [[(200, 40)]]), (0.1, [[(200, 40)]]), (0.2, [[(200, 40)]])]
    )):
        fleet.submit(spec, aid)
    fleet.run(10.0)
    assert fleet.dead_replica_indices == (0,)
    depths = fleet._queue_depths()
    assert depths[0] == "dead"
    for k in (1, 2):
        assert isinstance(depths[k], int)
    fleet.drain()


# ------------------------------------------------------- streaming mode


def test_streaming_mode_drops_per_agent_state():
    """retain_agents=False + retain_results=False: per-agent fleet and
    sim bookkeeping drains to zero once everything completes and
    compact() has swept the clock — the 1M-agent bench's memory gate in
    miniature."""
    children = [
        SimBackend("justitia", total_kv=512.0, retain_results=False)
        for _ in range(2)
    ]
    fleet = ReplicatedBackend(
        children, router="round_robin", retain_agents=False,
        fleet_workers=2,
    )
    done = []
    class Tap:
        def on_agent_complete(self, aid, t, replica=None):
            done.append(aid)
        def __getattr__(self, name):
            if name.startswith("on_"):
                return lambda *a, **k: None
            raise AttributeError(name)
    fleet.set_listener(Tap())
    n = 40
    for aid in range(n):
        fleet.submit(
            AgentSpec(stages=[[InferenceSpec(60, 10)]],
                      arrival=0.05 * aid), aid,
        )
    fleet.run(30.0)
    fleet.compact(fleet.now)
    assert len(done) == n
    assert not fleet._specs and not fleet._arrival0 and not fleet.assignment
    assert not fleet.global_clock.virtual_finish
    assert all(not c.sim._by_id for c in fleet.children)
    assert not fleet._compact_done
    fleet.close()


# ------------------------------------------------------- engine backend


@pytest.fixture(scope="module")
def tiny_model():
    import jax

    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config("granite-3-2b").reduced(vocab=128)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_engine_fleet_concurrent_bit_identical(tiny_model):
    model, params = tiny_model

    def run(**fleet):
        svc = AgentService.engine(
            model, params, "justitia", replicas=2, router="round_robin",
            pool_tokens=256, block_size=16, max_batch=2, cache_len=64,
            token_scale=1, time_scale=1.0, **fleet,
        )
        for i in range(4):
            svc.submit(AgentSpec(
                stages=[[InferenceSpec(16, 20)], [InferenceSpec(12, 12)]],
                arrival=0.5 * i, name=f"a{i}",
            ))
        svc.run(3.0)
        res = svc.drain()
        return res

    seq = run()
    con = run(fleet_workers=2)
    assert con.jct == seq.jct
    assert con.event_counts == seq.event_counts
    assert con.metrics["fleet_workers"] == 2
