"""PR 9 suspension semantics: grammar fuzz, KV retention, failover.

A closed-loop agent whose stage callback reports a ``resume_delay``
SUSPENDS at the stage boundary: it holds no decode slot for the think
time, its KV falls under the backend's ``suspend_retention`` policy
(hold / spill / drop), and memory pressure victimizes suspended agents
before running ones.  Checked here:

  * fuzzed Suspended/Resumed grammar interleavings on the sim backend
    under every retention (conformance rules live in
    ``test_event_conformance.assert_conformant_stream``);
  * retention observables: ``hold`` pins KV (``held_peak`` > 0) and
    escalates under pressure; ``drop`` pins nothing;
  * fuzzed grammar under crash failover on a 2-replica fleet
    (``allow_requeue``): suspensions stay balanced through migration;
  * a suspended agent on a crashed replica resumes EXACTLY ONCE, on the
    survivor, no earlier than its think deadline, with its accrued
    virtual finish time carried (``GlobalVirtualClock.migrate`` keeps
    the recorded F_j — a crash cannot demote a thinking agent);
  * the Equinox question: ``think_time_accrual=False`` removes thinking
    agents from the fleet GPS reference via buffered suspend/resume
    notes; both stances serve the workload to completion;
  * the real engine serves the same grammar (hold and drop).
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from test_event_conformance import assert_conformant_stream

from repro.api import (
    AgentRequeued,
    AgentResumed,
    AgentService,
    AgentSpec,
    AgentSuspended,
    EngineBackend,
    FaultPlan,
    SimBackend,
    StageCompleted,
)
from repro.configs import get_config
from repro.core import InferenceSpec
from repro.core.virtual_time import GlobalVirtualClock
from repro.models import Model

RETENTIONS = ("hold", "spill", "drop")


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("granite-3-2b").reduced(vocab=256)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


class ScriptedSession:
    """Deterministic closed-loop callback: fixed follow-up stages, each
    preceded by a fixed think delay (0.0 = no suspension)."""

    def __init__(self, stages, delays):
        assert len(stages) == len(delays)
        self.stages = [list(s) for s in stages]
        self.delays = list(delays)
        self.i = 0
        self.last_resume_delay = None

    def __call__(self, outcome):
        if self.i >= len(self.stages):
            return None
        self.last_resume_delay = self.delays[self.i]
        stage = self.stages[self.i]
        self.i += 1
        return stage


def _specs(raw):
    """raw: [(arrival, [stage0, stage1, ...], [delay1, ...])] where each
    stage is [(p, d), ...] and delay k precedes follow-up stage k."""
    specs = []
    for arrival, stages, delays in raw:
        first, rest = stages[0], stages[1:]
        specs.append(AgentSpec(
            stages=[[InferenceSpec(p, d) for p, d in first]],
            arrival=float(arrival),
            next_stage=ScriptedSession(
                [[InferenceSpec(p, d) for p, d in s] for s in rest],
                delays,
            ),
        ))
    return specs


def _demands(raw_agent):
    _, stages, _ = raw_agent
    return [d for stage in stages for _, d in stage]


# agents: staggered arrivals, 1-3 stages of 1-2 inferences, think delays
# in {0} U [0.3, 4.0] before each follow-up stage
think_workload = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=10.0),
        st.lists(
            st.lists(
                st.tuples(
                    st.integers(min_value=40, max_value=250),
                    st.integers(min_value=5, max_value=50),
                ),
                min_size=1, max_size=2,
            ),
            min_size=1, max_size=3,
        ),
        st.lists(
            st.one_of(
                st.just(0.0),
                st.floats(min_value=0.3, max_value=4.0),
            ),
            min_size=2, max_size=2,
        ),
    ).map(lambda t: (t[0], t[1], t[2][: max(0, len(t[1]) - 1)])),
    min_size=1, max_size=5,
)


# ------------------------------------------------------- sim grammar fuzz


@given(
    think_workload,
    st.sampled_from([700.0, 4000.0]),          # pressure / roomy
    st.sampled_from(RETENTIONS),
)
@settings(max_examples=20, deadline=None)
def test_sim_suspension_grammar_fuzz(raw, m, retention):
    svc = AgentService(SimBackend(
        "justitia", total_kv=m, token_events=True,
        suspend_retention=retention,
    ))
    handles = svc.submit_many(_specs(raw))
    res = svc.drain()
    assert len(res.finish) == len(raw)
    assert res.metrics["suspensions"] == res.metrics["resumes"]
    expect_susp = sum(
        sum(1 for d in delays if d > 0.0) for _, _, delays in raw
    )
    assert res.metrics["suspensions"] == expect_susp
    for h, raw_agent in zip(handles, raw):
        assert_conformant_stream(h, token_demands=_demands(raw_agent))
        n_susp = sum(isinstance(e, AgentSuspended) for e in h.events)
        assert n_susp == sum(1 for d in raw_agent[2] if d > 0.0)


def test_suspended_holds_no_decode_slot():
    """During think time the agent is in neither running nor swapped: a
    competing agent admitted mid-think sees the full pool (minus held KV
    under ``hold``)."""
    svc = AgentService(SimBackend(
        "justitia", total_kv=500.0, suspend_retention="drop",
    ))
    svc.submit(AgentSpec(
        stages=[[InferenceSpec(200, 20)]], arrival=0.0,
        next_stage=ScriptedSession([[InferenceSpec(200, 20)]], [5.0]),
    ))
    # arrives mid-think; with the thinker's KV dropped, the 400-token
    # prompt fits a 500-token pool without swapping anyone
    svc.submit(AgentSpec(stages=[[InferenceSpec(400, 10)]], arrival=2.0))
    res = svc.drain()
    assert set(res.finish) == {0, 1}
    assert res.swaps == 0
    assert res.metrics["suspensions"] == 1


def test_retention_observables_sim():
    """hold pins KV (held_peak > 0) and escalates under pressure;
    drop pins nothing and never escalates."""
    raw = [
        (0.5 * i,
         [[(180, 30)], [(180, 30)], [(180, 30)]],
         [2.0, 2.0])
        for i in range(6)
    ]
    out = {}
    for retention in RETENTIONS:
        svc = AgentService(SimBackend(
            "justitia", total_kv=700.0, suspend_retention=retention,
        ))
        svc.submit_many(_specs(raw))
        out[retention] = svc.drain()
    for retention, res in out.items():
        assert len(res.finish) == len(raw), retention
        assert res.metrics["suspensions"] == 12, retention
    assert out["hold"].metrics["held_peak"] > 0.0
    assert out["hold"].metrics["suspend_spills"] > 0, (
        "pressure never escalated held KV — the cell is not contended"
    )
    assert out["drop"].metrics["held_peak"] == 0.0
    assert out["drop"].metrics["suspend_spills"] == 0


# --------------------------------------------------- failover interleaving


def _fleet(plan=None, accrual=True, **kw):
    fleet_kw = {}
    if plan is not None:
        fleet_kw.update(fault_plan=plan, watchdog_timeout=1.0,
                        watchdog_retries=1)
    if not accrual:
        fleet_kw["think_time_accrual"] = False
    return AgentService.sim(
        "justitia", replicas=2, router="round_robin",
        total_kv=3000.0, token_events=True, **fleet_kw, **kw,
    )


@given(
    st.floats(min_value=2.0, max_value=6.0),      # crash time
    st.floats(min_value=3.0, max_value=9.0),      # think time
    st.booleans(),                                # think-time accrual
)
@settings(max_examples=10, deadline=None)
def test_failover_suspension_grammar_fuzz(crash_at, think, accrual):
    """Suspended/Resumed/Requeued interleavings through a replica crash
    keep the grammar: balanced suspensions per agent, no event while
    suspended, exactly one resume per suspension even for agents whose
    replica died mid-think."""
    svc = _fleet(FaultPlan().crash(0, crash_at), accrual=accrual)
    specs = [
        AgentSpec(
            stages=[[InferenceSpec(80, 20)]], arrival=0.4 * i,
            next_stage=ScriptedSession([[InferenceSpec(60, 15)]], [think]),
        )
        for i in range(4)
    ]
    handles = svc.submit_many(specs)
    res = svc.drain()
    assert len(res.finish) == 4
    assert res.metrics["suspensions"] == res.metrics["resumes"]
    for h in handles:
        assert_conformant_stream(
            h, expect_replica=True, allow_requeue=True,
        )
        n_susp = sum(isinstance(e, AgentSuspended) for e in h.events)
        n_res = sum(isinstance(e, AgentResumed) for e in h.events)
        assert n_susp == n_res, (
            f"agent {h.agent_id}: {n_susp} suspensions, {n_res} resumes"
        )


@given(
    st.integers(min_value=0, max_value=10_000),
    st.booleans(),                                # think-time accrual
)
@settings(max_examples=8, deadline=None)
def test_concurrent_suspension_bit_identical(seed, accrual):
    """Concurrent advancement reproduces suspension/resume streams
    event-for-event: the same scripted think workload through a crash
    fleet yields identical typed event sequences with fleet_workers=2."""
    rng = np.random.default_rng(seed)
    raw = [
        (float(rng.uniform(0.0, 4.0)),
         [[(int(rng.integers(60, 200)), int(rng.integers(10, 40)))]
          for _ in range(int(rng.integers(1, 4)))],
         None)
        for _ in range(int(rng.integers(2, 6)))
    ]
    raw = [
        (a, stages,
         [float(rng.choice([0.0, 1.5, 3.0])) for _ in stages[1:]])
        for a, stages, _ in raw
    ]
    streams = []
    for workers in (None, 2):
        svc = _fleet(FaultPlan().crash(0, 3.0), accrual=accrual,
                     fleet_workers=workers)
        handles = svc.submit_many(_specs(raw))
        res = svc.drain()
        streams.append((
            [[(type(e).__name__, e.time, e.replica)
              for e in h.events] for h in handles],
            res.jct, res.event_counts, res.metrics["suspensions"],
        ))
    assert streams[0] == streams[1]


def test_suspended_on_dead_replica_resumes_once_on_survivor():
    """The tentpole failover contract, deterministically: agents thinking
    on the crashed replica resume EXACTLY ONCE — the resume lands before
    the requeue, the remaining work runs on the survivor, and none of it
    starts before the think deadline."""
    svc = _fleet(FaultPlan().crash(0, 4.0))
    handles = [
        svc.submit(AgentSpec(
            stages=[[InferenceSpec(60, 15)]], arrival=float(i) * 0.2,
            next_stage=ScriptedSession([[InferenceSpec(50, 10)]], [6.0]),
        ))
        for i in range(4)
    ]
    res = svc.drain()
    assert len(res.finish) == 4
    assert res.metrics["replica_failures"] == 1
    assert res.metrics["agents_requeued"] >= 1
    requeued = 0
    for h in handles:
        assert_conformant_stream(h, expect_replica=True, allow_requeue=True)
        evs = h.events
        susp = [e for e in evs if isinstance(e, AgentSuspended)]
        resm = [e for e in evs if isinstance(e, AgentResumed)]
        reqs = [e for e in evs if isinstance(e, AgentRequeued)]
        assert len(susp) == 1 and len(resm) == 1, (
            f"agent {h.agent_id}: resume not exactly-once "
            f"({len(susp)} suspensions, {len(resm)} resumes)"
        )
        if not reqs:
            continue
        requeued += 1
        # the victim was mid-think when its replica died: resume precedes
        # the requeue in emission order, the requeue lands on the
        # survivor, and nothing runs before the think deadline
        assert evs.index(resm[0]) < evs.index(reqs[0])
        assert reqs[0].replica != reqs[0].from_replica
        until = susp[0].until
        after = evs[evs.index(reqs[0]):]
        assert all(e.time >= until - 1e-9 for e in after), (
            f"agent {h.agent_id}: survivor ran work before the think "
            f"deadline {until}"
        )
        assert all(e.replica == reqs[0].replica for e in after)
        assert any(isinstance(e, StageCompleted) for e in after), (
            f"agent {h.agent_id}: no follow-up stage on the survivor"
        )
    assert requeued >= 1, "crash victimized no thinking agent"


def test_global_clock_carries_fj_through_suspended_failover():
    """F_j is one-shot across a suspended agent's migration: the virtual
    finish recorded before the crash survives ``fail_replica`` +
    ``migrate``, and suspend/resume notes for dead replicas are no-ops."""
    gvt = GlobalVirtualClock([1000.0, 1000.0])
    gvt.register(0, 1, 0.0, 300.0)
    gvt.register(1, 2, 0.0, 300.0)
    gvt.reconcile(1.0)
    f1 = gvt.virtual_finish[1]
    gvt.note_suspend(0, 1, 2.0)           # thinking when the crash hits
    orphans = gvt.fail_replica(0)
    assert orphans == []                  # arrival already reconciled
    gvt.note_suspend(0, 1, 2.5)           # dead replica: must be a no-op
    gvt.note_resume(0, 1, 3.0)
    carried = gvt.migrate(1, 1, 8.0, 150.0)
    assert carried == f1
    gvt.reconcile(10.0)
    assert gvt.virtual_finish[1] == f1    # never overwritten
    assert gvt.replica_of[1] == 1


def test_think_time_accrual_modes():
    """Equinox stance vs paper stance: with accrual disabled the fleet
    routes deactivate/reactivate notes through the global clock; both
    modes complete the same agent set and record the stance."""
    for accrual in (True, False):
        svc = _fleet(accrual=accrual)
        svc.submit_many([
            AgentSpec(
                stages=[[InferenceSpec(80, 20)]], arrival=float(i) * 0.3,
                next_stage=ScriptedSession(
                    [[InferenceSpec(60, 15)]], [3.0]),
            )
            for i in range(4)
        ])
        res = svc.drain()
        assert len(res.finish) == 4
        assert res.metrics["think_time_accrual"] is accrual
        assert res.metrics["suspensions"] == 4
        assert res.metrics["resumes"] == 4


# ------------------------------------------------------------------ engine


@pytest.mark.parametrize("retention", ["hold", "drop"])
def test_engine_suspension_conformance(tiny_model, retention):
    """The real engine serves the suspension grammar: think-time agents
    release their decode slots, resume on schedule, and complete."""
    model, params = tiny_model
    svc = AgentService(EngineBackend(
        model, params, "justitia",
        pool_tokens=256, block_size=16, max_batch=2, cache_len=64,
        token_scale=1, time_scale=1.0, suspend_retention=retention,
    ))
    handles = [
        svc.submit(AgentSpec(
            stages=[[InferenceSpec(20, 8)]], arrival=float(i),
            next_stage=ScriptedSession(
                [[InferenceSpec(16, 6)]], [2.0]),
        ))
        for i in range(3)
    ]
    res = svc.drain()
    assert len(res.finish) == 3
    assert res.metrics["suspensions"] == 3
    assert res.metrics["resumes"] == 3
    for h in handles:
        assert_conformant_stream(h, token_demands=[8, 6])
        assert sum(isinstance(e, AgentSuspended) for e in h.events) == 1
        susp = next(e for e in h.events if isinstance(e, AgentSuspended))
        resm = next(e for e in h.events if isinstance(e, AgentResumed))
        assert resm.time >= susp.until - 1e-9
