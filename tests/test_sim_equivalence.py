"""Equivalence and perf-harness tests for the event-indexed simulator core.

The optimized ``repro.sim.ClusterSim`` must be *behaviour-preserving*
against the retained pre-rewrite core
(``repro.sim.reference.ReferenceClusterSim``): identical completion
orders, JCTs (within 1e-6; in practice the two cores are float-identical
by construction — see the stable decode form in both modules), swap and
event counts — across schedulers, pool sizes, and mixed arrival patterns.
Also covers the shared ``OrderedQueue``, the virtual-work GPS rewrite, the
admission-overshoot guard, incremental ``advance`` vs batch drain, the
load-aware router fix, and the CI perf-stage smoke.
"""

import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    GpsAgent,
    InferenceSpec,
    OrderedQueue,
    agent_cost,
    gps_finish_times,
    gps_finish_times_fluid,
    make_scheduler,
)
from repro.sim import ClusterSim, SimAgent
from repro.sim.reference import ReferenceClusterSim

DECODE_RATE = 30.0

SCHEDS = ["justitia", "vtc", "srjf", "vllm-fcfs", "vllm-sjf", "parrot"]

# mixed arrival patterns: a burst at t=0, staggered onlines, random gaps
agents_strategy = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=120.0),       # arrival
        st.lists(                                        # stages
            st.lists(
                st.tuples(
                    st.integers(min_value=8, max_value=400),   # prefill
                    st.integers(min_value=8, max_value=300),   # decode
                ),
                min_size=1,
                max_size=3,
            ),
            min_size=1,
            max_size=2,
        ),
    ),
    min_size=1,
    max_size=12,
)


def _sim_agents(raw):
    agents = []
    for i, (arr, stages) in enumerate(raw):
        spec_stages = [
            [InferenceSpec(p, d) for p, d in stage] for stage in stages
        ]
        cost = agent_cost([s for stage in spec_stages for s in stage])
        agents.append(
            SimAgent(
                agent_id=i,
                arrival=float(arr),
                stages=spec_stages,
                predicted_cost=cost,
                true_cost=cost,
            )
        )
    return agents


class _CompletionOrder:
    """Listener capturing the exact agent-completion emission order."""

    def __init__(self):
        self.order = []

    def on_agent_complete(self, agent_id, t):
        self.order.append(agent_id)


@given(
    agents_strategy,
    st.sampled_from([1200.0, 4000.0, 16384.0]),
    st.sampled_from(SCHEDS),
)
@settings(max_examples=30, deadline=None)
def test_event_indexed_core_matches_reference(raw, m, sched):
    """Identical completion order + JCTs (1e-6) + swap/event counts."""
    la, lb = _CompletionOrder(), _CompletionOrder()
    new = ClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m, listener=la
    ).run(_sim_agents(raw))
    ref = ReferenceClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m, listener=lb
    ).run(_sim_agents(raw))
    assert set(new.finish) == set(ref.finish)
    assert la.order == lb.order, f"completion order diverged under {sched}"
    for k in ref.finish:
        assert abs(new.finish[k] - ref.finish[k]) < 1e-6
        assert abs(new.jct[k] - ref.jct[k]) < 1e-6
    assert new.swaps == ref.swaps
    assert new.events == ref.events


def test_equivalence_on_paper_workload_suite():
    """Seeded paper-suite workload (heavier than the property examples):
    the two cores must agree exactly, scheduler by scheduler."""
    from repro.workloads import arrivals_for_density, sample_mixed_suite

    def build():
        rng = np.random.default_rng(7)
        suite = sample_mixed_suite(rng, 50)
        arr = arrivals_for_density(rng, 50, 3)
        return [
            SimAgent(i, float(t), [list(s) for s in a.stages],
                     a.true_cost, a.true_cost)
            for i, (a, t) in enumerate(zip(suite, arr))
        ]

    for sched, m in [("justitia", 2000.0), ("vtc", 2000.0),
                     ("srjf", 8192.0), ("vllm-fcfs", 8192.0)]:
        new = ClusterSim(
            make_scheduler(sched, m, service_rate=DECODE_RATE), m
        ).run(build())
        ref = ReferenceClusterSim(
            make_scheduler(sched, m, service_rate=DECODE_RATE), m
        ).run(build())
        assert new.finish == pytest.approx(ref.finish, abs=1e-6)
        assert (new.swaps, new.events) == (ref.swaps, ref.events), sched
        # the optimized core does strictly fewer policy invocations
        assert new.key_evals <= ref.key_evals


# ------------------------------------------------- token-stream overlay


class _StreamCollector(_CompletionOrder):
    """Captures the full token/swap/completion emission sequence."""

    def __init__(self):
        super().__init__()
        self.stream = []

    def on_token(self, agent_id, rid, tok, t):
        self.stream.append(("tok", agent_id, rid, tok, t))

    def on_swap_out(self, agent_id, rid, t):
        self.stream.append(("out", agent_id, rid, t))

    def on_swap_in(self, agent_id, rid, t):
        self.stream.append(("in", agent_id, rid, t))


@given(
    agents_strategy,
    st.sampled_from([1200.0, 4000.0, 16384.0]),
    st.sampled_from(SCHEDS),
)
@settings(max_examples=20, deadline=None)
def test_token_streaming_inert_and_identical_across_cores(raw, m, sched):
    """The ``token_events`` overlay must (a) leave completions/JCTs/swap
    and event counts BIT-IDENTICAL to the non-streaming run, and (b) make
    both cores emit the exact same token stream (ids, order, stamps)."""
    base = ClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m
    ).run(_sim_agents(raw))
    la, lb = _StreamCollector(), _StreamCollector()
    new = ClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m,
        listener=la, token_events=True,
    ).run(_sim_agents(raw))
    ref = ReferenceClusterSim(
        make_scheduler(sched, m, service_rate=DECODE_RATE), m,
        listener=lb, token_events=True,
    ).run(_sim_agents(raw))
    # (a) inert: bit-identical dynamics with streaming on
    assert new.jct == base.jct and new.finish == base.finish
    assert (new.swaps, new.events) == (base.swaps, base.events)
    # (b) lockstep: identical streams from both cores
    assert la.stream == lb.stream, f"token stream diverged under {sched}"
    assert la.order == lb.order
    # token counts per request sum to the decode demands
    per_rid: dict = {}
    for kind, _, rid, *_ in la.stream:
        if kind == "tok":
            per_rid[rid] = per_rid.get(rid, 0) + 1
    demands = sorted(
        d for _, stages in raw for stage in stages for _, d in stage
    )
    assert sorted(per_rid.values()) == demands


def test_token_streaming_invariant_to_advance_cadence():
    """The emitted token stream (ids AND stamps) must not depend on how
    often the driver polls ``advance`` — tokens catch up at event times,
    which horizon polling never adds or removes."""
    raw = [
        (float(i * 1.3), [[(120, 40), (90, 25)], [(60, 15)]])
        for i in range(8)
    ]
    m = 1500.0

    def run(horizons):
        lc = _StreamCollector()
        sim = ClusterSim(
            make_scheduler("justitia", m, service_rate=DECODE_RATE), m,
            listener=lc, token_events=True,
        )
        for a in sorted(
            _sim_agents(raw), key=lambda a: (a.arrival, a.agent_id)
        ):
            sim.submit(a)
        for h in horizons:
            sim.advance(h)
        sim.drain()
        return lc.stream

    batch = run(())
    assert batch == run(tuple(np.arange(0.9, 40.0, 0.9)))
    assert batch == run((3.0, 17.0, 23.0))


def test_closed_loop_stage_append_identical_across_cores():
    """Closed-loop lockstep: both cores emit ``on_stage_complete`` BEFORE
    the stage-exhaustion check, so a listener appending stages drives the
    same multi-turn continuation — with identical JCTs and streams."""

    class _Chainer(_StreamCollector):
        """Appends one extra stage per agent at its first stage boundary."""

        def __init__(self, sim_agents):
            super().__init__()
            self.by_id = {a.agent_id: a for a in sim_agents}
            self.chained: set = set()

        def on_stage_complete(self, agent_id, stage, t):
            self.stream.append(("stage", agent_id, stage, t))
            if agent_id not in self.chained:
                self.chained.add(agent_id)
                self.by_id[agent_id].stages.append(
                    [InferenceSpec(48, 12 + agent_id)]
                )

    m = 2000.0

    def agents():
        return _sim_agents(
            [(float(i), [[(100 + 10 * i, 20 + i)]]) for i in range(6)]
        )

    a_new, a_ref = agents(), agents()
    la, lb = _Chainer(a_new), _Chainer(a_ref)
    new = ClusterSim(
        make_scheduler("justitia", m, service_rate=DECODE_RATE), m,
        listener=la, token_events=True,
    ).run(a_new)
    ref = ReferenceClusterSim(
        make_scheduler("justitia", m, service_rate=DECODE_RATE), m,
        listener=lb, token_events=True,
    ).run(a_ref)
    assert new.finish == ref.finish and new.jct == ref.jct
    assert la.stream == lb.stream
    # every agent really served the appended second stage
    stages = [e for e in la.stream if e[0] == "stage"]
    assert sorted(e[1] for e in stages if e[2] == 1) == list(range(6))


# ------------------------------------------------------------------- GPS


gps_strategy = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=50.0),     # arrival
        st.floats(min_value=0.5, max_value=500.0),    # cost
    ),
    min_size=1,
    max_size=40,
)


@given(gps_strategy, st.sampled_from([100.0, 1500.0, 8192.0]))
@settings(max_examples=40, deadline=None)
def test_gps_virtual_work_matches_fluid(raw, m):
    agents = [
        GpsAgent(i, float(a), float(c)) for i, (a, c) in enumerate(raw)
    ]
    fast = gps_finish_times(agents, m)
    fluid = gps_finish_times_fluid(agents, m)
    assert set(fast) == set(fluid)
    for k in fluid:
        assert fast[k] == pytest.approx(fluid[k], rel=1e-6, abs=1e-5), (
            f"agent {k}: virtual-work {fast[k]} vs fluid {fluid[k]}"
        )


# ----------------------------------------------------------- OrderedQueue


def test_ordered_queue_static_sorted_by_construction():
    q = OrderedQueue(lambda x: x, dynamic=False)
    for v in [5, 1, 4, 1.5, 9]:
        q.push(v)
    q.refresh()                       # no-op for static queues
    assert list(q) == [1, 1.5, 4, 5, 9]
    assert q.head_key() == 1
    assert [q.popleft() for _ in range(len(q))] == [1, 1.5, 4, 5, 9]
    assert q.sorts == 0
    assert q.key_evals == 5           # exactly once per push


def test_ordered_queue_dynamic_version_gated_resort():
    keys = {"a": 3, "b": 1, "c": 2}
    q = OrderedQueue(lambda x: (keys[x], x), dynamic=True)
    for x in "abc":
        q.push(x)
    q.refresh(version=10)
    assert list(q) == ["b", "c", "a"] and q.sorts == 1
    # same version, no pushes: the keys cannot have moved -> no sort
    q.refresh(version=10)
    assert q.sorts == 1
    # version moved: re-sort with fresh keys
    keys["a"] = 0
    q.refresh(version=11)
    assert list(q) == ["a", "b", "c"] and q.sorts == 2


def test_ordered_queue_grouped_repositions_only_dirty_groups():
    keys = {1: 10.0, 2: 20.0, 3: 30.0}

    def key_fn(item):
        gid, rid = item
        return (keys[gid], rid)

    q = OrderedQueue(key_fn, dynamic=True, group_fn=lambda it: it[0])
    q.push((1, 0))
    q.push((2, 1))
    q.push((3, 2))
    q.refresh()
    evals0 = q.key_evals
    assert [g for g, _ in q] == [1, 2, 3]
    # group 3's key drops below everyone: only its items re-key
    keys[3] = 5.0
    q.mark_dirty(3)
    q.refresh()
    assert [g for g, _ in q] == [3, 1, 2]
    assert q.key_evals == evals0 + 1  # exactly the one moved item
    # clean refresh: nothing dirty, nothing evaluated
    q.refresh()
    assert q.key_evals == evals0 + 1
    assert q.popleft() == (3, 2)
    assert [g for g, _ in q] == [1, 2]


def test_ordered_queue_tail_access_and_remove_static():
    """PR-4 engine running-set surface: worst-key access at the tail and
    O(log n) arbitrary removal via the cached key (static mode)."""
    q = OrderedQueue(lambda x: x, dynamic=False)
    for v in [5, 1, 4, 1.5, 9]:
        q.push(v)
    assert q.peek_right() == 9
    assert q.pop_right() == 9
    assert list(q) == [1, 1.5, 4, 5]
    q.remove(4)
    assert list(q) == [1, 1.5, 5]
    # removal interacts correctly with the dead popleft prefix
    assert q.popleft() == 1
    q.remove(5)
    assert list(q) == [1.5]
    assert q.pop_right() == 1.5
    assert not q
    # empty-queue guards, including after an uncompacted popleft prefix
    # (the tail slot is then a dead tombstone, not an item)
    with pytest.raises(IndexError):
        q.peek_right()
    with pytest.raises(IndexError):
        q.pop_right()
    q2 = OrderedQueue(lambda x: x, dynamic=False)
    q2.push(7)
    assert q2.popleft() == 7
    with pytest.raises(IndexError):
        q2.pop_right()
    with pytest.raises(ValueError):
        OrderedQueue(lambda x: x, dynamic=True).remove("missing")


def test_ordered_queue_tail_and_remove_grouped_and_dynamic():
    keys = {1: 10.0, 2: 20.0, 3: 30.0}

    def key_fn(item):
        gid, rid = item
        return (keys[gid], rid)

    q = OrderedQueue(key_fn, dynamic=True, group_fn=lambda it: it[0])
    a, b, c = (1, 0), (2, 1), (3, 2)
    for it in (a, b, c):
        q.push(it)
    q.refresh()
    assert q.peek_right() == c
    assert q.pop_right() == c            # group bookkeeping must shrink
    q.mark_dirty(3)                      # no-op: group 3 is gone
    q.refresh()
    assert list(q) == [a, b]
    q.remove(a)
    assert list(q) == [b]
    # grouped removal after a pending (unrefreshed) dirty mark still finds
    # the item at its cached-key position
    keys[2] = 5.0
    q.mark_dirty(2)
    q.remove(b)
    assert not q

    # plain dynamic mode: identity-scan removal
    qd = OrderedQueue(lambda x: x, dynamic=True)
    for v in (3, 1, 2):
        qd.push(v)
    qd.refresh()
    assert qd.peek_right() == 3
    qd.remove(2)
    qd.refresh()
    assert list(qd) == [1, 3]
    assert qd.pop_right() == 3


def test_grouped_queue_matches_full_resort_under_simulation():
    """Randomized: grouped invalidation must equal a full re-sort as long
    as only marked groups' keys move (the agent_keyed contract)."""
    rng = np.random.default_rng(3)
    keys = {g: float(rng.integers(0, 50)) for g in range(8)}

    def key_fn(item):
        gid, rid = item
        return (keys[gid], rid)

    grouped = OrderedQueue(key_fn, dynamic=True, group_fn=lambda it: it[0])
    plain = OrderedQueue(key_fn, dynamic=True)
    rid = 0
    for step in range(200):
        op = rng.random()
        if op < 0.4:
            item = (int(rng.integers(0, 8)), rid)
            rid += 1
            grouped.push(item)
            plain.push(item)
        elif op < 0.7 and len(grouped):
            g = int(rng.integers(0, 8))
            keys[g] += float(rng.integers(1, 10))
            grouped.mark_dirty(g)
        else:
            grouped.refresh()
            plain.refresh()          # plain: unconditional (version=None)
            assert list(grouped) == list(plain)
            if len(grouped):
                assert grouped.popleft() == plain.popleft()
    grouped.refresh()
    plain.refresh()
    assert list(grouped) == list(plain)


# ------------------------------------------------- admission + increments


def test_admission_never_overshoots_pool():
    """Satellite regression: an admission pass must not push occupancy
    past M (the fit check precedes ``running`` insertion)."""
    m = 2000.0
    agents = [
        SimAgent(i, i * 0.05, [[InferenceSpec(700, 200)] * 3], 100.0, 100.0)
        for i in range(12)
    ]
    sim = ClusterSim(
        make_scheduler("justitia", m, service_rate=DECODE_RATE), m
    )
    res = sim.run(agents)
    assert len(res.finish) == 12
    assert res.peak_occupancy <= m + 1e-6


def test_oversized_request_admitted_alone_documented_escape():
    """A request larger than the whole pool is admitted only when the pool
    is otherwise idle (the vLLM thrash escape) — and occupancy may then
    exceed M by design."""
    m = 500.0
    sim = ClusterSim(
        make_scheduler("justitia", m, service_rate=DECODE_RATE), m
    )
    res = sim.run(
        [SimAgent(0, 0.0, [[InferenceSpec(900, 50)]], 10.0, 10.0)]
    )
    assert 0 in res.finish
    assert res.peak_occupancy >= 900.0


@pytest.mark.parametrize("sched", ["justitia", "vtc", "srjf"])
def test_incremental_advance_matches_batch_drain(sched):
    """Results must be invariant to the advance() polling cadence — for
    dynamic policies too (regression: service crediting at advance
    horizons re-partitioned the accounting integral and near-tie VTC
    counter comparisons flipped with the polling frequency)."""
    rng = np.random.default_rng(5)
    raw = [
        (float(rng.uniform(0, 40)),
         [[(int(rng.integers(16, 200)), int(rng.integers(8, 120)))
           for _ in range(int(rng.integers(1, 3)))]])
        for _ in range(25)
    ]
    batch = ClusterSim(
        make_scheduler(sched, 2000.0, service_rate=DECODE_RATE), 2000.0
    ).run(_sim_agents(raw))

    for horizons in [
        (5.0, 11.0, 17.0, 42.0, 99.0),
        tuple(np.arange(0.9, 120.0, 0.9)),       # fine-grained polling
        tuple(np.arange(1.3, 120.0, 1.3)),
    ]:
        inc = ClusterSim(
            make_scheduler(sched, 2000.0, service_rate=DECODE_RATE), 2000.0
        )
        for a in sorted(
            _sim_agents(raw), key=lambda a: (a.arrival, a.agent_id)
        ):
            inc.submit(a)
        for horizon in horizons:
            inc.advance(horizon)
        res = inc.drain()
        assert res.jct == batch.jct, (sched, horizons[:3])
        assert res.finish == batch.finish
        assert res.swaps == batch.swaps


def test_oversized_jump_processes_arrivals_on_time():
    """The single-sequence saturation jump must stop at the next arrival
    (not skip it to the oversized sequence's finish), and incremental
    polling must match the one-shot drain in this regime too."""
    def run_sim(horizons):
        m = 500.0
        sim = ClusterSim(
            make_scheduler("vtc", m, service_rate=1.0),
            m, decode_rate=1.0, prefill_rate=100.0,
        )
        sim.submit(
            SimAgent(0, 0.0, [[InferenceSpec(900, 50)]], 100.0, 100.0)
        )
        sim.submit(SimAgent(1, 3.0, [[InferenceSpec(40, 5)]], 5.0, 5.0))
        listener = _CompletionOrder()
        sim.listener = listener
        for h in horizons:
            sim.advance(h)
        return sim.drain(), listener.order

    one_shot, order_a = run_sim(())
    polled, order_b = run_sim((2.0, 6.0, 11.0, 30.0))
    assert one_shot.jct == polled.jct
    assert one_shot.finish == polled.finish
    assert order_a == order_b
    # stall polls must not inflate the events metric or re-partition
    # service credits (regression: each advance() during the saturated
    # stall used to record a phantom event and credit at horizon times)
    fine, order_c = run_sim(tuple(np.arange(0.5, 40.0, 0.5)))
    assert fine.events == one_shot.events
    assert fine.jct == one_shot.jct
    assert order_c == order_a
    # the reference core agrees (same jump-to-arrival semantics)
    m = 500.0
    ref = ReferenceClusterSim(
        make_scheduler("vtc", m, service_rate=1.0),
        m, decode_rate=1.0, prefill_rate=100.0,
    ).run([
        SimAgent(0, 0.0, [[InferenceSpec(900, 50)]], 100.0, 100.0),
        SimAgent(1, 3.0, [[InferenceSpec(40, 5)]], 5.0, 5.0),
    ])
    assert ref.finish == one_shot.finish


def test_advance_horizon_not_overshot_by_saturation_escape():
    """Regression: the single-sequence-saturates-pool jump used to raise
    the clock past the advance() horizon, so a later online submission was
    clamped to the overshot clock and its JCT corrupted."""
    m = 100.0
    sim = ClusterSim(
        make_scheduler("justitia", m, service_rate=1.0),
        m, decode_rate=1.0, prefill_rate=4000.0,
    )
    # p + d > M: triggers the documented oversized escape, finishing ~10s
    sim.submit(SimAgent(0, 0.0, [[InferenceSpec(95, 10)]], 10.0, 10.0))
    sim.advance(6.0)
    assert sim.t == 6.0                     # horizon respected
    arrival = sim.submit(
        SimAgent(1, 6.5, [[InferenceSpec(10, 2)]], 1.0, 1.0)
    )
    assert arrival == 6.5                   # not clamped to an overshoot
    res = sim.drain()
    assert set(res.finish) == {0, 1}
    assert res.jct[1] == res.finish[1] - 6.5


def test_sim_advance_emits_completions_mid_run():
    """``advance`` really processes events: completions are observable
    before ``drain`` (what load-aware fleet routers rely on)."""
    sim = ClusterSim(
        make_scheduler("justitia", 4000.0, service_rate=DECODE_RATE), 4000.0
    )
    listener = _CompletionOrder()
    sim.listener = listener
    sim.submit(SimAgent(0, 0.0, [[InferenceSpec(100, 30)]], 5.0, 5.0))
    sim.submit(SimAgent(1, 0.0, [[InferenceSpec(100, 3000)]], 9.0, 9.0))
    assert sim.live_agents == 2
    sim.advance(10.0)                       # agent 0 finishes in ~1s
    assert listener.order == [0]
    assert sim.live_agents == 1
    res = sim.drain()
    assert listener.order == [0, 1]
    assert set(res.finish) == {0, 1}


def test_least_loaded_router_sees_sim_completions_mid_run():
    """ROADMAP follow-up: on the sim backend ``least_loaded`` used to
    degenerate to round-robin because completions were only reported at
    drain.  With the incremental sim the fleet's live-agent accounting
    drops mid-run, so a freed replica is preferred."""
    from repro.api import AgentService, AgentSpec

    svc = AgentService.sim(
        "justitia", replicas=2, router="least_loaded",
        total_kv=4000.0, decode_rate=DECODE_RATE,
    )
    # replica 0: long-running elephant; replica 1: quick mouse
    svc.submit(AgentSpec(stages=[[InferenceSpec(100, 3000)]], arrival=0.0))
    svc.submit(AgentSpec(stages=[[InferenceSpec(100, 30)]], arrival=0.0))
    svc.run(until=20.0)                     # the mouse finishes (~1s)
    backend = svc.backend
    assert backend.live_agents == [1, 0]    # completion observed mid-run
    assert backend.children[1].in_flight == 0
    # both next agents prefer the freed replica first, then balance
    svc.submit(AgentSpec(stages=[[InferenceSpec(50, 20)]], arrival=20.0))
    svc.submit(AgentSpec(stages=[[InferenceSpec(50, 20)]], arrival=20.0))
    assert backend.assignment[2] == 1
    assert backend.assignment[3] in (0, 1)  # tie after re-balancing
    res = svc.drain()
    assert len(res.finish) == 4


# ------------------------------------------------------------ perf stage


def test_quick_perf_bench_completes_under_ceiling(tmp_path):
    """CI perf-stage smoke: the 1k-agent quick benchmark (oracle check +
    sweep) finishes well under a generous wall-clock ceiling and records a
    passing oracle."""
    import time

    from benchmarks.perf import main as perf_main

    out = tmp_path / "BENCH_sim.json"
    t0 = time.time()
    result = perf_main(["--quick", "--out", str(out)])
    wall = time.time() - t0
    assert wall < 240.0, f"quick perf bench took {wall:.0f}s"
    assert out.exists()
    data = json.loads(out.read_text())
    assert data["oracle"]["match"] is True
    assert data["oracle"]["max_abs_diff"] < 1e-6
    assert result["optimized"] and result["reference"]
    assert all(r["events_per_s"] > 0 for r in data["optimized"])
