"""Unit + property tests for the memory-centric cost model (paper §4.1)."""

import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    InferenceSpec,
    MemoryFamily,
    agent_cost,
    encdec_kv_token_time,
    hybrid_kv_token_time,
    inference_cost,
    kv_token_time,
    ssm_token_time,
    swa_kv_token_time,
    vtc_cost,
)

tok = st.integers(min_value=0, max_value=4096)
pos_tok = st.integers(min_value=1, max_value=4096)


def brute_force_cost(p: int, d: int) -> float:
    return float(sum(p + i for i in range(1, d + 1)))


def brute_force_swa(p: int, d: int, w: int) -> float:
    return float(sum(min(p + i, w) for i in range(1, d + 1)))


@given(p=tok, d=tok)
def test_kv_token_time_matches_discrete_sum(p, d):
    assert kv_token_time(p, d) == pytest.approx(brute_force_cost(p, d))


@given(p=tok, d=tok, w=pos_tok)
def test_swa_cost_matches_discrete_sum(p, d, w):
    assert swa_kv_token_time(p, d, w) == pytest.approx(brute_force_swa(p, d, w))


@given(p=tok, d=pos_tok)
def test_cost_monotone_in_prefill(p, d):
    assert kv_token_time(p + 1, d) > kv_token_time(p, d)


@given(p=tok, d=tok)
def test_cost_monotone_in_decode(p, d):
    assert kv_token_time(p, d + 1) > kv_token_time(p, d)


@given(p=tok, d=tok)
def test_quadratic_in_decode(p, d):
    """Doubling d more than doubles cost (superlinear) once d >= 1."""
    if d >= 1:
        assert kv_token_time(p, 2 * d) > 2 * kv_token_time(p, d)


@given(p=tok, d=tok, w=pos_tok)
def test_swa_never_exceeds_dense(p, d, w):
    assert swa_kv_token_time(p, d, w) <= kv_token_time(p, d) + 1e-9


@given(p=tok, d=tok)
def test_swa_with_huge_window_equals_dense(p, d):
    assert swa_kv_token_time(p, d, 10**9) == pytest.approx(kv_token_time(p, d))


@given(d=tok, s=st.floats(min_value=0.0, max_value=1e4, allow_nan=False))
def test_ssm_cost_linear(d, s):
    assert ssm_token_time(d, s) == pytest.approx(s * d)
    assert ssm_token_time(2 * d, s) == pytest.approx(2 * ssm_token_time(d, s))


@given(p=tok, d=tok)
def test_hybrid_interpolates(p, d):
    full = hybrid_kv_token_time(p, d, 1.0, 0.0)
    none = hybrid_kv_token_time(p, d, 0.0, 0.0)
    assert full == pytest.approx(kv_token_time(p, d))
    assert none == 0.0


@given(pe=tok, pd_=tok, d=tok)
def test_encdec_adds_constant_cross_attn(pe, pd_, d):
    c = encdec_kv_token_time(pe, pd_, d)
    assert c == pytest.approx(kv_token_time(pd_, d) + pe * d)


@given(specs=st.lists(st.tuples(tok, tok), min_size=0, max_size=20))
def test_agent_cost_additive(specs):
    infs = [InferenceSpec(p, d) for p, d in specs]
    total = agent_cost(infs)
    assert total == pytest.approx(sum(kv_token_time(p, d) for p, d in specs))


@given(p=tok, d=tok)
def test_vtc_cost_linear_baseline(p, d):
    assert vtc_cost(p, d) == pytest.approx(p + 2 * d)


def test_inference_cost_dispatch():
    s = InferenceSpec(100, 50)
    assert inference_cost(s, MemoryFamily.DENSE) == kv_token_time(100, 50)
    assert inference_cost(
        s, MemoryFamily.SLIDING_WINDOW, window=64
    ) == swa_kv_token_time(100, 50, 64)
    assert inference_cost(s, MemoryFamily.SSM, state_tokens=32.0) == 32.0 * 50
    assert inference_cost(
        s, MemoryFamily.HYBRID, attn_fraction=0.25, state_tokens=8.0
    ) == pytest.approx(0.25 * kv_token_time(100, 50) + 8.0 * 50)
    assert inference_cost(
        s, MemoryFamily.ENCDEC, prefill_enc=1500
    ) == pytest.approx(kv_token_time(100, 50) + 1500 * 50)


def test_negative_spec_rejected():
    with pytest.raises(ValueError):
        InferenceSpec(-1, 5)
    with pytest.raises(ValueError):
        InferenceSpec(5, -1)
