"""Swap-heavy engine tests pinning the device-resident hot path (PR 4)
against the frozen pre-rewrite oracle (``ReferenceServeEngine``), plus the
self-evicted-while-growing regression and listener event-ordering checks.

The workloads here keep the block pool tiny so the same agents swap out
and back in repeatedly — the regime where the rewrite's jitted slot
gather/scatter, O(log n) victim selection, and O(1) swapped-rid membership
all sit on the hot path.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import InferenceSpec, agent_cost, make_scheduler
from repro.engine import EngineAgent, ReferenceServeEngine, ServeEngine
from repro.models import Model

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("granite-3-2b").reduced(vocab=VOCAB)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def mk_agent(rng, aid, n_inf, p, d, arrival=0, stages=1, cost=None):
    sts = []
    for _ in range(stages):
        sts.append(
            [(rng.integers(0, VOCAB, size=p), d) for _ in range(n_inf)]
        )
    specs = [InferenceSpec(p, d)] * (n_inf * stages)
    return EngineAgent(
        aid, arrival, sts, agent_cost(specs) if cost is None else cost
    )


class EventLog:
    """Duck-typed listener recording the full lifecycle stream.

    Token VALUES are dropped: batched/chunked prefill may differ from the
    reference in float low bits, which can flip an argmax tie — scheduling
    behaviour (what these tests pin) must not depend on sampled values.
    """

    def __init__(self, alloc=None):
        self.events = []
        self.alloc = alloc

    def _note(self, kind, *args):
        self.events.append((kind, args))
        if self.alloc is not None:
            self.alloc.check_invariants()

    def on_arrival(self, aid, t):
        self._note("arrival", aid, t)

    def on_admit(self, aid, rid, t):
        self._note("admit", aid, rid, t)

    def on_swap_out(self, aid, rid, t):
        self._note("swap_out", aid, rid, t)

    def on_swap_in(self, aid, rid, t):
        self._note("swap_in", aid, rid, t)

    def on_token(self, aid, rid, tok, t):
        self._note("token", aid, rid, None, t)

    def on_stage_complete(self, aid, stage, t):
        self._note("stage", aid, stage, t)

    def on_agent_complete(self, aid, t):
        self._note("done", aid, t)


def run_engine(cls, model, params, sched_name, agents, *, listener=None,
               **kw):
    kw.setdefault("pool_tokens", 320)
    kw.setdefault("max_batch", 4)
    kw.setdefault("cache_len", 128)
    sched = make_scheduler(sched_name, float(kw["pool_tokens"]))
    eng = cls(model, params, sched, listener=listener, **kw)
    for a in agents:
        eng.submit_agent(a)
    done = eng.run_until_idle(max_iters=100_000)
    eng.alloc.check_invariants()
    return eng, done


def pressure_agents(seed=0, n=4):
    """Agents whose concurrent KV demand is ~3x the 320-token pool."""
    rng = np.random.default_rng(seed)
    return [mk_agent(rng, i, 2, 40, 48, arrival=2 * i) for i in range(n)]


@pytest.mark.parametrize("sched_name", ["justitia", "vtc"])
def test_swap_heavy_pressure_matches_reference_and_orders_events(
    tiny_model, sched_name
):
    """Tiny pool, repeated swap cycles: the optimized engine must drain
    without stalling, keep allocator invariants at EVERY lifecycle event,
    emit a per-request event stream in legal order, and reproduce the
    reference engine's stream exactly (token values aside)."""
    model, params = tiny_model

    logs = {}
    engines = {}
    for cls in (ServeEngine, ReferenceServeEngine):
        log = EventLog()
        eng, done = run_engine(
            cls, model, params, sched_name, pressure_agents(),
            listener=log,
        )
        # checked at every event too, via EventLog.alloc in the next test
        assert set(done) == {0, 1, 2, 3}, cls.__name__
        logs[cls], engines[cls] = log, eng

    new, ref = engines[ServeEngine], engines[ReferenceServeEngine]
    # swap-heavy by construction
    assert new.metrics["swaps"] > 0
    assert new.alloc.swap_events > 0
    # identical completion iterations, clock, and counters
    assert new.completions == ref.completions
    assert new.now == ref.now
    for key in ("tokens", "prefills", "swaps", "decode_steps"):
        assert new.metrics[key] == ref.metrics[key], key
    # identical event streams (order AND stamps)
    assert logs[ServeEngine].events == logs[ReferenceServeEngine].events

    # per-request lifecycle legality on the optimized stream
    state = {}
    for kind, args in logs[ServeEngine].events:
        if kind not in ("admit", "swap_out", "swap_in", "token"):
            continue
        rid = args[1]
        prev = state.get(rid, "new")
        if kind == "admit":
            assert prev == "new", f"rid {rid} admitted twice"
            state[rid] = "running"
        elif kind == "swap_out":
            assert prev == "running", f"rid {rid} swapped out while {prev}"
            state[rid] = "swapped"
        elif kind == "swap_in":
            assert prev == "swapped", f"rid {rid} swapped in while {prev}"
            state[rid] = "running"
        else:  # token
            assert prev == "running", f"rid {rid} decoded while {prev}"


def test_pressure_run_holds_allocator_invariants_at_every_event(
    tiny_model
):
    """check_invariants (including the incremental used-token counter)
    must hold at every single lifecycle event of a swap-heavy run, not
    just at drain."""
    model, params = tiny_model
    sched = make_scheduler("justitia", 320.0)
    eng = ServeEngine(
        model, params, sched, pool_tokens=320, max_batch=4, cache_len=128
    )
    log = EventLog(alloc=eng.alloc)
    eng.listener = log
    for a in pressure_agents(seed=1):
        eng.submit_agent(a)
    done = eng.run_until_idle(max_iters=100_000)
    assert len(done) == 4
    assert eng.metrics["swaps"] > 0
    assert any(kind == "swap_out" for kind, _ in log.events)


def test_self_evicted_while_growing_regression(tiny_model):
    """An elephant agent (worst scheduler key) whose own token growth
    exhausts the pool must evict ITSELF and stop decoding that step —
    the O(1) swapped-rid membership check must behave exactly like the
    reference's linear scan (regression for engine.py's post-swap check).
    """
    model, params = tiny_model

    def agents():
        rng2 = np.random.default_rng(3)
        # elephant: huge predicted cost => worst Justitia key.  Both fit
        # the 6-block pool at admission (2 blocks each) but their combined
        # growth (2x 57 tokens) exhausts it mid-decode, so the append that
        # trips first evicts the elephant — sometimes while the elephant
        # itself is the sequence being grown (the self-eviction path).
        eleph = mk_agent(rng2, 0, 1, 16, 40, cost=1e9)
        mouse = mk_agent(rng2, 1, 1, 16, 40, arrival=1)
        return [eleph, mouse]

    results = {}
    for cls in (ServeEngine, ReferenceServeEngine):
        log = EventLog()
        eng, done = run_engine(
            cls, model, params, "justitia", agents(),
            listener=log, pool_tokens=96, max_batch=2, cache_len=128,
            block_size=16,
        )
        assert set(done) == {0, 1}
        results[cls] = (eng, log)

    new_eng, new_log = results[ServeEngine]
    ref_eng, ref_log = results[ReferenceServeEngine]
    # the elephant really was evicted while growing: a swap_out of agent 0
    # with both requests running and no admission in between
    swap_outs = [a for k, a in new_log.events if k == "swap_out"]
    assert any(a[0] == 0 for a in swap_outs), "elephant never self-evicted"
    # after its swap_out, agent 0 must emit no token until its swap_in
    seen_out = False
    for kind, args in new_log.events:
        if kind == "swap_out" and args[0] == 0:
            seen_out = True
        elif kind == "swap_in" and args[0] == 0:
            seen_out = False
        elif kind == "token" and args[0] == 0:
            assert not seen_out, "self-evicted request kept decoding"
    # and the whole stream matches the reference bit-for-bit
    assert new_log.events == ref_log.events
    assert new_eng.completions == ref_eng.completions


# --------------------------------------------------- chunked prefill regime


def test_prefill_chunked_matches_one_shot_prefill(tiny_model):
    """Model-level: the genuinely-chunked dense prefill path must produce
    the same logits and cache as one-shot prefill (lens-masked, mixed
    per-row lengths), including after a decode continuation."""
    import jax.numpy as jnp

    model, params = tiny_model
    rng = np.random.default_rng(11)
    lens = jnp.asarray([50, 37, 12], jnp.int32)
    toks = jnp.asarray(rng.integers(0, VOCAB, size=(3, 50)), jnp.int32)
    batch = {"tokens": toks, "lens": lens}
    lg1, c1 = model.prefill(params, batch, cache_len=96)
    lg2, c2 = model.prefill_chunked(params, batch, cache_len=96, chunk=16)
    assert (c1["kv_pos"] == c2["kv_pos"]).all()
    assert jnp.max(jnp.abs(lg1 - lg2)) < 1e-4
    nxt = jnp.argmax(lg1[:, -1:], -1).astype(jnp.int32)
    d1, _ = model.decode(params, c1, nxt, lens)
    d2, _ = model.decode(params, c2, nxt, lens)
    assert jnp.max(jnp.abs(d1 - d2)) < 1e-4


def test_chunked_prefill_engine_matches_reference_completions(tiny_model):
    """Engine-level: with prompts spanning several prefill chunks, both
    engines must agree on completions, clock, and counters.  (on_admit
    stamps legitimately differ in this regime: the optimized engine
    stamps at pass-start `now`, the reference at its retired mid-pass
    clock bump — see ROADMAP 'Engine hot path'.)"""
    model, params = tiny_model

    def agents():
        rng2 = np.random.default_rng(5)
        return [
            mk_agent(rng2, 0, 2, 100, 20),
            mk_agent(rng2, 1, 1, 70, 16, arrival=2),
            mk_agent(rng2, 2, 1, 90, 12, arrival=4),
        ]

    results = {}
    for cls in (ServeEngine, ReferenceServeEngine):
        eng, done = run_engine(
            cls, model, params, "justitia", agents(),
            pool_tokens=2048, max_batch=4, cache_len=128,
            prefill_chunk=32,
        )
        assert set(done) == {0, 1, 2}
        results[cls] = eng

    new, ref = results[ServeEngine], results[ReferenceServeEngine]
    assert new.metrics["prefills"] == ref.metrics["prefills"] == 4
    assert new.completions == ref.completions
    assert new.now == ref.now
    for key in ("tokens", "decode_steps", "swaps"):
        assert new.metrics[key] == ref.metrics[key], key


def test_run_until_slicing_matches_reference_with_prefill_cost(tiny_model):
    """Regression: a fused decode window must not run past ``run(until)``
    when the admission pass itself advanced the clock (multi-chunk
    prefill cost) — an online arrival submitted at the slice boundary
    must land at the same iteration on both engines."""
    model, params = tiny_model

    def drive(cls):
        rng = np.random.default_rng(9)
        sched = make_scheduler("justitia", 2048.0)
        eng = cls(model, params, sched, pool_tokens=2048, max_batch=4,
                  cache_len=128, prefill_chunk=32)
        eng.submit_agent(mk_agent(rng, 0, 1, 100, 24))
        for until in (5, 9, 14, 30):
            eng.run(until)
            assert eng.now >= until
        eng.submit_agent(mk_agent(rng, 1, 1, 40, 8, arrival=eng.now))
        done = eng.run_until_idle(max_iters=10_000)
        return eng.now, done

    assert drive(ServeEngine) == drive(ReferenceServeEngine)
