"""Prefix-aware KV reuse subsystem (PR 6): refcount/COW invariants on
``PrefixAwareAllocator``, eviction safety, and the sim-vs-engine
hit-fraction correspondence through the AgentService facade."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (
    AgentHooks,
    AgentService,
    AgentSpec,
    EngineBackend,
    PrefixHit,
    SimBackend,
)
from repro.configs import get_config
from repro.core import InferenceSpec
from repro.kvcache import BlockAllocator, OutOfBlocks
from repro.kvcache.prefix import PrefixAwareAllocator
from repro.models import Model


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("granite-3-2b").reduced(
        vocab=256, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        head_dim=16,
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


# ------------------------------------------------------------ allocator unit


def _prompt(sid: int, n: int, shared: int = 32) -> list:
    """Deterministic canonical stream: ``shared`` tokens common to every
    sid, then a per-sid suffix (same construction the workloads use)."""
    rng = np.random.default_rng(1000 + sid)
    tail = rng.integers(0, 50_000, size=max(0, n - shared))
    head = np.arange(shared)[: min(shared, n)]
    return [int(t) for t in np.concatenate([head, tail])[:n]]


def test_admit_prefix_shares_blocks_and_counts_hits():
    a = PrefixAwareAllocator(total_tokens=256, block_size=16)
    a1, h1 = a.admit_prefix(1, _prompt(1, 48))
    assert h1 == 0 and a.hit_tokens == 0
    a2, h2 = a.admit_prefix(2, _prompt(2, 48))
    # 32 shared tokens = 2 full blocks dedup'd; suffix block private
    assert h2 == 32
    assert a1.block_table[:2] == a2.block_table[:2]
    assert a1.block_table[2] != a2.block_table[2]
    # occupancy stays LOGICAL: sharing dedups physical blocks only
    assert a.used_tokens == 96
    assert a.match_tokens(_prompt(3, 48)) == 32
    a.check_invariants()


def test_partial_tail_block_stays_private():
    a = PrefixAwareAllocator(total_tokens=256, block_size=16)
    a.admit_prefix(1, _prompt(1, 40))       # 2 full blocks + 8-token tail
    _, hit = a.admit_prefix(2, _prompt(1, 40))
    assert hit == 32                         # tail never matches
    assert a.cached_blocks == 2
    a.check_invariants()


def test_eviction_never_touches_live_sequences():
    """Pool exhaustion evicts only unreferenced cached blocks: a live
    chain is pinned, and the evicted blocks can't alias any live table."""
    a = PrefixAwareAllocator(total_tokens=128, block_size=16)  # 8 blocks
    a.admit_prefix(1, _prompt(1, 48))       # live: 3 blocks, all cached
    a.admit_prefix(2, _prompt(2, 48))       # shares 2, 1 fresh
    a.release(2)                             # seq 2's chain -> LRU
    assert a.evictions == 0
    # 4 physical blocks held, 4 free; a 5-block admission must evict
    alloc3, _ = a.admit_prefix(3, [9_999_000 + i for i in range(80)])
    assert a.evictions >= 1
    live_blocks = set(a.seq(1).block_table) | set(alloc3.block_table)
    assert len(live_blocks) == len(a.seq(1).block_table) + len(
        alloc3.block_table
    )
    # seq 1's chain survived eviction pressure intact
    assert a.match_tokens(_prompt(1, 48)) == 48
    a.check_invariants()


def test_eviction_drains_leaf_first():
    """Released chains enter the LRU deepest-first, so eviction takes the
    leaf before its parent and interior blocks never orphan children."""
    a = PrefixAwareAllocator(total_tokens=64, block_size=16)   # 4 blocks
    a.admit_prefix(1, _prompt(1, 48))
    a.release(1)
    assert a.cached_blocks == 3
    a.admit(2, 30)                           # 2 blocks: evicts 1 (4-3-2+1)
    assert a.evictions == 1
    # the surviving 2-block chain is exactly the prompt's first 2 blocks
    assert a.match_tokens(_prompt(1, 48)) == 32
    a.check_invariants()


def test_fork_then_append_is_copy_on_write():
    a = PrefixAwareAllocator(total_tokens=256, block_size=16)
    a.admit_prefix(1, _prompt(1, 48))
    fork = a.fork(1, 2, n_tokens=24)         # mid-block 2: shared cursor
    assert fork.block_table[:2] == a.seq(1).block_table[:2]
    assert a.cow_copies == 0
    assert a.append_token(2)                 # unshares block 2
    assert a.cow_copies == 1
    assert fork.block_table[0] == a.seq(1).block_table[0]
    assert fork.block_table[1] != a.seq(1).block_table[1]
    # the original keeps its cached chain and full prompt match
    assert a.match_tokens(_prompt(1, 48)) == 48
    a.check_invariants()


def test_swap_roundtrip_rematches_chain():
    a = PrefixAwareAllocator(total_tokens=256, block_size=16)
    a.admit_prefix(1, _prompt(1, 48))
    a.append_tokens(1, 10)
    a.swap_out(1)
    a.check_invariants()
    assert a.swap_in(1)
    assert a.seq(1).n_tokens == 58
    # prompt blocks re-registered: a later prompt still shares them
    _, hit = a.admit_prefix(2, _prompt(1, 48))
    assert hit == 48
    a.check_invariants()


# -------------------------------------------------------- allocator property


@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(
                ["admit", "admit_raw", "grow", "growk", "fork",
                 "swap", "swapin", "release"]
            ),
            st.integers(0, 5),
            st.integers(1, 90),
        ),
        max_size=100,
    )
)
@settings(max_examples=80, deadline=None)
def test_prefix_allocator_invariants_random_ops(ops):
    """Block conservation, exact refcounts/used_tokens, LRU consistency,
    and referenced-block pinning — whatever the operation sequence.

    Extends ``check_invariants`` with the eviction-safety property the
    PR 6 design note promises: a block referenced by ANY live sequence
    never reappears on the free list (no eviction of live state)."""
    a = PrefixAwareAllocator(total_tokens=192, block_size=16)  # 12 blocks
    live: set = set()
    fork_id = 100
    for op, sid, n in ops:
        try:
            if op == "admit" and sid not in live:
                a.admit_prefix(sid, _prompt(sid % 3, n))
                live.add(sid)
            elif op == "admit_raw" and sid not in live:
                a.admit(sid, n)
                live.add(sid)
            elif op == "grow" and sid in live and not a.seq(sid).swapped:
                a.append_token(sid)
            elif op == "growk" and sid in live and not a.seq(sid).swapped:
                a.append_tokens(sid, n % 24)
            elif op == "fork" and sid in live and not a.seq(sid).swapped:
                a.fork(sid, fork_id, 1 + n % a.seq(sid).n_tokens)
                live.add(fork_id)
                fork_id += 1
            elif op == "swap" and sid in live and not a.seq(sid).swapped:
                a.swap_out(sid)
            elif op == "swapin" and sid in live and a.seq(sid).swapped:
                a.swap_in(sid)
            elif op == "release" and sid in live:
                a.release(sid)
                live.discard(sid)
        except OutOfBlocks:
            pass
        a.check_invariants()
        # used_tokens is LOGICAL occupancy: block sharing can push it
        # past physical capacity, but never past one pool per live seq
        assert a.used_tokens <= 192 * max(1, len(live))
        free = set(a._free)
        for nd in a._nodes.values():
            if nd.refcount > 0:
                assert nd.block not in free, "referenced block freed"


def test_prefix_allocator_matches_base_when_content_free():
    """Content-free admissions make the prefix allocator behave exactly
    like the base allocator (free-count accounting included)."""
    base = BlockAllocator(total_tokens=128, block_size=16)
    pref = PrefixAwareAllocator(total_tokens=128, block_size=16)
    for alloc in (base, pref):
        alloc.admit(1, 30)
        alloc.append_tokens(1, 20)
        alloc.admit(2, 40)
        alloc.swap_out(1)
        alloc.release(2)
        alloc.check_invariants()
    assert base.free_blocks == pref.free_blocks
    assert base.used_tokens == pref.used_tokens
    assert pref.cached_blocks == 0 and pref.hit_tokens == 0


# ------------------------------------------- sim vs engine hit fractions


def _family_specs(token_scale: int):
    """Two-agent chat-like fleet with hand-built canonical streams whose
    shared prefix (256) and prompt lengths (384/640) are exact multiples
    of ``block_size * token_scale``, so block and stride rounding vanish
    and the engine's realized hit equals the sim's analytic hit."""
    shared = np.arange(256, dtype=np.int64) + 7_000
    streamA = np.concatenate([shared, np.arange(1024) + 100_000])
    streamB = np.concatenate([shared, np.arange(1024) + 200_000])
    specs = []
    for aid, (stream, arrival) in enumerate(
        [(streamA, 0.0), (streamB, 40.0)]
    ):
        specs.append(
            AgentSpec(
                stages=[
                    [InferenceSpec(384, 16)],
                    [InferenceSpec(640, 16)],
                ],
                arrival=arrival,
                prompt_ids=[[stream[:384]], [stream[:640]]],
                cached_hints=[[0.0], [384.0]],
                prefix_group="fam",
                shared_prefix=256.0,
                name=f"a{aid}",
            )
        )
    return specs


def test_sim_engine_hit_fractions_match(tiny_model):
    """The engine's content-hash realized hit fractions must equal the
    simulator's analytic model in the rounding-free regime: ample pool
    (no eviction), aligned prompt lengths, staggered arrivals."""
    model, params = tiny_model
    sim = AgentService(
        SimBackend("justitia", total_kv=8192.0, prefix_cache=True)
    )
    sim.submit_many(_family_specs(1))
    sim_res = sim.drain()
    eng = AgentService(
        EngineBackend(
            model, params, "justitia", pool_tokens=1024, max_batch=4,
            cache_len=256, token_scale=8, prefix_cache=True,
        )
    )
    eng.submit_many(_family_specs(8))
    eng_res = eng.drain()
    sim_hf = sim_res.metrics["hit_fractions"]
    eng_hf = eng_res.metrics["hit_fractions"]
    # agent 0: 0/384 then own 384/640 -> 384/1024; agent 1: the seeded
    # family prefix 256/384 then 384/640 -> 640/1024 (scale-free)
    assert sim_hf[0] == pytest.approx(0.375)
    assert sim_hf[1] == pytest.approx(0.625)
    assert eng_hf[0] == pytest.approx(sim_hf[0])
    assert eng_hf[1] == pytest.approx(sim_hf[1])
    assert sim_res.metrics["prefill_tokens_saved"] == pytest.approx(1024.0)
    assert eng_res.metrics["prefill_tokens_saved"] == 128  # 1024 / scale


def test_cache_off_backends_report_no_hits(tiny_model):
    model, params = tiny_model
    for svc in (
        AgentService(SimBackend("justitia", total_kv=8192.0)),
        AgentService(
            EngineBackend(model, params, "justitia", pool_tokens=1024,
                          max_batch=4, cache_len=256, token_scale=8)
        ),
    ):
        svc.submit_many(_family_specs(1))
        res = svc.drain()
        assert res.metrics.get("prefill_tokens_saved", 0) == 0
        assert res.metrics.get("hit_fractions", {}) in ({}, None) or all(
            v == 0.0 for v in res.metrics["hit_fractions"].values()
        )


def test_prefix_hit_events_and_hooks(tiny_model):
    """PrefixHit events reach both the recorder and per-agent hooks, and
    carry backend-native cached/prefill token counts."""
    model, params = tiny_model
    seen: list = []
    hooks = AgentHooks(on_prefix_hit=seen.append)
    svc = AgentService(
        EngineBackend(
            model, params, "justitia", pool_tokens=1024, max_batch=4,
            cache_len=256, token_scale=8, prefix_cache=True,
        )
    )
    for spec in _family_specs(8):
        svc.submit(spec, hooks=hooks)
    svc.drain()
    assert svc.recorder.event_counts.get("PrefixHit", 0) >= 2
    assert all(isinstance(ev, PrefixHit) for ev in seen)
    assert {ev.agent_id for ev in seen} == {0, 1}
    for ev in seen:
        assert 0 < ev.cached <= ev.prefill
