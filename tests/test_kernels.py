"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    flash_attention_ref,
    flash_prefill,
    paged_attention_ref,
    paged_gqa_decode,
)

# Pallas sweeps dominate tier-1 runtime (and need accelerator lowering);
# the slow tier runs them: `pytest -m slow` / scripts/ci.sh stage 3
pytestmark = pytest.mark.slow

TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


def _paged_ref(q, kp, vp, tables, lengths):
    b, nh, hd = q.shape
    nkv = kp.shape[2]
    qg = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, nkv, nh // nkv, hd)
    return paged_attention_ref(
        qg, kp.astype(jnp.float32), vp.astype(jnp.float32), tables, lengths
    ).reshape(b, nh, hd)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,nh,nkv,hd,bs,pages,max_pages",
    [
        (1, 4, 4, 64, 16, 8, 4),       # MHA
        (3, 8, 2, 64, 16, 32, 6),      # GQA 4:1
        (2, 8, 1, 128, 16, 16, 8),     # MQA
        (2, 6, 2, 80, 16, 16, 5),      # h2o-danube head_dim 80
        (1, 4, 2, 256, 32, 8, 3),      # xlstm-like wide heads, bs 32
    ],
)
def test_paged_attention_sweep(dtype, b, nh, nkv, hd, bs, pages, max_pages):
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(keys[0], (b, nh, hd), dtype)
    kp = jax.random.normal(keys[1], (pages, bs, nkv, hd), dtype)
    vp = jax.random.normal(keys[2], (pages, bs, nkv, hd), dtype)
    tables = jax.random.randint(keys[3], (b, max_pages), 0, pages)
    # lengths cover: tiny, partial page, full
    lengths = jnp.asarray(
        np.linspace(1, max_pages * bs, b).astype(np.int32)
    )
    out = paged_gqa_decode(q, kp, vp, tables, lengths, block_size=bs,
                           interpret=True)
    ref = _paged_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **TOL[dtype],
    )


def test_paged_attention_length_edge_cases():
    b, nh, nkv, hd, bs, pages, mp = 4, 4, 2, 64, 16, 8, 4
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(keys[0], (b, nh, hd))
    kp = jax.random.normal(keys[1], (pages, bs, nkv, hd))
    vp = jax.random.normal(keys[2], (pages, bs, nkv, hd))
    tables = jax.random.randint(keys[3], (b, mp), 0, pages)
    lengths = jnp.array([1, bs, bs + 1, mp * bs], jnp.int32)
    out = paged_gqa_decode(q, kp, vp, tables, lengths, block_size=bs,
                           interpret=True)
    ref = _paged_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,nh,nkv,hd,bq,bk,window",
    [
        (2, 256, 4, 2, 64, 64, 64, 0),
        (1, 256, 8, 8, 64, 128, 128, 0),     # MHA
        (2, 256, 4, 1, 128, 64, 64, 0),      # MQA
        (2, 256, 4, 2, 64, 64, 64, 96),      # SWA
        (1, 512, 2, 2, 80, 128, 64, 128),    # SWA, head_dim 80, rect blocks
    ],
)
def test_flash_prefill_sweep(dtype, b, s, nh, nkv, hd, bq, bk, window):
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(keys[0], (b, s, nh, hd), dtype)
    k = jax.random.normal(keys[1], (b, s, nkv, hd), dtype)
    v = jax.random.normal(keys[2], (b, s, nkv, hd), dtype)
    out = flash_prefill(q, k, v, window=window, block_q=bq, block_k=bk,
                        interpret=True)
    ref = jnp.swapaxes(
        flash_attention_ref(
            jnp.swapaxes(q.astype(jnp.float32) * hd ** -0.5, 1, 2),
            jnp.swapaxes(k.astype(jnp.float32), 1, 2),
            jnp.swapaxes(v.astype(jnp.float32), 1, 2),
            window=window,
        ), 1, 2,
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **TOL[dtype],
    )


def test_flash_rejects_misaligned_seq():
    q = jnp.zeros((1, 100, 2, 64))
    with pytest.raises(ValueError):
        flash_prefill(q, q[:, :, :2], q[:, :, :2], block_q=64, block_k=64,
                      interpret=True)


def test_paged_matches_model_decode_attention():
    """The paged kernel must agree with the engine's dense-cache attention
    path (gqa_attention with kv_pos masking) on the same content."""
    from repro.models.layers import gqa_attention

    b, nh, nkv, hd, bs, mp = 2, 4, 2, 64, 16, 4
    t = mp * bs
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(keys[0], (b, nh, hd))
    kc = jax.random.normal(keys[1], (b, t, nkv, hd))
    vc = jax.random.normal(keys[2], (b, t, nkv, hd))
    lengths = jnp.array([17, 50], jnp.int32)

    # dense path
    kv_pos = jnp.where(jnp.arange(t)[None] < lengths[:, None],
                       jnp.arange(t)[None], -1)
    dense = gqa_attention(
        q[:, None], kc, vc,
        q_positions=lengths[:, None] - 1 + 1,  # querying at position len
        kv_positions=kv_pos, kv_valid=kv_pos >= 0,
    )[:, 0]

    # paged path: lay the same cache out as contiguous pages per sequence
    kp = kc.reshape(b * mp, bs, nkv, hd)
    vp = vc.reshape(b * mp, bs, nkv, hd)
    tables = jnp.arange(b * mp).reshape(b, mp)
    paged = paged_gqa_decode(q, kp, vp, tables, lengths, block_size=bs,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------- chunkwise mLSTM


def _mlstm_ref(q, k, v, i_raw, log_f):
    """Per-step recurrence oracle (matches repro.models.ssm.mlstm_forward)."""
    import math

    b, h, s, hd = q.shape
    c = jnp.zeros((b, h, hd, hd))
    n = jnp.zeros((b, h, hd))
    m = jnp.full((b, h), -1e30)
    outs = []
    for t in range(s):
        m_new = jnp.maximum(log_f[:, :, t] + m, i_raw[:, :, t])
        alpha = jnp.exp(log_f[:, :, t] + m - m_new)
        beta = jnp.exp(i_raw[:, :, t] - m_new)
        kf = k[:, :, t] / math.sqrt(hd)
        c = c * alpha[..., None, None] + beta[..., None, None] * jnp.einsum(
            "bhk,bhv->bhkv", kf, v[:, :, t]
        )
        n = n * alpha[..., None] + beta[..., None] * kf
        num = jnp.einsum("bhk,bhkv->bhv", q[:, :, t], c)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", q[:, :, t], n)),
            jnp.exp(-m_new),
        )
        outs.append(num / den[..., None])
        m = m_new
    return jnp.stack(outs, axis=2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,s,hd,chunk",
    [
        (2, 2, 64, 32, 16),
        (1, 3, 128, 64, 32),
        (1, 1, 96, 128, 32),     # non-power-of-two chunk count
        (2, 1, 64, 256, 64),     # xlstm-350m head_dim, single chunk
    ],
)
def test_mlstm_chunk_kernel_sweep(dtype, b, h, s, hd, chunk):
    from repro.kernels import mlstm_chunk_kernel

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = (jax.random.normal(ks[0], (b, h, s, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, h, s, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, h, s, hd)) * 0.5).astype(dtype)
    i_raw = (jax.random.normal(ks[3], (b, h, s)) * 0.5).astype(dtype)
    log_f = (
        -jax.nn.softplus(-jax.random.normal(ks[4], (b, h, s)) * 0.5 - 2.0)
    ).astype(dtype)
    out = mlstm_chunk_kernel(q, k, v, i_raw, log_f, chunk=chunk,
                             interpret=True)
    ref = _mlstm_ref(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), i_raw.astype(jnp.float32),
        log_f.astype(jnp.float32),
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), **TOL[dtype]
    )


def test_mlstm_chunk_kernel_rejects_misaligned():
    from repro.kernels import mlstm_chunk_kernel

    q = jnp.zeros((1, 1, 100, 32))
    g = jnp.zeros((1, 1, 100))
    with pytest.raises(ValueError):
        mlstm_chunk_kernel(q, q, q, g, g, chunk=64, interpret=True)
