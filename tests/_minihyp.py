"""Minimal in-repo stand-in for ``hypothesis`` (see tests/conftest.py).

The container this repo targets does not ship ``hypothesis`` and nothing
may be pip-installed, but the property tests are the teeth of the fairness
reproduction — skipping them silently (the previous stub's behaviour) left
Theorem B.1 and the virtual-clock invariants unchecked.  This module
implements the small strategy surface those tests use (``integers``,
``floats``, ``lists``, ``tuples``, ``sampled_from`` + ``map``/``filter``)
with *seeded* random example generation, so every ``@given`` property runs
its assertions for real, deterministically across pytest runs.

Not a hypothesis replacement: no shrinking, no database, no coverage-guided
generation.  Each test's RNG is seeded from its qualified name (override
with ``MINIHYP_SEED``), boundary values are mixed into numeric draws (min,
max, zero) since those are where order/monotonicity properties break, and a
failing example is reported with seed + args so it can be replayed.

When the real ``hypothesis`` is installed, conftest leaves it alone and
this module is unused.
"""

from __future__ import annotations

import os
import types
import zlib
from random import Random

__all__ = [
    "given", "settings", "assume", "note", "HealthCheck", "strategies",
]

#: examples per property when the test does not say (hypothesis defaults to
#: 100; kept lower to hold tier-1 runtime — override via MINIHYP_MAX_EXAMPLES)
DEFAULT_MAX_EXAMPLES = int(os.environ.get("MINIHYP_MAX_EXAMPLES", "50"))


class Unsatisfied(Exception):
    """Raised by ``assume(False)``: discard the example, draw another."""


class Strategy:
    """Base strategy: draws one value per ``example(rng)`` call."""

    def example(self, rng: Random):
        raise NotImplementedError

    def map(self, fn):
        return _Mapped(self, fn)

    def filter(self, pred):
        return _Filtered(self, pred)

    def flatmap(self, fn):
        return _FlatMapped(self, fn)


class _Mapped(Strategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def example(self, rng):
        return self.fn(self.base.example(rng))


class _Filtered(Strategy):
    def __init__(self, base, pred):
        self.base, self.pred = base, pred

    def example(self, rng):
        for _ in range(100):
            v = self.base.example(rng)
            if self.pred(v):
                return v
        raise Unsatisfied(f"filter rejected 100 draws from {self.base!r}")


class _FlatMapped(Strategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def example(self, rng):
        return self.fn(self.base.example(rng)).example(rng)


class _Integers(Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2**31) if min_value is None else int(min_value)
        self.hi = 2**31 if max_value is None else int(max_value)

    def example(self, rng):
        # boundary draws: integer order/monotonicity properties break at the
        # edges far more often than in the middle of the range
        r = rng.random()
        if r < 0.08:
            return self.lo
        if r < 0.16:
            return self.hi
        if r < 0.24 and self.lo <= 0 <= self.hi:
            return 0
        return rng.randint(self.lo, self.hi)


class _Floats(Strategy):
    def __init__(
        self, min_value=None, max_value=None, allow_nan=False,
        allow_infinity=False, width=64,
    ):
        self.lo = -1e9 if min_value is None else float(min_value)
        self.hi = 1e9 if max_value is None else float(max_value)

    def example(self, rng):
        r = rng.random()
        if r < 0.06:
            return self.lo
        if r < 0.12:
            return self.hi
        if r < 0.18 and self.lo <= 0.0 <= self.hi:
            return 0.0
        if r < 0.26:
            # log-uniform draw: exercises values many orders apart
            span = self.hi - self.lo
            if span > 0:
                return self.lo + span * (10.0 ** rng.uniform(-9, 0))
        return rng.uniform(self.lo, self.hi)


class _Lists(Strategy):
    def __init__(self, elements, min_size=0, max_size=None, unique=False):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = (
            self.min_size + 10 if max_size is None else int(max_size)
        )
        self.unique = unique

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        out = [self.elements.example(rng) for _ in range(n)]
        if self.unique:
            seen, uniq = set(), []
            for v in out:
                if v not in seen:
                    seen.add(v)
                    uniq.append(v)
            out = uniq
            if len(out) < self.min_size:
                raise Unsatisfied("unique list under min_size")
        return out


class _Tuples(Strategy):
    def __init__(self, *elements):
        self.elements = elements

    def example(self, rng):
        return tuple(e.example(rng) for e in self.elements)


class _SampledFrom(Strategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from needs a non-empty collection")

    def example(self, rng):
        return rng.choice(self.elements)


class _Just(Strategy):
    def __init__(self, value):
        self.value = value

    def example(self, rng):
        return self.value


class _OneOf(Strategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rng):
        return rng.choice(self.strategies).example(rng)


def integers(min_value=None, max_value=None):
    return _Integers(min_value, max_value)


def floats(min_value=None, max_value=None, **kw):
    return _Floats(min_value, max_value, **kw)


def lists(elements, *, min_size=0, max_size=None, unique=False):
    return _Lists(elements, min_size, max_size, unique)


def tuples(*elements):
    return _Tuples(*elements)


def sampled_from(elements):
    return _SampledFrom(elements)


def booleans():
    return _SampledFrom([False, True])


def just(value):
    return _Just(value)


def one_of(*strategies):
    return _OneOf(*strategies)


def _unsupported(name):
    raise NotImplementedError(
        f"minihyp does not implement strategy {name!r} — extend "
        "tests/_minihyp.py or install the real hypothesis"
    )


# hypothesis.strategies facade (conftest installs this as the submodule)
strategies = types.ModuleType("hypothesis.strategies")
for _name in (
    "integers", "floats", "lists", "tuples", "sampled_from", "booleans",
    "just", "one_of",
):
    setattr(strategies, _name, globals()[_name])
strategies.__getattr__ = lambda name: _unsupported(name)


# ------------------------------------------------------------- decorators


def assume(condition) -> bool:
    if not condition:
        raise Unsatisfied()
    return True


def note(message) -> None:  # parity no-op: we report args on failure instead
    pass


HealthCheck = types.SimpleNamespace(
    too_slow=None, data_too_large=None, filter_too_much=None,
    function_scoped_fixture=None,
)


def settings(*args, **kwargs):
    """Record ``max_examples`` etc. for ``given`` (composes in any order)."""

    def deco(fn):
        merged = {**getattr(fn, "_minihyp_settings", {}), **kwargs}
        fn._minihyp_settings = merged
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the property over seeded random examples (no shrinking).

    The wrapper takes no parameters on purpose: pytest must not mistake the
    strategy parameters for fixtures.
    """

    def deco(fn):
        def wrapper():
            # settings() may be applied below given (attr lands on fn) or
            # above it (attr lands on wrapper) — honour either
            cfg = (
                getattr(wrapper, "_minihyp_settings", None)
                or getattr(fn, "_minihyp_settings", None)
                or {}
            )
            max_examples = int(cfg.get("max_examples", DEFAULT_MAX_EXAMPLES))
            seed_env = os.environ.get("MINIHYP_SEED")
            seed = (
                int(seed_env)
                if seed_env is not None
                else zlib.crc32(fn.__qualname__.encode())
            )
            rng = Random(seed)
            ran = 0
            attempts = 0
            while ran < max_examples and attempts < max_examples * 5:
                attempts += 1
                try:
                    args = [s.example(rng) for s in arg_strategies]
                    kwargs = {
                        k: s.example(rng) for k, s in kw_strategies.items()
                    }
                except Unsatisfied:
                    continue
                try:
                    fn(*args, **kwargs)
                except Unsatisfied:
                    continue
                except Exception as e:
                    detail = (
                        f"\nFalsifying example (minihyp seed={seed}, "
                        f"example #{ran}): args={args!r} kwargs={kwargs!r}"
                    )
                    e.args = (
                        (str(e.args[0]) + detail,) + e.args[1:]
                        if e.args
                        else (detail,)
                    )
                    raise
                ran += 1
            if ran == 0:
                raise Unsatisfied(
                    f"{fn.__qualname__}: no example satisfied assume()/"
                    f"filter() in {attempts} attempts"
                )

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # NB: no __wrapped__ — pytest unwraps it and would then mistake the
        # property's strategy parameters for fixtures
        return wrapper

    return deco
