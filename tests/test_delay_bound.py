"""Empirical check of Theorem B.1 (constant delay bound).

    f_j − f̄_j  ≤  2·c_max + C_max / M

where f_j is the agent's completion under Justitia (packetized,
non-preemptive), f̄_j its completion under GPS (fluid fair sharing), c_max
the largest single-inference KV token-time and C_max the largest agent cost.

The theorem's model has no prefill latency and no swap penalty, so the
simulator is configured to match (prefill_rate → ∞, swap_penalty = 0).
Times are converted between GPS token-iteration units and simulator seconds
via the decode rate (1 iteration = 1/decode_rate seconds).
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import AgentService, AgentSpec
from repro.core import (
    GlobalVirtualClock,
    GpsAgent,
    InferenceSpec,
    agent_cost,
    gps_finish_times,
    inference_cost,
    make_scheduler,
)
from repro.sim import ClusterSim, SimAgent

DECODE_RATE = 30.0

agent_strategy = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=200.0, allow_nan=False),  # arrival
        st.lists(  # parallel inferences: (prefill, decode)
            st.tuples(
                st.integers(min_value=8, max_value=300),
                st.integers(min_value=8, max_value=300),
            ),
            min_size=1,
            max_size=5,
        ),
    ),
    min_size=1,
    max_size=12,
)


@given(agent_strategy, st.sampled_from([1500.0, 3000.0, 8000.0]))
@settings(max_examples=40, deadline=None)
def test_constant_delay_bound(raw, m):
    agents = []
    for i, (arr, specs) in enumerate(sorted(raw)):
        infs = [InferenceSpec(p, d) for p, d in specs]
        cost = agent_cost(infs)
        agents.append(
            SimAgent(
                agent_id=i,
                arrival=float(arr),
                stages=[infs],
                predicted_cost=cost,  # theorem assumes accurate costs
                true_cost=cost,
            )
        )
    c_max = max(
        inference_cost(s) for a in agents for st_ in a.stages for s in st_
    )
    c_agent_max = max(a.true_cost for a in agents)

    sim = ClusterSim(
        make_scheduler("justitia", m, service_rate=DECODE_RATE),
        m,
        decode_rate=DECODE_RATE,
        prefill_rate=1e12,  # theorem's model: prefill instantaneous
        swap_penalty=0.0,
    )
    res = sim.run(agents)

    # GPS fluid reference in token-iteration time units
    gps = gps_finish_times(
        [
            GpsAgent(a.agent_id, a.arrival * DECODE_RATE, a.true_cost)
            for a in agents
        ],
        m,
    )

    bound_iters = 2.0 * c_max + c_agent_max / m
    for a in agents:
        f_real_iters = res.finish[a.agent_id] * DECODE_RATE
        delay = f_real_iters - gps[a.agent_id]
        assert delay <= bound_iters * 1.05 + 1.0, (
            f"agent {a.agent_id}: delay {delay:.1f} iters exceeds bound "
            f"{bound_iters:.1f} (c_max={c_max:.0f}, C_max={c_agent_max:.0f}, "
            f"M={m})"
        )


# ------------------------------------------------- multi-replica fleets


@given(
    agent_strategy,
    st.sampled_from([2, 3]),
    st.sampled_from([1500.0, 3000.0]),
)
@settings(max_examples=15, deadline=None)
def test_multi_replica_delay_bound_with_reconciled_clock(raw, k, m):
    """Theorem B.1, fleet-wide: with K replicas behind ``ReplicatedBackend``
    and the per-replica GPS clocks reconciled by ``GlobalVirtualClock``,
    every agent still finishes within the single-backend worst-case delay
    bound of ITS replica's GPS reference — sharding the fair queue does not
    void the guarantee, it applies per shard with the reconciled lag
    making the drift observable."""
    specs = []
    for arr, infs in sorted(raw):
        stage = [InferenceSpec(p, d) for p, d in infs]
        cost = agent_cost(stage)
        specs.append(
            AgentSpec(stages=[stage], arrival=float(arr),
                      predicted_cost=cost, true_cost=cost)
        )
    service = AgentService.sim(
        "justitia",
        replicas=k,
        router="round_robin",
        total_kv=m,
        decode_rate=DECODE_RATE,
        prefill_rate=1e12,   # theorem's model: instantaneous prefill
        swap_penalty=0.0,
    )
    handles = service.submit_many(specs)
    res = service.drain()
    assert len(res.finish) == len(specs)

    assignment = service.backend.assignment
    c_max = max(
        inference_cost(s) for spec in specs for st_ in spec.stages
        for s in st_
    )
    c_agent_max = max(spec.true_cost for spec in specs)

    # reconciled clock in the theorem's units (iterations, service_rate=1)
    gclock = GlobalVirtualClock([m] * k)
    for h in handles:
        gclock.register(
            assignment[h.agent_id], h.agent_id,
            h.arrival * DECODE_RATE, h.spec.true_cost,
        )
    makespan_iters = max(res.finish.values()) * DECODE_RATE
    snap = gclock.reconcile(makespan_iters)
    assert snap.lag >= 0.0
    assert snap.global_virtual_time == min(snap.virtual_times)

    bound_iters = gclock.delay_bound(c_max, c_agent_max)
    assert bound_iters == pytest.approx(2.0 * c_max + c_agent_max / m)

    # per-replica GPS fluid reference over each replica's own arrivals
    for replica in range(k):
        mine = [h for h in handles if assignment[h.agent_id] == replica]
        if not mine:
            continue
        gps = gps_finish_times(
            [
                GpsAgent(h.agent_id, h.arrival * DECODE_RATE,
                         h.spec.true_cost)
                for h in mine
            ],
            m,
        )
        for h in mine:
            f_real_iters = res.finish[h.agent_id] * DECODE_RATE
            delay = f_real_iters - gps[h.agent_id]
            assert delay <= bound_iters * 1.05 + 1.0, (
                f"agent {h.agent_id} on replica {replica}: delay "
                f"{delay:.1f} iters exceeds fleet bound {bound_iters:.1f} "
                f"(lag={snap.lag:.1f})"
            )

    # events carried the replica that the router recorded
    for h in handles:
        assert h.replica == assignment[h.agent_id]


@given(
    st.integers(min_value=6, max_value=18),
    st.integers(min_value=32, max_value=128),
    st.integers(min_value=16, max_value=64),
    st.sampled_from([2, 3]),
)
@settings(max_examples=10, deadline=None)
def test_fleet_completion_order_matches_single_replica_oracle(n, p, d, k):
    """Identical agents + round_robin: the K-replica fleet completes agents
    in the same order as the 1-replica Justitia oracle (arrival order —
    equal costs give strictly increasing virtual finish times, and the
    reconciled pampering order agrees)."""
    m = 2000.0

    def make_specs():
        cost = agent_cost([InferenceSpec(p, d)])
        return [
            AgentSpec(stages=[[InferenceSpec(p, d)]], arrival=i * 1.0,
                      predicted_cost=cost, true_cost=cost)
            for i in range(n)
        ]

    def order(finish):
        return [aid for aid, _ in
                sorted(finish.items(), key=lambda kv: (kv[1], kv[0]))]

    def run(replicas):
        service = AgentService.sim(
            "justitia", replicas=replicas, router="round_robin",
            total_kv=m, decode_rate=DECODE_RATE,
            prefill_rate=1e12, swap_penalty=0.0,
        )
        service.submit_many(make_specs())
        return service, service.drain()

    _, oracle = run(1)
    fleet_svc, fleet = run(k)
    assert order(fleet.finish) == order(oracle.finish)
    # the reconciled fleet-wide pampering order agrees with the oracle too
    assert fleet_svc.backend.pampering_order() == order(oracle.finish)


def test_starvation_bounded_under_justitia():
    """Fig. 9's property: an elephant's delay under Justitia does not grow
    with the number of competing mice (unlike SRJF).

    Mice demand must be sustainable (< backend capacity) — under overload
    *no* scheduler can bound the elephant's delay.  Capacity here is
    m * decode_rate = 1000 * 30 = 30k token-iters/s; each mouse costs
    ~49k and arrives every 2.5 s (~65% load).
    """
    m = 1000.0

    def make_workload(n_mice):
        elephant_specs = [InferenceSpec(300, 400)] * 6
        agents = [
            SimAgent(0, 0.0, [elephant_specs],
                     agent_cost(elephant_specs), agent_cost(elephant_specs))
        ]
        for i in range(n_mice):
            specs = [InferenceSpec(250, 150)]
            agents.append(
                SimAgent(1 + i, 1.0 + i * 2.5, [specs],
                         agent_cost(specs), agent_cost(specs))
            )
        return agents

    def elephant_jct(name, n_mice):
        sim = ClusterSim(make_scheduler(name, m, service_rate=30.0), m)
        return sim.run(make_workload(n_mice)).jct[0]

    jus_small = elephant_jct("justitia", 30)
    jus_large = elephant_jct("justitia", 240)
    srjf_small = elephant_jct("srjf", 30)
    srjf_large = elephant_jct("srjf", 240)

    # SRJF starves the elephant as mice multiply; Justitia's delay plateaus
    # once arriving mice have later virtual finish times than the elephant
    assert srjf_large > srjf_small * 1.5
    assert jus_large < jus_small * 1.5
    assert jus_large < srjf_large / 2
