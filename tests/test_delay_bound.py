"""Empirical check of Theorem B.1 (constant delay bound).

    f_j − f̄_j  ≤  2·c_max + C_max / M

where f_j is the agent's completion under Justitia (packetized,
non-preemptive), f̄_j its completion under GPS (fluid fair sharing), c_max
the largest single-inference KV token-time and C_max the largest agent cost.

The theorem's model has no prefill latency and no swap penalty, so the
simulator is configured to match (prefill_rate → ∞, swap_penalty = 0).
Times are converted between GPS token-iteration units and simulator seconds
via the decode rate (1 iteration = 1/decode_rate seconds).
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    GpsAgent,
    InferenceSpec,
    agent_cost,
    gps_finish_times,
    inference_cost,
    make_scheduler,
)
from repro.sim import ClusterSim, SimAgent

DECODE_RATE = 30.0

agent_strategy = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=200.0, allow_nan=False),  # arrival
        st.lists(  # parallel inferences: (prefill, decode)
            st.tuples(
                st.integers(min_value=8, max_value=300),
                st.integers(min_value=8, max_value=300),
            ),
            min_size=1,
            max_size=5,
        ),
    ),
    min_size=1,
    max_size=12,
)


@given(agent_strategy, st.sampled_from([1500.0, 3000.0, 8000.0]))
@settings(max_examples=40, deadline=None)
def test_constant_delay_bound(raw, m):
    agents = []
    for i, (arr, specs) in enumerate(sorted(raw)):
        infs = [InferenceSpec(p, d) for p, d in specs]
        cost = agent_cost(infs)
        agents.append(
            SimAgent(
                agent_id=i,
                arrival=float(arr),
                stages=[infs],
                predicted_cost=cost,  # theorem assumes accurate costs
                true_cost=cost,
            )
        )
    c_max = max(
        inference_cost(s) for a in agents for st_ in a.stages for s in st_
    )
    c_agent_max = max(a.true_cost for a in agents)

    sim = ClusterSim(
        make_scheduler("justitia", m, service_rate=DECODE_RATE),
        m,
        decode_rate=DECODE_RATE,
        prefill_rate=1e12,  # theorem's model: prefill instantaneous
        swap_penalty=0.0,
    )
    res = sim.run(agents)

    # GPS fluid reference in token-iteration time units
    gps = gps_finish_times(
        [
            GpsAgent(a.agent_id, a.arrival * DECODE_RATE, a.true_cost)
            for a in agents
        ],
        m,
    )

    bound_iters = 2.0 * c_max + c_agent_max / m
    for a in agents:
        f_real_iters = res.finish[a.agent_id] * DECODE_RATE
        delay = f_real_iters - gps[a.agent_id]
        assert delay <= bound_iters * 1.05 + 1.0, (
            f"agent {a.agent_id}: delay {delay:.1f} iters exceeds bound "
            f"{bound_iters:.1f} (c_max={c_max:.0f}, C_max={c_agent_max:.0f}, "
            f"M={m})"
        )


def test_starvation_bounded_under_justitia():
    """Fig. 9's property: an elephant's delay under Justitia does not grow
    with the number of competing mice (unlike SRJF).

    Mice demand must be sustainable (< backend capacity) — under overload
    *no* scheduler can bound the elephant's delay.  Capacity here is
    m * decode_rate = 1000 * 30 = 30k token-iters/s; each mouse costs
    ~49k and arrives every 2.5 s (~65% load).
    """
    m = 1000.0

    def make_workload(n_mice):
        elephant_specs = [InferenceSpec(300, 400)] * 6
        agents = [
            SimAgent(0, 0.0, [elephant_specs],
                     agent_cost(elephant_specs), agent_cost(elephant_specs))
        ]
        for i in range(n_mice):
            specs = [InferenceSpec(250, 150)]
            agents.append(
                SimAgent(1 + i, 1.0 + i * 2.5, [specs],
                         agent_cost(specs), agent_cost(specs))
            )
        return agents

    def elephant_jct(name, n_mice):
        sim = ClusterSim(make_scheduler(name, m, service_rate=30.0), m)
        return sim.run(make_workload(n_mice)).jct[0]

    jus_small = elephant_jct("justitia", 30)
    jus_large = elephant_jct("justitia", 240)
    srjf_small = elephant_jct("srjf", 30)
    srjf_large = elephant_jct("srjf", 240)

    # SRJF starves the elephant as mice multiply; Justitia's delay plateaus
    # once arriving mice have later virtual finish times than the elephant
    assert srjf_large > srjf_small * 1.5
    assert jus_large < jus_small * 1.5
    assert jus_large < srjf_large / 2
