"""SLO-tier latency accounting + fleet metrics regressions (PR 7).

Covers the three accounting fixes that ride the fused-prefill PR:

  * ``repro.sim.metrics``' new TTFT/TBT percentile and SLO-attainment
    aggregates, and :class:`repro.api.service.MetricsRecorder`'s
    per-request tracking (keyed ``(replica, rid)`` — rids are only
    unique per child backend in a fleet);
  * ``ReplicatedBackend.drain`` fleet-level prefix-cache metrics:
    ``hit_fractions`` dict-merged and ``prefill_tokens_saved`` summed
    across children (they used to be dropped — only per-replica copies
    survived);
  * TTFT semantics under prefix hits: BOTH backends must timestamp the
    first token from the SHORTENED prefill, so a cached prefix buys
    exactly its own length of first-token latency — pinned by comparing
    cold/warm TTFT deltas against the cached amount on the sim
    (analytic, exact) and the engine (chunk-granular, exact for
    block- and chunk-aligned prompts), unfused and fused.
"""

import numpy as np
import pytest

from repro.api import (
    AgentArrived,
    AgentCompleted,
    AgentService,
    AgentSpec,
    EngineBackend,
    SimBackend,
    TokenGenerated,
    specs_from_closed_loop,
)
from repro.api.service import MetricsRecorder
from repro.core import InferenceSpec
from repro.sim.metrics import (
    SloTier,
    latency_stats,
    slo_attainment,
)
from repro.workloads import SLO_CLASSES, SLO_TIERS, slo_tier_of

# ------------------------------------------------------- metric aggregates


def test_latency_stats_percentiles():
    ttfts = {i: float(i) for i in range(1, 101)}     # 1..100
    tbts = {i: 0.5 for i in range(10)}
    lat = latency_stats(ttfts, tbts)
    assert lat.n_ttft == 100 and lat.n_tbt == 10
    assert lat.ttft_mean == pytest.approx(50.5)
    assert lat.ttft_p50 == pytest.approx(np.percentile(range(1, 101), 50))
    assert lat.ttft_p99 == pytest.approx(np.percentile(range(1, 101), 99))
    assert lat.tbt_p99 == pytest.approx(0.5)
    assert "ttft" in lat.row() and "tbt" in lat.row()
    empty = latency_stats({}, {})
    assert empty.n_ttft == 0 and empty.ttft_p99 == 0.0


def test_slo_attainment_tiers():
    fast = SloTier("fast", ttft=1.0, tbt=0.1)
    slow = SloTier("slow", ttft=10.0, tbt=1.0)
    tiers = {0: fast, 1: fast, 2: slow, 3: fast}
    ttfts = {0: 0.5, 1: 2.0, 2: 8.0}     # 3 misses its deadline by absence
    tbts = {0: 0.05, 1: 0.05}            # 2 has no TBT sample: vacuous pass
    slo = slo_attainment(ttfts, tbts, tiers)
    # 0 attains both; 1 misses TTFT; 2 attains (TBT vacuous); 3 has no
    # first token at all -> counted as a miss
    assert slo.n == 4
    assert slo.attainment == pytest.approx(2 / 4)
    assert slo.ttft_attainment == pytest.approx(2 / 4)
    assert slo.per_tier["fast"] == pytest.approx(1 / 3)
    assert slo.per_tier["slow"] == pytest.approx(1.0)
    assert slo_attainment({}, {}, {}).attainment == 1.0


def test_workload_slo_tiers_cover_classes():
    assert set(SLO_TIERS) == set(SLO_CLASSES)
    for cls in SLO_CLASSES:
        tier = slo_tier_of(cls)
        assert tier.ttft > 0 and tier.tbt > 0
    # interactive agents get the tight targets
    assert SLO_TIERS["interactive"].ttft < SLO_TIERS["batch"].ttft


def test_recorder_ttft_tbt_per_request_keying():
    """TTFT is arrival -> first token of ANY request; TBT pools within-
    request gaps.  Two fleet replicas reuse rid 0 for different agents —
    the (replica, rid) key must keep their spans apart."""
    rec = MetricsRecorder()
    rec.record(AgentArrived(0, 10.0, replica=0))
    rec.record(AgentArrived(1, 10.0, replica=1))
    # agent 0 / replica 0, rid 0: tokens at 12, 13, 14
    for t in (12.0, 13.0, 14.0):
        rec.record(TokenGenerated(0, t, rid=0, token=7, replica=0))
    # agent 1 / replica 1, SAME rid 0: tokens at 20, 26
    for t in (20.0, 26.0):
        rec.record(TokenGenerated(1, t, rid=0, token=7, replica=1))
    rec.record(AgentCompleted(0, 14.0, jct=4.0, replica=0))
    rec.record(AgentCompleted(1, 26.0, jct=16.0, replica=1))
    assert rec.ttfts() == {0: pytest.approx(2.0), 1: pytest.approx(10.0)}
    # merged keying would pool one 8-token span; correct keying gives
    # agent 0 a 2s/2-gap span and agent 1 a 6s/1-gap span
    assert rec.tbts() == {0: pytest.approx(1.0), 1: pytest.approx(6.0)}
    lat = rec.latency_stats()
    assert lat.n_ttft == 2 and lat.n_tbt == 2
    tiers = {0: SloTier("t", ttft=5.0, tbt=2.0),
             1: SloTier("t", ttft=5.0, tbt=2.0)}
    assert rec.slo_stats(tiers).attainment == pytest.approx(0.5)


# ------------------------------------------ fleet-level cache metrics fix


def test_replicated_drain_fleet_cache_metrics():
    """Regression: the fleet drain used to drop hit_fractions /
    prefill_tokens_saved on the floor (only ``per_replica`` copies
    survived).  They must now be the dict-merge / sum of the children's,
    with BOTH replicas contributing."""
    svc = AgentService.sim(
        "justitia", replicas=2, router="round_robin",
        total_kv=16384.0, prefix_cache=True,
    )
    rng = np.random.default_rng(3)
    specs = specs_from_closed_loop(rng, 8, 20.0, classes=("chat",))
    svc.submit_many(specs)
    res = svc.drain()
    hf = res.metrics["hit_fractions"]
    saved = res.metrics["prefill_tokens_saved"]
    merged, child_saved = {}, 0.0
    for child in res.metrics["per_replica"]:
        merged.update(child.get("child_hit_fractions") or {})
        child_saved += child.get("child_prefill_tokens_saved", 0) or 0
    assert hf == merged and len(hf) > 0
    assert saved == pytest.approx(child_saved) and saved > 0
    assignment = svc.backend.assignment
    replicas_with_hits = {assignment[aid] for aid in hf}
    assert replicas_with_hits == {0, 1}, (
        "fleet metrics must merge across ALL children, not just the last"
    )


# ------------------------------------- TTFT semantics under prefix hits


BLOCK = 16
CHUNK = 8
PROMPT = 64          # 4 full blocks, 8 chunks
HIT = 32             # shared head: 2 full blocks, 4 chunks
DECODE = 6
PREFILL_RATE = 4000.0


def _shared_prefix_specs(rng):
    """Two one-request agents whose prompts share a block- and
    chunk-aligned 32-token head, far enough apart that neither queues."""
    head = rng.integers(0, 256, size=HIT)
    prompts = [
        np.concatenate([head, rng.integers(0, 256, size=PROMPT - HIT)])
        for _ in range(2)
    ]
    return [
        AgentSpec(
            stages=[[InferenceSpec(PROMPT, DECODE)]],
            arrival=float(200 * i),
            prompts=[[p]],
            prefix_group="fam",
            shared_prefix=float(HIT),
        )
        for i, p in enumerate(prompts)
    ]


def _ttfts(backend):
    svc = AgentService(backend)
    rng = np.random.default_rng(17)
    svc.submit_many(_shared_prefix_specs(rng))
    res = svc.drain()
    assert len(res.finish) == 2
    t = svc.recorder.ttfts()
    return t[0], t[1]


def test_sim_ttft_shortened_by_analytic_hit():
    """Sim cores: the warm agent's first token arrives exactly
    ``hit / prefill_rate`` seconds earlier than the cold agent's."""
    cold_off, warm_off = _ttfts(
        SimBackend("justitia", total_kv=8192.0, token_events=True,
                   prefill_rate=PREFILL_RATE)
    )
    assert warm_off == pytest.approx(cold_off)     # cache off: identical
    cold_on, warm_on = _ttfts(
        SimBackend("justitia", total_kv=8192.0, token_events=True,
                   prefill_rate=PREFILL_RATE, prefix_cache=True)
    )
    assert cold_on == pytest.approx(cold_off)      # cold path unchanged
    shortening = (cold_on - warm_on) * PREFILL_RATE
    assert shortening == pytest.approx(HIT), (
        f"sim first token must come off the SHORTENED prefill: "
        f"TTFT delta covers {shortening:.1f} tokens, expected {HIT}"
    )


@pytest.mark.parametrize("fused", [False, True])
def test_engine_ttft_shortened_by_cached_blocks(tiny_model, fused):
    """Engine (both admission paths): a block-aligned cached head buys
    exactly ``hit / prefill_chunk`` iterations of first-token latency —
    the same "first token timestamped from the shortened prefill" rule
    the sim pins, at the engine's chunk granularity."""
    model, params = tiny_model

    def backend(prefix_cache):
        return EngineBackend(
            model, params, "justitia",
            pool_tokens=2048, block_size=BLOCK, max_batch=4,
            cache_len=128, prefill_chunk=CHUNK, token_scale=1,
            time_scale=1.0, prefix_cache=prefix_cache,
            fused_prefill=fused,
        )

    cold_off, warm_off = _ttfts(backend(prefix_cache=False))
    assert warm_off == pytest.approx(cold_off)     # cache off: identical
    cold_on, warm_on = _ttfts(backend(prefix_cache=True))
    assert cold_on == pytest.approx(cold_off)      # cold path unchanged
    shortening = (cold_on - warm_on) * CHUNK       # iterations -> tokens
    assert shortening == pytest.approx(HIT), (
        f"engine (fused={fused}) first token must come off the shortened "
        f"prefill: TTFT delta covers {shortening:.1f} tokens, "
        f"expected {HIT}"
    )


@pytest.fixture(scope="module")
def tiny_model():
    import jax

    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config("granite-3-2b").reduced(vocab=256)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params
