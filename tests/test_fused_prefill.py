"""Fused prefill-in-window tests (the PR 7 tentpole).

``fused_prefill=True`` rides each admitted prompt's uncached suffix
through the jitted decode windows one chunk-slice per ``lax.scan`` step
instead of charging a blocking whole-prefill pass at admission.  Pinned
here:

  * **fused-off oracle** — with the flag off (the default) the engine
    stays bit-identical to the frozen ``ReferenceServeEngine`` (same
    strictly-additive rule the prefix cache obeys);
  * **token equivalence** — the fused path computes the SAME token
    values as the unfused path (``Model.prefill_slice`` is numerically
    identical to ``prefill_chunked``), only the clock accounting moves;
  * **gating** — ring-buffer (sliding-window) caches are rejected: the
    slice writer assumes full-cache row addressing;
  * **prefix-cache composition** — fused admission still serves cached
    prefixes (including whole-prompt hits, which skip the fused stream
    entirely and decode from the zero-clock head write);
  * **scheduling-free windows** (minihyp) — with a prompt in flight the
    window sizer's new trigger (prefill-slice exhaustion / admission
    becoming possible mid-window) keeps every scheduling event on a
    window boundary: admissions and swaps at pass starts,
    stage-submitting completions and pf exhaustion only on a window's
    LAST step, and the fused stream never overshoots the prompt by a
    full slice.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from benchmarks.perf_engine import _snapshot, synth_agents
from repro.configs import get_config
from repro.core import InferenceSpec, agent_cost, make_scheduler
from repro.engine import EngineAgent, ReferenceServeEngine, ServeEngine
from repro.models import Model

VOCAB = 256


_MODEL_CACHE = {}


def _tiny_model():
    if "m" not in _MODEL_CACHE:
        cfg = get_config("granite-3-2b").reduced(vocab=VOCAB)
        model = Model(cfg)
        _MODEL_CACHE["m"] = (model, model.init(jax.random.PRNGKey(0)))
    return _MODEL_CACHE["m"]


@pytest.fixture(scope="module")
def tiny_model():
    return _tiny_model()


class TokenTap:
    """Listener that records each request's sampled token sequence."""

    def __init__(self):
        self.tokens = {}

    def on_token(self, agent_id, rid, tok, now):
        self.tokens.setdefault(rid, []).append(int(tok))


def _drain(model, params, agents, *, fused, sched="justitia", **kw):
    kw.setdefault("pool_tokens", 2048)
    kw.setdefault("max_batch", 4)
    kw.setdefault("cache_len", 256)
    kw.setdefault("prefill_chunk", 8)
    tap = TokenTap()
    eng = ServeEngine(
        model, params, make_scheduler(sched, float(kw["pool_tokens"])),
        fused_prefill=fused, listener=tap, **kw
    )
    for a in agents:
        eng.submit_agent(a)
    done = eng.run_until_idle()
    eng.alloc.check_invariants()
    return eng, done, tap.tokens


def test_fused_off_bit_identical_to_reference(tiny_model):
    """The flag-off engine must remain the reference engine, bit for bit
    (completions, clock, token/prefill/swap/decode-step counts)."""
    model, params = tiny_model
    for sched in ("justitia", "vtc"):
        snaps = {}
        for cls in (ServeEngine, ReferenceServeEngine):
            eng = cls(
                model, params, make_scheduler(sched, 256.0),
                pool_tokens=256, max_batch=4, cache_len=96,
            )
            for a in synth_agents(3, 10):
                eng.submit_agent(a)
            eng.run_until_idle(max_iters=5_000_000)
            eng.alloc.check_invariants()
            snaps[cls.__name__] = _snapshot(eng)
        assert snaps["ServeEngine"] == snaps["ReferenceServeEngine"], sched


def test_fused_token_values_match_unfused(tiny_model):
    """prefill_slice must reproduce prefill_chunked's numerics exactly:
    every request's sampled token sequence is identical under both
    admission paths (only the clock accounting differs)."""
    model, params = tiny_model
    plain = _drain(model, params, synth_agents(5, 8), fused=False)
    fused = _drain(model, params, synth_agents(5, 8), fused=True)
    assert fused[0].metrics["fused_slices"] > 0
    assert fused[2] == plain[2]
    assert fused[1].keys() == plain[1].keys()


def test_fused_rejects_ring_cache():
    """Sliding-window ring caches address rows mod window; the slice
    writer assumes full-cache addressing, so construction must fail."""
    cfg = get_config("h2o-danube-1.8b").reduced(
        vocab=VOCAB, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert cfg.sliding_window and cfg.sliding_window < 256
    with pytest.raises(ValueError, match="fused_prefill"):
        ServeEngine(
            model, params, make_scheduler("justitia", 256.0),
            pool_tokens=256, max_batch=2, cache_len=256,
            fused_prefill=True,
        )


def test_fused_composes_with_prefix_cache(tiny_model):
    """Fused admission still serves cached prefixes.  Three agents share
    a block-aligned prompt head; the third repeats the first's prompt
    exactly, so its whole prompt hits and it must decode straight from
    the zero-clock head write (no fused slices of its own)."""
    model, params = tiny_model
    rng = np.random.default_rng(9)
    head = rng.integers(0, VOCAB, size=32)      # two 16-token blocks
    prompts = [
        np.concatenate([head, rng.integers(0, VOCAB, size=16)]),
        np.concatenate([head, rng.integers(0, VOCAB, size=16)]),
    ]
    prompts.append(prompts[0].copy())           # whole-prompt repeat
    agents = [
        EngineAgent(
            i, 40 * i, [[(p, 12)]], agent_cost([InferenceSpec(len(p), 12)])
        )
        for i, p in enumerate(prompts)
    ]
    eng, done, toks = _drain(
        model, params, agents, fused=True,
        prefix_cache=True, block_size=16,
    )
    assert set(done) == {0, 1, 2}
    assert eng.metrics["prefix_hits"] >= 2
    assert eng.metrics["prefill_tokens_saved"] >= 32 + 48
    assert all(len(t) == 12 for t in toks.values())
    # the repeat's prompt was fully cached: its admission streamed no
    # slices, so total slices cover only the three uncached suffixes
    chunk = eng.prefill_chunk
    expected = sum(-(-n // chunk) for n in (48, 16, 0) if n)
    assert eng.metrics["fused_slices"] == expected


# ------------------------------------------- scheduling-free fused windows


class SpyEngine(ServeEngine):
    """Records every decode window (start iteration, width) and the
    iteration at which a fused prefill stream exhausted its prompt."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.windows = []
        self.pf_exhaust = []

    def _decode_once(self, limit=None):
        t0 = self.now
        pf = self._pf
        k = super()._decode_once(limit)
        self.windows.append((t0, k))
        if pf is not None and self._pf is None:
            self.pf_exhaust.append((self.now, pf.total, pf.written))
        return k


class TriggerTap:
    """Records the engine iteration of every scheduling event."""

    def __init__(self):
        self.pass_start = []       # admissions / swaps: pass boundaries
        self.stage_complete = []   # (agent_id, stage, now)

    def on_admit(self, agent_id, rid, now):
        self.pass_start.append(now)

    def on_swap_out(self, agent_id, rid, now):
        self.pass_start.append(now)

    def on_swap_in(self, agent_id, rid, now):
        self.pass_start.append(now)

    def on_stage_complete(self, agent_id, stage, now):
        self.stage_complete.append((agent_id, stage, now))


@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=2, max_value=6),
    st.sampled_from([4, 8, 16]),                 # prefill_chunk
    st.sampled_from([256, 2048]),                # swap pressure / roomy
    st.sampled_from(["justitia", "vtc"]),
)
@settings(max_examples=12, deadline=None)
def test_fused_windows_are_scheduling_free(
    seed, n_agents, chunk, pool, sched
):
    """ROADMAP invariant, extended by PR 7: a fused decode window spans
    no scheduling trigger.  Admissions and swaps may only happen at pass
    starts (the iteration right after a window ends); stage-SUBMITTING
    completions (a stage with a successor — the ones that schedule new
    work) and prefill-slice exhaustion only on a window's LAST step,
    never strictly inside; and exhaustion overshoots the prompt by less
    than one slice (the new ``ceil(remaining/chunk)`` cap is tight).
    Final-stage completions are exempt: with empty queues they schedule
    nothing, and the window may legally span them (module doc)."""
    model, params = _tiny_model()
    agents = synth_agents(seed, n_agents)
    n_stages = {a.agent_id: len(a.stages) for a in agents}
    tap = TriggerTap()
    eng = SpyEngine(
        model, params, make_scheduler(sched, float(pool)),
        pool_tokens=pool, max_batch=4, cache_len=96,
        prefill_chunk=chunk, listener=tap, fused_prefill=True,
    )
    for a in agents:
        eng.submit_agent(a)
    eng.run_until_idle(max_iters=5_000_000)
    eng.alloc.check_invariants()

    starts = {t0 for t0, _ in eng.windows}
    last_steps = {t0 + k - 1 for t0, k in eng.windows}
    interior = set()
    for t0, k in eng.windows:
        interior.update(range(t0 + 1, t0 + k - 1))

    for t in tap.pass_start:
        assert int(t) in starts, f"admission/swap at {t} not a pass start"
        assert int(t) not in interior, "admission/swap inside a window"
    submitting = [
        (aid, stage, t) for aid, stage, t in tap.stage_complete
        if stage < n_stages[aid] - 1
    ]
    for aid, stage, t in submitting:
        assert int(t) in last_steps, (
            f"agent {aid} stage {stage} (has a successor) completed at "
            f"{t}, not on a window's last step"
        )
        assert int(t) not in interior, "stage boundary inside a window"
    for now, total, written in eng.pf_exhaust:
        assert now in last_steps, (
            f"prefill exhaustion at {now} not on a window's last step"
        )
        assert written - total < chunk, (
            f"fused stream overshot the prompt: wrote {written} of "
            f"{total} (chunk {chunk})"
        )
