"""Per-architecture smoke tests (assignment deliverable f).

For each assigned arch: instantiate the REDUCED variant of the same family
(2 layers, d_model <= 512, <= 4 experts) and run one forward AND one train
step on CPU, asserting output shapes and the absence of NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import Model
from repro.training import AdamWConfig, init_adamw, make_train_step

# full-zoo forward/train sweeps dominate tier-1 runtime; run via `pytest -m slow`
pytestmark = pytest.mark.slow

B, S = 2, 16


def make_batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab).astype(
            jnp.int32
        )
    }
    if cfg.kind == "encdec":
        batch["embeds"] = (
            jax.random.normal(key, (B, cfg.n_audio_frames, cfg.d_model)) * 0.1
        )
    if cfg.kind == "vlm":
        batch["embeds"] = (
            jax.random.normal(key, (B, cfg.n_image_tokens, cfg.d_model)) * 0.1
        )
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()

    step = make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1))
    opt = init_adamw(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert not np.isnan(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x.astype(jnp.float32)))),
        jax.tree.map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                     params, params2),
        0.0,
    )
    assert moved > 0.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_serve_path(arch):
    """prefill + one decode step: shapes + no NaN."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    n_off = cfg.n_image_tokens if cfg.kind == "vlm" else 0
    logits, cache = model.prefill(params, dict(batch), cache_len=n_off + S + 8)
    assert logits.shape == (B, 1, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), n_off + S, jnp.int32)
    logits2, cache = model.decode(params, cache, tok, pos)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert not np.isnan(np.asarray(logits2, np.float32)).any()
