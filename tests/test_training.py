"""Training substrate tests: optimizer math, checkpoint roundtrip, data
pipeline determinism, and a real learning check on a tiny model."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.training import (
    AdamWConfig,
    DataConfig,
    SyntheticLM,
    adamw_update,
    data_iterator,
    init_adamw,
    lr_schedule,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)

# real train-step/learning checks dominate tier-1 runtime; run via `pytest -m slow`
pytestmark = pytest.mark.slow


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(0, 100, 5)]
    assert lrs[0] < lrs[2]            # warmup rises
    assert lrs[-1] < max(lrs)         # cosine decays
    assert min(lrs) >= 0.0


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_adamw(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=1,
                      total_steps=200, grad_clip=100.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    opt = init_adamw(params)
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=1)
    _, _, metrics = adamw_update(cfg, {"w": jnp.full(3, 1e6)}, opt, params)
    assert float(metrics["grad_norm"]) > 1e5  # reported raw


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
    }
    p = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(p, tree, step=7)
    restored, step = restore_checkpoint(p, tree)
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == np.dtype("bfloat16") or (
        np.asarray(restored["nested"]["b"], np.float32) == 1.0
    ).all()


def test_checkpoint_shape_mismatch_raises(tmp_path):
    p = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(p, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(p, {"a": jnp.zeros((3,))})


def test_data_pipeline_deterministic_and_learnable():
    cfg = DataConfig(vocab=128, seq_len=64, global_batch=4, seed=3)
    b1 = next(data_iterator(cfg))
    b2 = next(data_iterator(cfg))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 128


def test_tiny_model_learns_synthetic_language():
    """Loss must drop clearly below the uniform baseline within 60 steps."""
    mcfg = get_config("granite-3-2b").reduced(vocab=128, n_layers=2)
    model = Model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab=128, seq_len=64, global_batch=8, seed=0,
                      order=1, temperature=0.2)
    it = data_iterator(dcfg)
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                           weight_decay=0.0)
    ))
    opt = init_adamw(params)
    losses = []
    for i in range(80):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    uniform = np.log(128)
    assert losses[-1] < losses[0]
    assert min(losses[-5:]) < uniform * 0.75, losses[-5:]
