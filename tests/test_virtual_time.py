"""Property tests for the GPS virtual clock (paper §4.3, Eq. 2-3).

The defining properties of virtual-time fair queuing:
  1. V(t) is non-decreasing in t;
  2. F_j = V(a_j) + C_j is one-shot: later arrivals never reorder {F_j};
  3. the {F_j} order equals the exact GPS fluid completion order.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    GlobalVirtualClock,
    GpsAgent,
    VirtualClock,
    gps_finish_times,
)

arrival_cost_lists = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=1000.0, allow_nan=False),
        st.floats(min_value=0.1, max_value=1e5, allow_nan=False),
    ),
    min_size=1,
    max_size=40,
)


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4))
def test_virtual_time_monotone(items, m):
    clock = VirtualClock(m)
    items = sorted(items)
    prev_v = 0.0
    for i, (a, c) in enumerate(items):
        clock.on_arrival(i, a, c)
        v = clock.now(a)
        assert v >= prev_v - 1e-6
        prev_v = v
    # probing far in the future is still monotone
    assert clock.now(items[-1][0] + 1e6) >= prev_v - 1e-6


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4))
@settings(max_examples=60)
def test_virtual_finish_order_matches_gps_fluid(items, m):
    """The heart of fair queuing: ascending F_j == GPS completion order."""
    items = sorted(items)
    clock = VirtualClock(m)
    f = {}
    for i, (a, c) in enumerate(items):
        f[i] = clock.on_arrival(i, a, c)
    gps = gps_finish_times(
        [GpsAgent(i, a, c) for i, (a, c) in enumerate(items)], m
    )
    # sort by virtual finish; GPS fluid finishes must be non-decreasing along
    # that order (ties in F_j allowed to appear in any order)
    order = sorted(f, key=lambda k: (f[k], k))
    gps_seq = [gps[k] for k in order]
    for x, y in zip(gps_seq, gps_seq[1:]):
        assert x <= y + 1e-6


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4))
@settings(max_examples=60)
def test_one_shot_property(items, m):
    """F_j computed at arrival is unchanged by any later arrivals."""
    items = sorted(items)
    clock_full = VirtualClock(m)
    f_full = [clock_full.on_arrival(i, a, c) for i, (a, c) in enumerate(items)]
    # recompute each F_j with a clock that only ever saw the prefix
    for j in range(len(items)):
        clock_prefix = VirtualClock(m)
        for i, (a, c) in enumerate(items[: j + 1]):
            f_pref = clock_prefix.on_arrival(i, a, c)
        assert f_pref == pytest.approx(f_full[j], rel=1e-9, abs=1e-6)


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4))
@settings(max_examples=60)
def test_gps_finish_after_arrival_plus_solo_time(items, m):
    """GPS completion can never beat running alone on the full backend."""
    items = sorted(items)
    gps = gps_finish_times(
        [GpsAgent(i, a, c) for i, (a, c) in enumerate(items)], m
    )
    for i, (a, c) in enumerate(items):
        assert gps[i] >= a + c / m - 1e-6


# ------------------------------------------- global (fleet) virtual time


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4))
@settings(max_examples=40)
def test_single_replica_global_clock_matches_local(items, m):
    """K=1: the reconciled global clock IS the per-backend clock."""
    items = sorted(items)
    local = VirtualClock(m)
    gclock = GlobalVirtualClock([m])
    f_local = {}
    for i, (a, c) in enumerate(items):
        f_local[i] = local.on_arrival(i, a, c)
        gclock.register(0, i, a, c)
    t_end = items[-1][0] + 100.0
    snap = gclock.reconcile(t_end)
    assert snap.lag == 0.0
    assert snap.global_virtual_time == pytest.approx(local.now(t_end))
    for i, f in f_local.items():
        assert gclock.virtual_finish[i] == pytest.approx(f)
        assert gclock.replica_of[i] == 0


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4),
       st.sampled_from([2, 3, 4]))
@settings(max_examples=40)
def test_global_clock_order_free_registration(items, m, k):
    """Registration order must not matter: submissions interleave with runs
    online, so arrivals reach the fleet clock out of time order."""
    items = sorted(items)
    in_order = GlobalVirtualClock([m] * k)
    shuffled = GlobalVirtualClock([m] * k)
    for i, (a, c) in enumerate(items):
        in_order.register(i % k, i, a, c)
    for i, (a, c) in reversed(list(enumerate(items))):
        shuffled.register(i % k, i, a, c)
    t_end = items[-1][0] + 10.0
    s1, s2 = in_order.reconcile(t_end), shuffled.reconcile(t_end)
    assert s1.virtual_times == s2.virtual_times
    assert in_order.virtual_finish == shuffled.virtual_finish
    assert in_order.pampering_order() == shuffled.pampering_order()


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4),
       st.sampled_from([2, 3]))
@settings(max_examples=40)
def test_global_virtual_time_monotone_and_bounded_by_lag(items, m, k):
    """min_k V_k is non-decreasing and every replica sits within the lag."""
    items = sorted(items)
    gclock = GlobalVirtualClock([m] * k)
    for i, (a, c) in enumerate(items):
        gclock.register(i % k, i, a, c)
    prev_global = 0.0
    t_max = items[-1][0]
    for t in [t_max * f for f in (0.25, 0.5, 0.75, 1.0)] + [t_max + 50.0]:
        snap = gclock.reconcile(t)
        assert snap.global_virtual_time >= prev_global - 1e-6
        assert snap.lag >= 0.0
        for v in snap.virtual_times:
            assert (
                snap.global_virtual_time - 1e-6
                <= v
                <= snap.global_virtual_time + snap.lag + 1e-6
            )
        prev_global = snap.global_virtual_time


def test_global_clock_lag_measures_imbalance():
    """All load on one replica: its clock races ahead, the idle replica's
    stalls, and the lag is exactly the spread."""
    gclock = GlobalVirtualClock([100.0, 100.0])
    gclock.register(0, 0, 0.0, 500.0)
    gclock.register(0, 1, 0.0, 500.0)
    snap = gclock.reconcile(2.0)
    assert snap.virtual_times[1] == 0.0          # idle clock stalls
    assert snap.virtual_times[0] > 0.0
    assert snap.lag == pytest.approx(snap.virtual_times[0])
    assert snap.global_virtual_time == 0.0


def test_delay_bound_service_rate_converts_units():
    """The same fleet expressed in iteration time (pool tokens) and in
    workload seconds (pool * rate cost-units/s, as ReplicatedBackend builds
    it) must give the same Theorem B.1 bound up to the time-unit change."""
    rate = 30.0
    iter_clock = GlobalVirtualClock([1000.0, 2000.0])
    sec_clock = GlobalVirtualClock([1000.0 * rate, 2000.0 * rate])
    b_iters = iter_clock.delay_bound(50.0, 5000.0)
    b_secs = sec_clock.delay_bound(50.0, 5000.0, service_rate=rate)
    assert b_iters == pytest.approx(2.0 * 50.0 + 5000.0 / 1000.0)
    assert b_secs == pytest.approx(b_iters / rate)


def test_global_clock_rejects_bad_registration():
    gclock = GlobalVirtualClock([100.0])
    with pytest.raises(ValueError):
        gclock.register(1, 0, 0.0, 10.0)         # replica out of range
    gclock.register(0, 0, 5.0, 10.0)
    gclock.reconcile(10.0)
    with pytest.raises(ValueError):
        gclock.register(0, 1, 3.0, 10.0)         # predates horizon
    with pytest.raises(ValueError):
        GlobalVirtualClock([])


def test_clock_rejects_time_reversal():
    clock = VirtualClock(100.0)
    clock.on_arrival(0, 10.0, 5.0)
    with pytest.raises(ValueError):
        clock.advance(5.0)


def test_idle_clock_stalls():
    clock = VirtualClock(100.0)
    clock.on_arrival(0, 0.0, 10.0)  # GPS-finishes at t=0.1
    v1 = clock.now(1.0)
    v2 = clock.now(100.0)
    assert v1 == pytest.approx(v2)  # nothing active: V stalls
