"""Property tests for the GPS virtual clock (paper §4.3, Eq. 2-3).

The defining properties of virtual-time fair queuing:
  1. V(t) is non-decreasing in t;
  2. F_j = V(a_j) + C_j is one-shot: later arrivals never reorder {F_j};
  3. the {F_j} order equals the exact GPS fluid completion order.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import GpsAgent, VirtualClock, gps_finish_times

arrival_cost_lists = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=1000.0, allow_nan=False),
        st.floats(min_value=0.1, max_value=1e5, allow_nan=False),
    ),
    min_size=1,
    max_size=40,
)


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4))
def test_virtual_time_monotone(items, m):
    clock = VirtualClock(m)
    items = sorted(items)
    prev_v = 0.0
    for i, (a, c) in enumerate(items):
        clock.on_arrival(i, a, c)
        v = clock.now(a)
        assert v >= prev_v - 1e-6
        prev_v = v
    # probing far in the future is still monotone
    assert clock.now(items[-1][0] + 1e6) >= prev_v - 1e-6


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4))
@settings(max_examples=60)
def test_virtual_finish_order_matches_gps_fluid(items, m):
    """The heart of fair queuing: ascending F_j == GPS completion order."""
    items = sorted(items)
    clock = VirtualClock(m)
    f = {}
    for i, (a, c) in enumerate(items):
        f[i] = clock.on_arrival(i, a, c)
    gps = gps_finish_times(
        [GpsAgent(i, a, c) for i, (a, c) in enumerate(items)], m
    )
    # sort by virtual finish; GPS fluid finishes must be non-decreasing along
    # that order (ties in F_j allowed to appear in any order)
    order = sorted(f, key=lambda k: (f[k], k))
    gps_seq = [gps[k] for k in order]
    for x, y in zip(gps_seq, gps_seq[1:]):
        assert x <= y + 1e-6


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4))
@settings(max_examples=60)
def test_one_shot_property(items, m):
    """F_j computed at arrival is unchanged by any later arrivals."""
    items = sorted(items)
    clock_full = VirtualClock(m)
    f_full = [clock_full.on_arrival(i, a, c) for i, (a, c) in enumerate(items)]
    # recompute each F_j with a clock that only ever saw the prefix
    for j in range(len(items)):
        clock_prefix = VirtualClock(m)
        for i, (a, c) in enumerate(items[: j + 1]):
            f_pref = clock_prefix.on_arrival(i, a, c)
        assert f_pref == pytest.approx(f_full[j], rel=1e-9, abs=1e-6)


@given(arrival_cost_lists, st.floats(min_value=1.0, max_value=1e4))
@settings(max_examples=60)
def test_gps_finish_after_arrival_plus_solo_time(items, m):
    """GPS completion can never beat running alone on the full backend."""
    items = sorted(items)
    gps = gps_finish_times(
        [GpsAgent(i, a, c) for i, (a, c) in enumerate(items)], m
    )
    for i, (a, c) in enumerate(items):
        assert gps[i] >= a + c / m - 1e-6


def test_clock_rejects_time_reversal():
    clock = VirtualClock(100.0)
    clock.on_arrival(0, 10.0, 5.0)
    with pytest.raises(ValueError):
        clock.advance(5.0)


def test_idle_clock_stalls():
    clock = VirtualClock(100.0)
    clock.on_arrival(0, 0.0, 10.0)  # GPS-finishes at t=0.1
    v1 = clock.now(1.0)
    v2 = clock.now(100.0)
    assert v1 == pytest.approx(v2)  # nothing active: V stalls
