"""Fault-tolerant fleet serving (PR 8): deterministic fault injection,
watchdog failure detection, agent failover, and degraded-fleet fairness.

Covers the PR 8 invariants (ROADMAP "Failure semantics"):

  * :class:`repro.api.FaultPlan` — builder validation, seeded
    reproducibility, horizon math;
  * :class:`repro.core.GlobalVirtualClock` failure/migration — virtual
    time carried across a migration, dead clocks frozen, live-only
    snapshots and delay bounds;
  * end-to-end sim-fleet crash: every agent completes on the survivors,
    event streams stay conformant across the migration (AgentRequeued
    resets the per-replica chain), JCTs span from the ORIGINAL arrival;
  * stalls/slowdowns shorter than the watchdog budget leave final
    results bit-identical to the fault-free fleet (timestamps are
    model-derived, not advancement-driven) and exercise only the
    suspect/recover path;
  * with the watchdog disarmed, a crashed-and-busy child raises
    :class:`repro.api.FleetStalledError` with diagnostics instead of
    letting the fleet spin;
  * routers place over live replicas only after a failure, and
    ``Router.rebalance`` routes failover through the normal pick path;
  * the same crash on an engine fleet completes on the survivor.
"""

import math

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from test_event_conformance import assert_conformant_stream

from repro.api import (
    AgentService,
    AgentSpec,
    Fault,
    FaultPlan,
    FleetStalledError,
    ReplicatedBackend,
    SimBackend,
)
from repro.api.replicated import RoundRobinRouter
from repro.configs import get_config
from repro.core import InferenceSpec
from repro.core.virtual_time import GlobalVirtualClock
from repro.models import Model


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("granite-3-2b").reduced(vocab=256)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _specs(n, *, stages=2, spacing=0.2):
    return [
        AgentSpec(
            stages=[[InferenceSpec(300, 60)] for _ in range(stages)],
            arrival=spacing * i,
            name=f"a{i}",
        )
        for i in range(n)
    ]


# ------------------------------------------------------------- FaultPlan


class TestFaultPlan:
    def test_builder_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            Fault(0, "explode", 1.0)
        with pytest.raises(ValueError, match="permanent"):
            Fault(0, "crash", 1.0, duration=2.0)
        with pytest.raises(ValueError, match="factor"):
            Fault(0, "slowdown", 1.0, duration=2.0, factor=1.5)
        plan = FaultPlan().stall(0, 1.0, 2.0)
        with pytest.raises(ValueError, match="overlap"):
            plan.stall(0, 2.0, 1.0)
        plan.stall(1, 2.0, 1.0)  # other replica: fine
        plan.crash(0, 10.0)
        with pytest.raises(ValueError, match="after it"):
            plan.stall(0, 11.0, 1.0)
        with pytest.raises(ValueError, match="precedes"):
            plan.crash(1, 1.0)

    def test_seeded_reproducible(self):
        a = FaultPlan.seeded(42, 4, n_crashes=1, n_stalls=2)
        b = FaultPlan.seeded(42, 4, n_crashes=1, n_stalls=2)
        assert a.faults == b.faults
        assert sum(f.kind == "crash" for f in a.faults) == 1
        assert sum(f.kind == "stall" for f in a.faults) == 2
        # crash and stalls land on distinct replicas
        assert len({f.replica for f in a.faults}) == 3

    def test_horizon(self):
        plan = (
            FaultPlan()
            .crash(0, 5.0)
            .stall(1, 2.0, 3.0)
            .slowdown(2, 1.0, 2.0, 0.5)
        )
        # crash: clamped at the crash time forever
        assert plan.horizon(0, 3.0) == 3.0
        assert plan.horizon(0, 7.0) == 5.0
        assert plan.horizon(0, 1e9) == 5.0
        # stall: clamped at the window start until the window closes
        assert plan.horizon(1, 3.0) == 2.0
        assert plan.horizon(1, 4.999) == 2.0
        assert plan.horizon(1, 6.0) == 6.0
        # slowdown: factor-speed inside the window, free outside
        assert plan.horizon(2, 0.5) == 0.5
        assert plan.horizon(2, 2.0) == pytest.approx(1.5)
        assert plan.horizon(2, 5.0) == 5.0
        # unaffected replica
        assert plan.horizon(3, 9.0) == 9.0
        assert plan.max_boundary() == 5.0
        assert plan.boundaries() == [1.0, 2.0, 3.0, 5.0]


# ------------------------------------------------- global clock failover


class TestGlobalClockFailover:
    def test_migrate_carries_virtual_finish(self):
        gc = GlobalVirtualClock([100.0, 100.0])
        gc.register(0, 1, 0.0, 50.0)
        gc.register(1, 2, 0.0, 50.0)
        gc.reconcile(0.5)
        f1 = gc.virtual_finish[1]
        gc.fail_replica(0)
        gc.migrate(1, 1, 1.0, 30.0)
        gc.reconcile(2.0)
        assert gc.virtual_finish[1] == f1, "migration rewrote accrued F_j"
        assert gc.replica_of[1] == 1

    def test_fail_replica_returns_unreplayed_orphans(self):
        gc = GlobalVirtualClock([100.0, 100.0])
        gc.register(0, 7, 5.0, 10.0)   # buffered, never reconciled
        orphans = gc.fail_replica(0)
        assert orphans == [(7, 10.0)]
        with pytest.raises(ValueError, match="dead"):
            gc.register(0, 8, 6.0, 1.0)
        with pytest.raises(ValueError, match="dead"):
            gc.migrate(9, 0, 6.0, 1.0)

    def test_dead_clock_frozen_and_live_snapshot(self):
        gc = GlobalVirtualClock([100.0, 100.0, 100.0])
        for k in range(3):
            gc.register(k, k, 0.0, 1000.0)
        snap = gc.reconcile(1.0)
        v_dead = snap.virtual_times[0]
        gc.fail_replica(0)
        snap2 = gc.reconcile(3.0)
        assert snap2.virtual_times[0] == v_dead, "dead clock advanced"
        assert snap2.live == (1, 2)
        assert snap2.virtual_times[1] > v_dead
        # global time / lag computed over live replicas only
        assert snap2.global_virtual_time == min(snap2.virtual_times[1:])
        assert snap2.lag == (
            max(snap2.virtual_times[1:]) - min(snap2.virtual_times[1:])
        )

    def test_delay_bound_over_live_capacities(self):
        gc = GlobalVirtualClock([50.0, 200.0])
        full = gc.delay_bound(3.0, 100.0)
        gc.fail_replica(1)          # only the SMALL replica survives
        degraded = gc.delay_bound(3.0, 100.0)
        assert degraded == full     # worst replica was already the bound
        gc2 = GlobalVirtualClock([50.0, 200.0])
        gc2.fail_replica(0)         # only the big replica survives
        assert gc2.delay_bound(3.0, 100.0) < full


# ------------------------------------------------- sim fleet end to end


def _fleet(plan=None, watchdog=None, **kw):
    return AgentService.sim(
        replicas=4, total_kv=800.0, token_events=True,
        fault_plan=plan, watchdog_timeout=watchdog, **kw,
    )


def test_crash_failover_completes_on_survivors():
    svc0 = _fleet()
    h0 = [svc0.submit(s) for s in _specs(12)]
    base = svc0.drain()

    plan = FaultPlan().crash(1, 3.0)
    svc = _fleet(plan, watchdog=0.5)
    handles = [svc.submit(s) for s in _specs(12)]
    res = svc.drain()

    assert set(res.finish) == set(base.finish), "agents lost in failover"
    assert res.metrics["replica_failures"] == 1
    assert res.metrics["failed_replicas"] == [1]
    assert res.metrics["live_replicas"] == 3
    assert res.metrics["agents_requeued"] >= 1
    assert res.event_counts.get("ReplicaFailed") == 1
    assert res.event_counts.get("AgentRequeued") == (
        res.metrics["agents_requeued"]
    )
    requeued = 0
    for h in handles:
        assert_conformant_stream(
            h, expect_replica=True, allow_requeue=True
        )
        if any(type(e).__name__ == "AgentRequeued" for e in h.events):
            requeued += 1
            # handle tracks the agent to its new replica, and the fleet's
            # assignment agrees
            assert h.replica != 1
            assert h.replica == svc.backend.assignment[h.agent_id]
            # JCT spans from the ORIGINAL arrival, not the re-submission
            assert res.jct[h.agent_id] == pytest.approx(
                res.finish[h.agent_id] - h.arrival
            )
    assert requeued == res.metrics["agents_requeued"]
    # the degraded fleet pays: no agent finished EARLIER than fault-free
    # on the failed replica's survivors is not guaranteed per-agent, but
    # fleet-wide max delay is bounded and recorded
    ratio = max(res.jct.values()) / max(base.jct.values())
    assert 1.0 <= ratio < 10.0


def test_stall_under_budget_bit_identical_plus_recovery():
    svc0 = _fleet()
    [svc0.submit(s) for s in _specs(12)]
    base = svc0.drain()

    plan = FaultPlan().stall(2, 1.0, 1.5)
    svc = _fleet(plan, watchdog=1.0)   # budget 15s >> 1.5s stall
    [svc.submit(s) for s in _specs(12)]
    res = svc.drain()

    assert res.finish == base.finish, "stall changed final results"
    assert res.jct == base.jct
    assert res.swaps == base.swaps
    assert res.metrics["replica_failures"] == 0
    assert res.event_counts.get("ReplicaRecovered", 0) >= 1
    assert "ReplicaFailed" not in res.event_counts


def test_slowdown_bit_identical():
    svc0 = _fleet()
    [svc0.submit(s) for s in _specs(12)]
    base = svc0.drain()

    plan = FaultPlan().slowdown(0, 0.5, 2.0, 0.25)
    svc = _fleet(plan, watchdog=1.0)
    [svc.submit(s) for s in _specs(12)]
    res = svc.drain()
    assert res.finish == base.finish
    assert res.jct == base.jct
    assert res.metrics["replica_failures"] == 0


def test_crash_without_watchdog_raises_stall_guard():
    plan = FaultPlan().crash(0, 2.0)
    svc = _fleet(plan)   # watchdog disarmed
    [svc.submit(s) for s in _specs(8)]
    with pytest.raises(FleetStalledError) as ei:
        svc.drain()
    err = ei.value
    assert err.replica == 0
    assert err.last_time == pytest.approx(2.0)
    assert err.in_flight > 0
    assert set(err.queue_depths) == {0, 1, 2, 3}
    assert "watchdog" in str(err)


def test_crash_determinism():
    """Same plan + same workload => bit-identical failover run."""
    plan_a = FaultPlan.seeded(9, 4, crash_window=(2.0, 4.0))
    plan_b = FaultPlan.seeded(9, 4, crash_window=(2.0, 4.0))
    runs = []
    for plan in (plan_a, plan_b):
        svc = _fleet(plan, watchdog=0.5)
        [svc.submit(s) for s in _specs(12)]
        res = svc.drain()
        runs.append(res)
    assert runs[0].finish == runs[1].finish
    assert runs[0].jct == runs[1].jct
    assert runs[0].event_counts == runs[1].event_counts


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=8, deadline=None)
def test_crash_failover_never_loses_agents(seed):
    """Property: any seeded 1-of-4 crash completes every agent."""
    plan = FaultPlan.seeded(seed, 4, crash_window=(1.0, 6.0))
    svc = _fleet(plan, watchdog=0.5)
    handles = [svc.submit(s) for s in _specs(10)]
    res = svc.drain()
    assert set(res.finish) == {h.agent_id for h in handles}
    assert res.metrics["replica_failures"] == 1


# ------------------------------------------------------ router behavior


def test_routers_place_on_live_replicas_only():
    plan = FaultPlan().crash(0, 1.0)
    svc = _fleet(plan, watchdog=0.25, router="round_robin")
    [svc.submit(s) for s in _specs(8)]
    svc.run(30.0)
    fleet = svc.backend
    assert fleet.dead_replica_indices == (0,)
    # post-failure submissions go to survivors only, and round-robin
    # cycles over the three live indices
    late = [
        svc.submit(AgentSpec(stages=[[InferenceSpec(100, 10)]],
                             arrival=svc.now, name=f"late{i}"))
        for i in range(6)
    ]
    picks = [fleet.assignment[h.agent_id] for h in late]
    assert 0 not in picks
    assert set(picks) == {1, 2, 3}
    res = svc.drain()
    assert all(h.agent_id in res.finish for h in late)


def test_rebalance_default_routes_through_pick():
    r = RoundRobinRouter(3)
    specs = [(AgentSpec(stages=[[InferenceSpec(10, 5)]]), i, 1.0)
             for i in range(5)]
    assert r.rebalance(specs) == [0, 1, 2, 0, 1]


# ------------------------------------------------- closed-loop failover


def test_closed_loop_failover_preserves_turn_exactness():
    """A crash mid-session must not double-fire stage callbacks: completed
    stages are never replayed, the in-progress stage's callback never
    fired pre-crash, so every logical stage triggers its callback exactly
    once and sessions produce the same number of turns as fault-free."""

    def make_specs():
        counts = {}

        def session(aid):
            def cb(outcome):
                counts[aid] = counts.get(aid, 0) + 1
                if counts[aid] < 3:
                    return [InferenceSpec(200, 40)]
                return None

            return cb

        return [
            AgentSpec(
                stages=[[InferenceSpec(300, 60)]],
                arrival=0.3 * i,
                predicted_cost=3000.0,
                true_cost=3000.0,
                next_stage=session(i),
                name=f"cl{i}",
            )
            for i in range(8)
        ], counts

    specs0, counts0 = make_specs()
    svc0 = _fleet()
    [svc0.submit(s) for s in specs0]
    base = svc0.drain()
    assert all(c == 3 for c in counts0.values())

    specs, counts = make_specs()
    plan = FaultPlan().crash(1, 2.5)
    svc = _fleet(plan, watchdog=0.5)
    handles = [svc.submit(s) for s in specs]
    res = svc.drain()
    assert set(res.finish) == set(base.finish)
    assert res.metrics["replica_failures"] == 1
    assert counts == counts0, "failover changed callback cadence"
    for h in handles:
        assert_conformant_stream(h, expect_replica=True, allow_requeue=True)


# -------------------------------------------------------- engine fleet


def test_engine_fleet_crash_failover(tiny_model):
    model, params = tiny_model
    svc = AgentService.engine(
        model, params, "justitia", replicas=2, router="round_robin",
        pool_tokens=256, block_size=16, max_batch=2, cache_len=64,
        token_scale=1, time_scale=1.0,
        fault_plan=FaultPlan().crash(0, 6.0),
        watchdog_timeout=2.0, watchdog_retries=1,
    )
    raw = [
        AgentSpec(stages=[[InferenceSpec(16, 30)], [InferenceSpec(12, 20)]],
                  arrival=float(i))
        for i in range(4)
    ]
    handles = [svc.submit(s) for s in raw]
    res = svc.drain()
    assert set(res.finish) == {h.agent_id for h in handles}
    assert res.metrics["replica_failures"] == 1
    assert res.metrics["failed_replicas"] == [0]
    assert res.metrics["agents_requeued"] >= 1
    for h in handles:
        assert_conformant_stream(
            h, expect_replica=True, allow_requeue=True
        )


# ---------------------------------------------------- degraded fairness


def test_degraded_delay_bound_excludes_dead_capacity():
    plan = FaultPlan().crash(3, 2.0)
    svc = _fleet(plan, watchdog=0.5)
    [svc.submit(s) for s in _specs(10)]
    svc.drain()
    fleet: ReplicatedBackend = svc.backend
    full = GlobalVirtualClock(fleet.virtual_capacities).delay_bound(
        3000.0, 3000.0
    )
    degraded = fleet.delay_bound(3000.0, 3000.0)
    # homogeneous fleet: per-replica bound unchanged by losing a replica
    assert degraded == pytest.approx(full)
    # but it is genuinely computed over the survivors
    assert fleet.global_clock.live_indices == (0, 1, 2)


def test_fault_kwargs_require_fleet():
    with pytest.raises(ValueError, match="replicas"):
        AgentService.sim(fault_plan=FaultPlan().crash(0, 1.0))


def test_concurrent_crash_failover_bit_identical():
    """fleet_workers>1 reproduces the sequential crash-failover run
    event-for-event — with and without work stealing armed on top."""
    plan = FaultPlan().crash(1, 3.0)
    for steal in (None, 1.3):
        runs = []
        for workers in (None, 4):
            svc = _fleet(plan, watchdog=0.5, fleet_workers=workers,
                         steal_threshold=steal)
            handles = [svc.submit(s) for s in _specs(12)]
            runs.append((svc.drain(), handles))
        (ra, _), (rb, hb) = runs
        assert ra.finish == rb.finish
        assert ra.jct == rb.jct
        assert ra.event_counts == rb.event_counts
        assert rb.metrics["fleet_workers"] == 4
        assert rb.metrics["replica_failures"] == 1
        for h in hb:
            assert_conformant_stream(
                h, expect_replica=True, allow_requeue=True
            )


def test_fleet_without_plan_unchanged():
    """fault_plan=None keeps the original plain lockstep drive —
    bit-identical results with and without the fault machinery armed."""
    a = AgentService.sim(replicas=3, total_kv=900.0)
    [a.submit(s) for s in _specs(9)]
    ra = a.drain()
    b = AgentService.sim(replicas=3, total_kv=900.0, fault_plan=None,
                         watchdog_timeout=None)
    [b.submit(s) for s in _specs(9)]
    rb = b.drain()
    assert ra.finish == rb.finish
    assert ra.jct == rb.jct
    assert ra.event_counts == rb.event_counts
