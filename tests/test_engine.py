"""End-to-end serving engine tests (real JAX model, tiny config) and
block-allocator property tests."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core import InferenceSpec, agent_cost, make_scheduler
from repro.engine import EngineAgent, ServeEngine
from repro.kvcache import BlockAllocator, OutOfBlocks
from repro.models import Model

VOCAB = 256


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("granite-3-2b").reduced(vocab=VOCAB)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def mk_agent(rng, aid, n_inf, p, d, arrival=0, stages=1):
    sts = []
    for _ in range(stages):
        sts.append([(rng.integers(0, VOCAB, size=p), d) for _ in range(n_inf)])
    specs = [InferenceSpec(p, d)] * (n_inf * stages)
    return EngineAgent(aid, arrival, sts, agent_cost(specs))


def run_engine(model, params, sched_name, agents, **kw):
    kw.setdefault("pool_tokens", 2048)
    kw.setdefault("max_batch", 4)
    kw.setdefault("cache_len", 256)
    sched = make_scheduler(sched_name, float(kw["pool_tokens"]))
    eng = ServeEngine(model, params, sched, **kw)
    for a in agents:
        eng.submit_agent(a)
    done = eng.run_until_idle()
    eng.alloc.check_invariants()
    return eng, done


def test_all_agents_complete_and_tokens_counted(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(0)
    agents = [
        mk_agent(rng, 0, 2, 64, 32),
        mk_agent(rng, 1, 1, 32, 16),
        mk_agent(rng, 2, 1, 16, 8, stages=2),
    ]
    eng, done = run_engine(model, params, "justitia", agents)
    assert set(done) == {0, 1, 2}
    # 2*32 + 1*16 + 2*8 = 96 decode tokens
    assert eng.metrics["tokens"] == 96
    assert eng.metrics["prefills"] == 5


def test_memory_pressure_triggers_swap_and_still_completes(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(1)
    agents = [mk_agent(rng, i, 2, 60, 40) for i in range(3)]
    eng, done = run_engine(
        model, params, "justitia", agents, pool_tokens=320, max_batch=4
    )
    assert set(done) == {0, 1, 2}
    assert eng.metrics["swaps"] + eng.alloc.swap_events > 0
    assert eng.metrics["tokens"] == 3 * 2 * 40


def test_justitia_unblocks_mouse_fcfs_does_not(tiny_model):
    """Head-of-line blocking: under FCFS the mouse waits for the elephant's
    queued inferences; under Justitia (earlier GPS finish) it jumps them."""
    model, params = tiny_model

    def agents():
        rng = np.random.default_rng(2)
        eleph = mk_agent(rng, 0, 6, 100, 100)    # 6 infs, only a few fit
        mouse = mk_agent(rng, 1, 1, 16, 8)
        return [eleph, mouse]

    _, done_j = run_engine(model, params, "justitia", agents(),
                           pool_tokens=512, max_batch=2, cache_len=256)
    _, done_f = run_engine(model, params, "vllm-fcfs", agents(),
                           pool_tokens=512, max_batch=2, cache_len=256)
    assert done_j[1] < done_f[1] / 2  # mouse much earlier under Justitia
    assert done_j[1] < done_j[0]


def test_engine_rejects_oversized_request(tiny_model):
    model, params = tiny_model
    rng = np.random.default_rng(3)
    sched = make_scheduler("justitia", 2048.0)
    eng = ServeEngine(model, params, sched, pool_tokens=2048, max_batch=2,
                      cache_len=128)
    with pytest.raises(ValueError):
        eng.submit_agent(mk_agent(rng, 0, 1, 200, 50))


# ------------------------------------------------------------ allocator


def test_allocator_basic():
    a = BlockAllocator(total_tokens=160, block_size=16)
    assert a.n_blocks == 10
    s = a.admit(1, 33)   # 3 blocks
    assert s.n_blocks == 3 and a.free_blocks == 7
    for _ in range(15):
        assert a.append_token(1)
    assert a.seq(1).n_tokens == 48
    a.release(1)
    assert a.free_blocks == 10
    a.check_invariants()


def test_allocator_swap_cycle():
    a = BlockAllocator(total_tokens=64, block_size=16)
    a.admit(1, 30)
    a.admit(2, 30)
    with pytest.raises(OutOfBlocks):
        a.admit(3, 40)
    freed = a.swap_out(1)
    assert freed == 2 and a.free_blocks == 2
    assert a.admit(3, 30)
    assert not a.swap_in(1)        # no room while 2,3 live
    a.release(3)
    assert a.swap_in(1)
    assert a.seq(1).n_tokens == 30
    a.check_invariants()


def test_allocator_incremental_used_tokens_counter():
    """used_tokens is an O(1) incremental counter (PR-4 satellite): every
    mutator keeps it equal to the recomputed live-token sum, which
    check_invariants asserts."""
    a = BlockAllocator(total_tokens=160, block_size=16)
    assert a.used_tokens == 0
    a.admit(1, 33)
    a.admit(2, 10)
    assert a.used_tokens == 43
    a.append_token(1)
    assert a.used_tokens == 44
    a.swap_out(1)
    assert a.used_tokens == 10
    assert a.swap_in(1)
    assert a.used_tokens == 44
    a.release(2)
    assert a.used_tokens == 34
    a.swap_out(1)
    a.release(1)               # releasing a swapped seq: no live tokens
    assert a.used_tokens == 0
    a.check_invariants()


def test_allocator_bulk_append_tokens():
    """append_tokens(k) == k successful append_token calls, all-or-nothing
    when the pool cannot host the growth (decode-window bulk commit)."""
    a = BlockAllocator(total_tokens=96, block_size=16)
    a.admit(1, 10)
    assert a.append_tokens(1, 30)          # 10 -> 40 tokens, 3 blocks
    assert a.seq(1).n_tokens == 40
    assert a.seq(1).n_blocks == 3
    assert a.used_tokens == 40
    a.admit(2, 40)                          # 3 more blocks: pool now full
    assert not a.append_tokens(1, 20)       # would need a 4th free block
    assert a.seq(1).n_tokens == 40          # nothing partially applied
    assert a.append_tokens(1, 8)            # fits in the last block
    assert a.seq(1).n_tokens == 48
    assert a.append_tokens(1, 0)
    a.check_invariants()
    b = BlockAllocator(total_tokens=96, block_size=16)
    b.admit(7, 10)
    b.swap_out(7)
    with pytest.raises(ValueError):
        b.append_tokens(7, 3)


@given(
    ops=st.lists(
        st.tuples(st.sampled_from(["admit", "grow", "growk", "release",
                                   "swap"]),
                  st.integers(0, 7), st.integers(1, 90)),
        max_size=120,
    )
)
@settings(max_examples=120, deadline=None)
def test_allocator_invariants_random_ops(ops):
    """No double allocation, no leaks, occupancy bounded — whatever the
    operation sequence."""
    a = BlockAllocator(total_tokens=256, block_size=16)
    live = {}
    for op, sid, n in ops:
        try:
            if op == "admit" and sid not in live:
                a.admit(sid, n)
                live[sid] = True
            elif op == "grow" and sid in live and not a.seq(sid).swapped:
                a.append_token(sid)
            elif op == "growk" and sid in live and not a.seq(sid).swapped:
                a.append_tokens(sid, n % 24)
            elif op == "release" and sid in live:
                a.release(sid)
                del live[sid]
            elif op == "swap" and sid in live and not a.seq(sid).swapped:
                a.swap_out(sid)
        except OutOfBlocks:
            pass
        a.check_invariants()
        assert a.used_tokens <= 256
