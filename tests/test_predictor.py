"""Tests for the TF-IDF + per-class MLP cost predictor (paper §4.2)."""

import numpy as np
import pytest

from repro.predictor import (
    AgentCostPredictor,
    MlpCostModel,
    TfidfVectorizer,
    relative_error,
    tokenize,
)
from repro.workloads import sample_agent


def test_tokenize_lowercase_alnum():
    assert tokenize("Hello, World-42!") == ["hello", "world", "42"]


def test_tfidf_shapes_and_determinism():
    corpus = [f"alpha beta gamma {'delta ' * (i % 5)}" for i in range(20)]
    v = TfidfVectorizer(max_features=8, min_df=2)
    x1 = v.fit_transform(corpus)
    x2 = v.transform(corpus)
    assert x1.shape == (20, v.dim)
    np.testing.assert_allclose(x1, x2)


def test_tfidf_min_df_filters_hapax():
    corpus = ["common common rare_once"] + ["common word"] * 10
    v = TfidfVectorizer(max_features=32, min_df=3)
    v.fit(corpus)
    assert "rare_once" not in v.vocab_
    assert "common" in v.vocab_


def test_tfidf_length_feature_tracks_length():
    v = TfidfVectorizer(max_features=8, min_df=1)
    v.fit(["a b c d", "a b c d e f g h"])
    x = v.transform(["a b", "a b c d e f g h i j k l"])
    assert x[1, -1] > x[0, -1]


def test_tfidf_state_dict_roundtrip():
    v = TfidfVectorizer(max_features=8, min_df=1)
    corpus = ["alpha beta", "beta gamma", "gamma alpha"]
    v.fit(corpus)
    v2 = TfidfVectorizer.from_state_dict(v.state_dict())
    np.testing.assert_allclose(v.transform(corpus), v2.transform(corpus))


def test_mlp_learns_synthetic_quadratic():
    """Cost = (5 + 20*z)^2 where feature x encodes z: the MLP must beat the
    mean predictor by a wide margin on held-out data."""
    rng = np.random.default_rng(0)
    z = rng.uniform(0, 1, 200)
    x = np.stack([z, rng.normal(size=200)], axis=1)  # one signal, one noise
    cost = (5 + 20 * z) ** 2
    m = MlpCostModel.train(x[:150], cost[:150])
    pred = m.predict(x[150:])
    err = relative_error(pred, cost[150:])
    base = relative_error(
        np.full(50, cost[:150].mean()), cost[150:]
    )
    assert err < base / 2
    assert err < 25.0


def test_mlp_prediction_clipped_to_train_band():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(100, 3))
    cost = np.exp(rng.normal(10, 0.3, 100))
    m = MlpCostModel.train(x, cost, epochs=50)
    wild = m.predict(rng.normal(scale=50, size=(20, 3)))  # far OOD inputs
    assert wild.max() <= cost.max() * 1.3 + 1
    assert wild.min() >= cost.min() * 0.7 - 1


def test_end_to_end_predictor_accuracy():
    """Reproduces the paper's Table-1 MLP row setting: ~100 samples/class,
    relative error in the same ballpark as the paper's 53%."""
    rng = np.random.default_rng(0)
    classes = ["EV", "SC"]
    samples, test = {}, {}
    for cls in classes:
        tr = [sample_agent(rng, cls) for _ in range(100)]
        te = [sample_agent(rng, cls) for _ in range(40)]
        samples[cls] = ([a.prompt for a in tr], [a.true_cost for a in tr])
        test[cls] = ([a.prompt for a in te], np.array([a.true_cost for a in te]))
    pred = AgentCostPredictor(max_features=64)
    pred.fit(samples)
    for cls, (prompts, truth) in test.items():
        err = relative_error(pred.predict_batch(cls, prompts), truth)
        assert err < 120.0, f"{cls}: {err}"
    # runtime path: scalar predict returns a positive finite cost
    c = pred.predict("EV", test["EV"][0][0])
    assert np.isfinite(c) and c > 0
